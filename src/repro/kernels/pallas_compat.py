"""Version shims for the Pallas TPU API surface the kernels touch.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
both kernel modules need whichever name this jax build exposes.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
