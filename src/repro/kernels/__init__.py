"""Pallas TPU kernels for the paper's compute hot-spots.

  quant_matmul     fused unpack+dequant+matmul over packed LQ weights
  act_quant        fused runtime per-region activation quantization
  lut_matmul       paper section-V look-up-table scheme (one-hot partial sums)
  paged_attention  fused flash-decode over wire-format KV pages
                   (in-register affine/LUT dequant + online softmax)

Each kernel has a pure-jnp oracle in ref.py (paged_attention's oracle is
the model-layer gather+dequant path); ops.py holds the public jit'd
wrappers with backend selection (pallas / interpret / ref).
"""
from . import ops, ref, paged_attention
from .ops import (QWeight, quantize_weight, dequantize_weight, quant_matmul,
                  act_quant, lut_matmul, quant_dense)

__all__ = ["ops", "ref", "paged_attention", "QWeight", "quantize_weight",
           "dequantize_weight", "quant_matmul", "act_quant", "lut_matmul",
           "quant_dense"]
