"""Pallas TPU kernels for the paper's compute hot-spots.

  quant_matmul  fused unpack+dequant+matmul over packed LQ weights
  act_quant     fused runtime per-region activation quantization
  lut_matmul    paper section-V look-up-table scheme (one-hot partial sums)

Each kernel has a pure-jnp oracle in ref.py; ops.py holds the public
jit'd wrappers with backend selection (pallas / interpret / ref).
"""
from . import ops, ref
from .ops import (QWeight, quantize_weight, dequantize_weight, quant_matmul,
                  act_quant, lut_matmul, quant_dense)

__all__ = ["ops", "ref", "QWeight", "quantize_weight", "dequantize_weight",
           "quant_matmul", "act_quant", "lut_matmul", "quant_dense"]
