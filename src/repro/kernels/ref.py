"""Pure-jnp oracles for every Pallas kernel in this package.

Layout conventions shared by kernels and oracles (kernel wire format):

  quant matmul weights ("QWeight"):
    packed : uint8 (K // codes_per_byte, N)   codes packed along K
    scale  : f32   (G, N)   G = K // group_size    (per-region step s_lk)
    zmin   : f32   (G, N)                          (per-region x^lk_min)

  activation quant ("QAct"):
    packed : uint8 (M, K // codes_per_byte)   codes packed along K
    scale  : f32   (M, G)
    zmin   : f32   (M, G)

Regions run along the contraction axis K in both cases — exactly the
paper's Fig. 4 picture with the weight rows split into local regions.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import packing


# ---------------------------------------------------------------------------
# weight quantization into the kernel wire format
# ---------------------------------------------------------------------------

def quantize_weight(w: jnp.ndarray, bits: int, group_size: int):
    """f32 (K, N) -> (packed (K/cpb, N), scale (G, N), zmin (G, N))."""
    k, n = w.shape
    if k % group_size:
        raise ValueError(f"K={k} not divisible by group_size={group_size}")
    g = k // group_size
    wf = w.astype(jnp.float32).reshape(g, group_size, n)
    xmin = wf.min(axis=1)                                  # (G, N)
    xmax = wf.max(axis=1)
    levels = (1 << bits) - 1
    rng = xmax - xmin
    scale = jnp.where(rng > 0, rng / levels, jnp.ones_like(rng))
    codes = jnp.clip(jnp.round((wf - xmin[:, None]) / scale[:, None]),
                     0, levels).astype(jnp.uint8).reshape(k, n)
    packed = packing.pack(codes.T, bits).T                 # pack along K
    return packed, scale, zmin_cast(xmin)


def zmin_cast(x):
    return x.astype(jnp.float32)


def dequantize_weight(packed, scale, zmin, bits: int, group_size: int,
                      dtype=jnp.float32):
    """Inverse of :func:`quantize_weight` -> f32 (K, N)."""
    kp, n = packed.shape
    codes = packing.unpack(packed.T, bits).T.astype(jnp.float32)  # (K, N)
    k = codes.shape[0]
    g = k // group_size
    wf = (codes.reshape(g, group_size, n) * scale[:, None]
          + zmin[:, None]).reshape(k, n)
    return wf.astype(dtype)


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------

def quant_matmul(x, packed, scale, zmin, *, bits: int, group_size: int):
    """Oracle for kernels.quant_matmul: x @ dequant(w)."""
    w = dequantize_weight(packed, scale, zmin, bits, group_size)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def act_quant(x, *, bits: int, group_size: int):
    """Oracle for kernels.act_quant: runtime per-region activation quant.

    x: (M, K) float -> (packed (M, K/cpb), scale (M, G), zmin (M, G)).
    """
    m, k = x.shape
    if k % group_size:
        raise ValueError(f"K={k} not divisible by group_size={group_size}")
    g = k // group_size
    xf = x.astype(jnp.float32).reshape(m, g, group_size)
    xmin = xf.min(axis=-1)
    xmax = xf.max(axis=-1)
    levels = (1 << bits) - 1
    rng = xmax - xmin
    scale = jnp.where(rng > 0, rng / levels, jnp.ones_like(rng))
    codes = jnp.clip(jnp.round((xf - xmin[..., None]) / scale[..., None]),
                     0, levels).astype(jnp.uint8).reshape(m, k)
    return packing.pack(codes, bits), scale, xmin.astype(jnp.float32)


def act_dequant(packed, scale, zmin, *, bits: int, group_size: int):
    codes = packing.unpack(packed, bits).astype(jnp.float32)     # (M, K)
    m, k = codes.shape
    g = k // group_size
    return (codes.reshape(m, g, group_size) * scale[..., None]
            + zmin[..., None]).reshape(m, k)


def lut_matmul(a_packed, a_scale, a_zmin, w, *, bits: int, group_size: int):
    """Oracle for kernels.lut_matmul: dequant(a) @ w via explicit dequant.

    The kernel computes the identical quantity through the one-hot
    partial-sum dataflow (paper section V); numerically both equal
    dequant(a) @ w up to float association.
    """
    a = act_dequant(a_packed, a_scale, a_zmin, bits=bits,
                    group_size=group_size)
    return a @ w.astype(jnp.float32)
