"""Public jit'd wrappers around the Pallas kernels.

Backend policy (``backend=`` argument, default "auto"):

  * "pallas"     -- compile the Pallas TPU kernel (requires TPU).
  * "interpret"  -- Pallas interpret mode: the kernel body runs in Python on
                    CPU.  Used by tests to validate the exact kernel against
                    the pure-jnp oracle.
  * "ref"        -- the pure-jnp oracle itself (fast on CPU, identical math;
                    XLA fuses the dequant into the matmul).  Used on non-TPU
                    backends, including the dry-run host compile.
  * "auto"       -- "pallas" on TPU else "ref".

The :class:`QWeight` pytree is the deployment weight format -- packed codes
plus per-local-region affine -- and flows through jit / pjit / scan.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import packing
from . import ref as _ref
from . import quant_matmul as _qm
from . import act_quant as _aq
from . import lut_matmul as _lm


def resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


# ---------------------------------------------------------------------------
# QWeight: deployment weight format
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("packed", "scale", "zmin"),
         meta_fields=("bits", "group_size", "k", "n"))
@dataclasses.dataclass(frozen=True)
class QWeight:
    packed: jnp.ndarray   # uint8 (K/cpb, N) codes packed along K
    scale: jnp.ndarray    # f32 (G, N)
    zmin: jnp.ndarray     # f32 (G, N)
    bits: int
    group_size: int
    k: int
    n: int

    @property
    def shape(self):
        return (self.k, self.n)

    def nbytes(self) -> int:
        return (self.packed.size * self.packed.dtype.itemsize
                + self.scale.size * 4 + self.zmin.size * 4)


def quantize_weight(w, bits: int, group_size: int) -> QWeight:
    """Offline weight quantization into the kernel wire format."""
    k, n = w.shape
    packed, scale, zmin = _ref.quantize_weight(w, bits, group_size)
    return QWeight(packed=packed, scale=scale, zmin=zmin, bits=bits,
                   group_size=group_size, k=k, n=n)


def dequantize_weight(qw: QWeight, dtype=jnp.float32):
    return _ref.dequantize_weight(qw.packed, qw.scale, qw.zmin, qw.bits,
                                  qw.group_size, dtype)


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------

def quant_matmul(x, qw: QWeight, *, backend: str = "auto", **block_kw):
    """x (..., K) @ dequant(qw) -> (..., N).  Leading dims are flattened."""
    b = resolve_backend(backend)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, qw.k)
    if b == "ref":
        out = _ref.quant_matmul(x2, qw.packed, qw.scale, qw.zmin,
                                bits=qw.bits, group_size=qw.group_size)
    else:
        out = _qm.quant_matmul(x2, qw.packed, qw.scale, qw.zmin,
                               bits=qw.bits, group_size=qw.group_size,
                               interpret=(b == "interpret"), **block_kw)
    return out.reshape(*lead, qw.n)


def act_quant(x, *, bits: int, group_size: int, backend: str = "auto",
              **block_kw):
    """Runtime activation quantization (paper: inputs quantized online)."""
    b = resolve_backend(backend)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if b == "ref":
        packed, scale, zmin = _ref.act_quant(x2, bits=bits,
                                             group_size=group_size)
    else:
        packed, scale, zmin = _aq.act_quant(x2, bits=bits,
                                            group_size=group_size,
                                            interpret=(b == "interpret"),
                                            **block_kw)
    g = x.shape[-1] // group_size
    return (packed.reshape(*lead, -1), scale.reshape(*lead, g),
            zmin.reshape(*lead, g))


def lut_matmul(a_packed, a_scale, a_zmin, w, *, bits: int, group_size: int,
               backend: str = "auto", **block_kw):
    """Paper section-V LUT forward.  a_* in QAct wire format; w float (K, N)."""
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.lut_matmul(a_packed, a_scale, a_zmin, w, bits=bits,
                               group_size=group_size)
    return _lm.lut_matmul(a_packed, a_scale, a_zmin, w, bits=bits,
                          group_size=group_size,
                          interpret=(b == "interpret"), **block_kw)


def quant_dense(x, qw: QWeight, *, a_bits: int | None = None,
                lut: bool = False, backend: str = "auto"):
    """Full paper forward for one projection: optional runtime activation
    quant (a_bits), then packed-weight matmul -- or the LUT path when
    ``lut=True`` (activations quantized, weights float-reconstructed).
    """
    if lut:
        if a_bits is None:
            raise ValueError("LUT path requires a_bits")
        lead = x.shape[:-1]
        x2 = x.reshape(-1, qw.k)
        ap, asc, azm = act_quant(x2, bits=a_bits, group_size=qw.group_size,
                                 backend=backend)
        w = dequantize_weight(qw)
        out = lut_matmul(ap, asc, azm, w, bits=a_bits,
                         group_size=qw.group_size, backend=backend)
        return out.reshape(*lead, qw.n).astype(x.dtype)
    if a_bits is not None:
        lead = x.shape[:-1]
        x2 = x.reshape(-1, qw.k)
        ap, asc, azm = act_quant(x2, bits=a_bits, group_size=qw.group_size,
                                 backend=backend)
        xq = _ref.act_dequant(ap, asc, azm, bits=a_bits,
                              group_size=qw.group_size).astype(x.dtype)
        x = xq.reshape(*lead, qw.k)
    return quant_matmul(x, qw, backend=backend)
