"""Fused runtime activation quantization Pallas kernel.

The paper quantizes layer inputs *at runtime* (section IV: "the inputs have
to be converted into fixed point in runtime").  This kernel fuses the whole
pipeline over each local quantization region in one VMEM pass:

    per-region min / max  ->  scale s_lk, zero x^lk_min (eq. 5)
    round((x - min)/s)    ->  n-bit codes               (eq. 3)
    bit-pack codes into uint8 lanes

Block: (bm, K) rows -- a row's regions are contiguous along K, so one block
holds whole regions and the reductions stay in-registers.  Outputs:
packed (M, K/cpb) uint8, scale (M, G) f32, zmin (M, G) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import packing


def _kernel(x_ref, p_ref, s_ref, z_ref, *, bits: int, group_size: int):
    x = x_ref[...].astype(jnp.float32)                  # (bm, K)
    bm, k = x.shape
    g = k // group_size
    xg = x.reshape(bm, g, group_size)
    xmin = xg.min(axis=-1)                              # (bm, G)
    xmax = xg.max(axis=-1)
    levels = (1 << bits) - 1
    rng = xmax - xmin
    scale = jnp.where(rng > 0, rng / levels, jnp.ones_like(rng))
    codes = jnp.clip(jnp.round((xg - xmin[..., None]) / scale[..., None]),
                     0, levels).astype(jnp.int32).reshape(bm, k)
    if bits in packing.PACKABLE_BITS:
        cpb = packing.codes_per_byte(bits)
        c = codes.reshape(bm, k // cpb, cpb)
        shifts = jnp.arange(cpb, dtype=jnp.int32) * bits
        packed = (c << shifts[None, None, :]).sum(axis=-1)
    else:
        packed = codes
    p_ref[...] = packed.astype(jnp.uint8)
    s_ref[...] = scale
    z_ref[...] = xmin


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm",
                                             "interpret"))
def act_quant(x, *, bits: int, group_size: int, bm: int = 256,
              interpret: bool = False):
    """x (M, K) -> (packed (M, K/cpb) uint8, scale (M, G), zmin (M, G))."""
    m, k = x.shape
    if k % group_size:
        raise ValueError(f"K={k} not divisible by group_size={group_size}")
    g = k // group_size
    cpb = packing.codes_per_byte(bits)
    bm = min(bm, _round_up(m, 8))
    mp = _round_up(m, bm)
    x_p = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x

    packed, scale, zmin = pl.pallas_call(
        functools.partial(_kernel, bits=bits, group_size=group_size),
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k // cpb), lambda i: (i, 0)),
            pl.BlockSpec((bm, g), lambda i: (i, 0)),
            pl.BlockSpec((bm, g), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, k // cpb), jnp.uint8),
            jax.ShapeDtypeStruct((mp, g), jnp.float32),
            jax.ShapeDtypeStruct((mp, g), jnp.float32),
        ],
        interpret=interpret,
        name=f"act_quant_b{bits}g{group_size}",
    )(x_p)
    return packed[:m], scale[:m], zmin[:m]


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult
