"""Fused paged-attention Pallas kernel: flash-decode over wire-format pages.

The XLA paged-decode path pays three HBM round-trips on exactly the data
the LQ format compressed: gather wire pages into a logical view, dequantize
that view to a full fp pool copy, then attend over it
(``models/attention.py`` paged branch).  This kernel fuses all three — the
page table is a scalar-prefetch operand, so each grid step's BlockSpec
``index_map`` streams ONE physical page of packed codes (+ per-region
scale/zmin) straight into VMEM, dequantizes in-register, and folds the page
into an online-softmax accumulator (the flash-decode recurrence).  HBM
traffic is the wire bytes, once.

Dequant paths per page (``dequant=``):

  "affine"  unpack codes, ``k = codes * scale + zmin`` per local region,
            then the q@k / p@v matmuls — the throughput path, any bits.
  "lut"     bits <= 4: the paper's Table-Lookup trick (section V) applied
            to attention, reusing the ``core/lut.py`` /
            ``kernels/lut_matmul.py`` masked-matmul dataflow.  With n-bit
            codes there are only 2^n distinct values, so per local region

                q . k      = scale * sum_v v * (q @ mask_v) + zmin * sum_j q_j
                p . v_col  = sum_v v * (p*scale @ mask_v)   + (p @ zmin)

            with ``mask_v = (codes == v)`` a {0,1} matrix — table build and
            read are adds + binary matmuls, never a materialized fp page.
  "auto"    "lut" when the pool is quantized at bits <= 4, else "affine"
            ("fp" pools skip dequant entirely).

Grid ``(B, KV, P)`` — batch and kv-head parallel, the page axis sequential
("arbitrary") so the m/l/acc VMEM scratch carries the running softmax state
across pages.  Queries arrive as (B, Lq, KV, G, D) — GQA groups and the
multi-query run (Lq = k+1, the speculative verify) flatten onto one
(Lq*G, D) row block so both decode shapes share this kernel.  Masking
matches ``decode_attention`` over the gathered view: key position
``p*page_size + r`` is visible to query row i iff it is ``<= pos[b] + i``,
which also hides scratch-padded table entries (their positions lie past the
slot's live prefix) — an all-masked page contributes nothing because masked
probabilities are forced to zero *after* the running-max update.

``interpret=True`` runs the same kernel on CPU (CI parity tests); real-TPU
deployments should keep D and page_size lane/sublane aligned (see
``quant_matmul.py`` for the padding idiom).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

try:  # pallas is optional at import time: gate, don't crash (ROADMAP env)
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    from .pallas_compat import CompilerParams as _CompilerParams
    _PALLAS_ERR = None
except Exception as e:  # pragma: no cover - exercised on pallas-less hosts
    pl = pltpu = _CompilerParams = None
    _PALLAS_ERR = e

NEG_INF = -1e30
DEQUANT_MODES = ("auto", "affine", "lut")


def available() -> bool:
    """Whether the Pallas toolchain imported (kernel or interpret mode)."""
    return pl is not None


def default_mode() -> str:
    """Execution mode for this host: compiled on TPU, interpret elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def resolve_mode(fused: bool, *, obs=None) -> str | None:
    """Map an engine flag to a kernel mode, falling back to the XLA
    gather+dequant path (``None``) when Pallas is unavailable.

    A downgrade (fused requested, Pallas missing) is an SLO-relevant
    silent failure: when an enabled ``obs`` is passed, it is reported via
    :func:`report_fallback` so the run's trace/metrics carry the truth.
    """
    if not fused:
        return None
    if not available():
        report_fallback(obs)
        return None
    return default_mode()


def report_fallback(obs) -> bool:
    """Emit the one-shot ``fused_fallback`` trace event + counter.

    Returns True when something was recorded (engines use this to latch
    their own once-per-engine guard across late obs attachment)."""
    if obs is None or not getattr(obs, "enabled", False):
        return False
    obs.event("fused_fallback", backend=jax.default_backend(),
              error=repr(_PALLAS_ERR) if _PALLAS_ERR is not None else "")
    obs.metrics.counter("fused_fallback_total").inc()
    return True


def _infer_bits(packed_d: int, d: int) -> int:
    return {1: 8, 2: 4, 4: 2, 8: 1}[d // packed_d]


def dequant_path(bits: int | None, dequant: str = "auto") -> str:
    """The per-page dequant dataflow a pool format lowers to:
    ``"fp"`` (no dequant), ``"affine"``, or ``"lut"`` — the ``auto``
    policy picks LUT whenever the table fits (bits <= 4)."""
    if dequant not in DEQUANT_MODES:
        raise ValueError(f"dequant must be one of {DEQUANT_MODES}, "
                         f"got {dequant!r}")
    if bits is None:
        return "fp"
    lut = dequant == "lut" or (dequant == "auto" and bits <= 4)
    if lut and bits > 4:
        raise ValueError("LUT dequant needs kv bits <= 4 (section V.A)")
    return "lut" if lut else "affine"


def _unpack(pk, bits: int, d: int):
    """In-register unpack of uint8 lanes -> int32 codes (..., d).

    Must match ``core/packing.pack``: code j of a byte sits at shift
    ``(j % cpb) * bits``.
    """
    if bits == 8:
        return pk.astype(jnp.int32)
    cpb = 8 // bits
    shifts = jnp.arange(cpb, dtype=jnp.int32) * bits
    vals = (pk.astype(jnp.int32)[..., None] >> shifts) & ((1 << bits) - 1)
    return vals.reshape(*pk.shape[:-1], pk.shape[-1] * cpb)


def _row_positions(lqg: int, gq: int, page_size: int, pos_b, p):
    """(allowed (LqG, ps)) mask for this page: key pos <= query pos."""
    row = jax.lax.broadcasted_iota(jnp.int32, (lqg, 1), 0)
    qpos = pos_b + row // gq                                   # (LqG, 1)
    spos = p * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)                          # (1, ps)
    return spos <= qpos


def _online_step(s, allowed, acc_ref, m_ref, l_ref, pv_fn):
    """One flash-decode page update; returns nothing (scratch in place).

    ``pv_fn(pmat)`` produces the page's (LqG, D) probability-weighted
    values.  Masked probabilities are zeroed AFTER the max update: an
    all-masked page has m == NEG_INF and exp(s - m) == 1 there, which
    would otherwise poison l with phantom mass.
    """
    s = jnp.where(allowed, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    pmat = jnp.where(allowed, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + pmat.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + pv_fn(pmat)
    m_ref[...] = m_new


def _kernel_fp(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref, *, page_size: int, gq: int,
               p_steps: int, sm_scale: float):
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                        # (LqG, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                  # (ps, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    allowed = _row_positions(q.shape[0], gq, page_size,
                             pos_ref[pl.program_id(0)], p)
    _online_step(s, allowed, acc_ref, m_ref, l_ref,
                 lambda pmat: jax.lax.dot_general(
                     pmat, v, (((1,), (0,)), ((), ())),
                     preferred_element_type=jnp.float32))

    @pl.when(p == p_steps - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _kernel_quant(tbl_ref, pos_ref, q_ref, kp_ref, ks_ref, kz_ref,
                  vp_ref, vs_ref, vz_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bits: int, group_size: int, page_size: int, gq: int,
                  p_steps: int, sm_scale: float, lut: bool, d: int):
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    gr = d // group_size                                       # regions
    q = q_ref[0, 0].astype(jnp.float32)                        # (LqG, D)
    lqg = q.shape[0]
    k_codes = _unpack(kp_ref[0, :, 0, :], bits, d)             # (ps, D) i32
    k_sc = ks_ref[0, :, 0, :]                                  # (ps, Gr)
    k_zm = kz_ref[0, :, 0, :]
    v_codes = _unpack(vp_ref[0, :, 0, :], bits, d)
    v_sc = vs_ref[0, :, 0, :]
    v_zm = vz_ref[0, :, 0, :]

    if lut:
        # table-lookup scores: s*sum_v v*(q_g @ mask_v) + zmin*(q row sums)
        qg = q.reshape(lqg, gr, group_size)
        qsum = qg.sum(axis=-1)                                 # (LqG, Gr)
        kc = k_codes.reshape(page_size, gr, group_size)
        code_dot = jnp.zeros((lqg, page_size, gr), jnp.float32)
        for vcode in range(1, 1 << bits):                      # v=0 adds 0
            mask_v = (kc == vcode).astype(jnp.float32)
            code_dot += jnp.float32(vcode) * jnp.einsum(
                "lgj,sgj->lsg", qg, mask_v,
                preferred_element_type=jnp.float32)
        s = (code_dot * k_sc[None]).sum(axis=-1) \
            + jax.lax.dot_general(qsum, k_zm, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    else:
        kf = (k_codes.astype(jnp.float32)
              .reshape(page_size, gr, group_size)
              * k_sc[..., None] + k_zm[..., None]).reshape(page_size, d)
        s = jax.lax.dot_general(q, kf, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    s = s * sm_scale
    allowed = _row_positions(lqg, gq, page_size,
                             pos_ref[pl.program_id(0)], p)

    if lut:
        vc = v_codes.reshape(page_size, gr, group_size)

        def pv_fn(pmat):
            # p@v per region: sum_v v*((p*scale) @ mask_v) + (p @ zmin)
            ps_mat = pmat[:, :, None] * v_sc[None]             # (LqG,ps,Gr)
            pv = jnp.zeros((lqg, gr, group_size), jnp.float32)
            for vcode in range(1, 1 << bits):
                mask_v = (vc == vcode).astype(jnp.float32)
                pv += jnp.float32(vcode) * jnp.einsum(
                    "lsg,sgj->lgj", ps_mat, mask_v,
                    preferred_element_type=jnp.float32)
            pz = jax.lax.dot_general(pmat, v_zm, (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            return (pv + pz[..., None]).reshape(lqg, d)
    else:
        vf = (v_codes.astype(jnp.float32)
              .reshape(page_size, gr, group_size)
              * v_sc[..., None] + v_zm[..., None]).reshape(page_size, d)

        def pv_fn(pmat):
            return jax.lax.dot_general(pmat, vf, (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    _online_step(s, allowed, acc_ref, m_ref, l_ref, pv_fn)

    @pl.when(p == p_steps - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("dequant", "interpret"))
def paged_attention(q, k_pages, v_pages, page_table, pos, *,
                    dequant: str = "auto", interpret: bool = False):
    """Fused flash-decode over a paged pool, wire format and all.

    q (B, Lq, KV, G, D); ``k_pages``/``v_pages`` are one pool leaf — fp
    (n_pages, page_size, KV, D) arrays or LQ wire dicts with
    (n_pages, page_size, KV, D/cpb) packed codes (``core/kvwire.py``);
    page_table (B, P) int32 physical page ids, in table order (position t
    lives at table entry t // page_size); pos (B,) int32 — the absolute
    position of each slot's FIRST query row (query i attends ``<= pos+i``).
    Returns (B, Lq, KV, G, D) in q's dtype.  Token parity with
    ``gather_pages -> dequantize_kv -> decode_attention`` is the contract
    (tests/test_paged_attention.py); bit-identity is not, since the online
    softmax re-associates the reduction.
    """
    if pl is None:
        raise RuntimeError(f"Pallas unavailable: {_PALLAS_ERR!r}; use the "
                           "XLA gather+dequant path instead")
    b, lq, kvh, gq, d = q.shape
    lqg = lq * gq
    n_tbl = page_table.shape[1]
    quant = isinstance(k_pages, dict)
    sm_scale = d ** -0.5

    qm = q.transpose(0, 2, 1, 3, 4).reshape(b, kvh, lqg, d)
    qm = qm.astype(jnp.float32)
    tbl = page_table.astype(jnp.int32)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))

    def q_map(bi, h, p, tbl_ref, pos_ref):
        return (bi, h, 0, 0)

    def page_map(bi, h, p, tbl_ref, pos_ref):
        return (tbl_ref[bi, p], 0, h, 0)

    if quant:
        packed_d = k_pages["packed"].shape[-1]
        gr = k_pages["scale"].shape[-1]
        bits = _infer_bits(packed_d, d)
        group_size = d // gr
        page_size = k_pages["packed"].shape[1]
        lut = dequant_path(bits, dequant) == "lut"
        kernel = functools.partial(
            _kernel_quant, bits=bits, group_size=group_size,
            page_size=page_size, gq=gq, p_steps=n_tbl, sm_scale=sm_scale,
            lut=lut, d=d)
        leaf_specs = [
            pl.BlockSpec((1, page_size, 1, packed_d), page_map),
            pl.BlockSpec((1, page_size, 1, gr), page_map),
            pl.BlockSpec((1, page_size, 1, gr), page_map),
        ]
        in_specs = [pl.BlockSpec((1, 1, lqg, d), q_map)] \
            + leaf_specs + leaf_specs
        operands = (qm, k_pages["packed"], k_pages["scale"],
                    k_pages["zmin"], v_pages["packed"], v_pages["scale"],
                    v_pages["zmin"])
        name = f"paged_attention_{'lut' if lut else 'affine'}_b{bits}"
    else:
        dequant_path(None, dequant)            # still validates the mode
        page_size = k_pages.shape[1]
        kernel = functools.partial(
            _kernel_fp, page_size=page_size, gq=gq, p_steps=n_tbl,
            sm_scale=sm_scale)
        in_specs = [
            pl.BlockSpec((1, 1, lqg, d), q_map),
            pl.BlockSpec((1, page_size, 1, d), page_map),
            pl.BlockSpec((1, page_size, 1, d), page_map),
        ]
        operands = (qm, k_pages, v_pages)
        name = "paged_attention_fp"

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, kvh, n_tbl),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, lqg, d), q_map),
            scratch_shapes=[pltpu.VMEM((lqg, d), jnp.float32),
                            pltpu.VMEM((lqg, 1), jnp.float32),
                            pltpu.VMEM((lqg, 1), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, lqg, d), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=name,
    )(tbl, posb, *operands)
    out = out.reshape(b, kvh, lq, gq, d).transpose(0, 2, 1, 3, 4)
    return out.astype(q.dtype)
