"""Look-up-table matmul Pallas kernel (paper section V, TPU adaptation).

The paper replaces multiply-accumulates with table lookups: with n-bit
activations there are only 2^n distinct codes, so each local region's inner
product is  s * sum_v v*T[v] + zmin * sum_j w_j  with the "table"
T[v] = sum_{j: code_j == v} w_j  built by adds alone.

TPU has no scatter-accumulate into VMEM tables, but the *identical dataflow*
is a sequence of **binary masked matmuls** (DESIGN.md section 5.2): for each
code value v the mask (codes == v) is a {0,1} matrix and

    T_v = mask_v @ W                (the table build, one per code value)
    out += (v * s) . T_v            (the table read / combine)

The kernel loops v = 0..2^n-1 (unrolled -- 4 iterations at 2-bit), which is
the one-hot partial-sum matmul.  This is the fidelity implementation used
for paper-Table-3 accounting; the packed path (quant_matmul.py) is the
throughput deployment.

Grid: (M/bm, N/bn, G) with G = K / group_size -- one local region per K step.

Block shapes:
  codes (bm, group_size) uint8 (unpacked codes)
  scale (bm, 1) f32 ; zmin (bm, 1) f32     (this region's affine, per row)
  w     (group_size, bn)
  out   (bm, bn)  f32 accumulation across regions
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from .pallas_compat import CompilerParams as _CompilerParams


def _kernel(c_ref, s_ref, z_ref, w_ref, o_ref, acc_ref, *,
            bits: int, g_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = c_ref[...].astype(jnp.int32)            # (bm, gs)
    w = w_ref[...].astype(jnp.float32)              # (gs, bn)
    s = s_ref[...]                                  # (bm, 1)
    z = z_ref[...]

    # table build + combine: sum_v v * (mask_v @ W), v = 1 .. 2^bits-1
    # (v = 0 contributes nothing -- the paper's same skip, section V.C)
    code_dot = jnp.zeros_like(acc_ref)
    for v in range(1, 1 << bits):
        mask_v = (codes == v).astype(w.dtype)       # binary {0,1}
        t_v = jax.lax.dot_general(mask_v, w, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        code_dot += jnp.float32(v) * t_v
    # region affine: s * code_dot + zmin * sum_j w_j
    wsum = w.sum(axis=0, keepdims=True)             # (1, bn)
    acc_ref[...] += s * code_dot + z * wsum

    @pl.when(pl.program_id(2) == g_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm",
                                             "bn", "interpret"))
def lut_matmul(a_packed, a_scale, a_zmin, w, *, bits: int, group_size: int,
               bm: int = 128, bn: int = 128, interpret: bool = False):
    """dequant(a) @ w via the LUT dataflow.

    a_packed (M, K/cpb) uint8, a_scale/a_zmin (M, G), w (K, N) float.
    Returns f32 (M, N).
    """
    if bits > 4:
        raise ValueError("LUT path needs activation bits <= 4 (section V.A)")
    m = a_packed.shape[0]
    k, n = w.shape
    if k % group_size:
        # the grid covers K // group_size full regions; a ragged tail
        # would be silently dropped from the product, not just misrounded
        raise ValueError(
            f"K={k} is not a multiple of group_size={group_size}: the "
            f"trailing {k % group_size}-wide partial local region has no "
            f"grid step and would be dropped from the matmul")
    g = k // group_size
    codes = packing.unpack(a_packed, bits, k)            # (M, K) uint8

    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 128))
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    if mp != m:
        codes = jnp.pad(codes, ((0, mp - m), (0, 0)))
        a_scale = jnp.pad(a_scale, ((0, mp - m), (0, 0)))
        a_zmin = jnp.pad(a_zmin, ((0, mp - m), (0, 0)))
    w_p = jnp.pad(w, ((0, 0), (0, np_ - n))) if np_ != n else w

    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, g_steps=g),
        grid=(mp // bm, np_ // bn, g),
        in_specs=[
            pl.BlockSpec((bm, group_size), lambda i, j, r: (i, r)),
            pl.BlockSpec((bm, 1), lambda i, j, r: (i, r)),
            pl.BlockSpec((bm, 1), lambda i, j, r: (i, r)),
            pl.BlockSpec((group_size, bn), lambda i, j, r: (r, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=f"lut_matmul_b{bits}g{group_size}",
    )(codes, a_scale, a_zmin, w_p)
    return out[:m, :n]


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult
