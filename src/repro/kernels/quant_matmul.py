"""Fused dequantize-matmul Pallas TPU kernel.

Computes  ``x @ dequant(W)``  where W is stored as packed low-bit codes with
per-local-region affine params (paper section IV.C) -- the TPU deployment of
the paper's scheme (DESIGN.md section 5.1):

  * HBM->VMEM traffic moves the *packed* codes (bits/8 bytes per weight plus
    per-region scale/zmin), which is where the speedup lives on TPU: decode /
    small-batch GEMM is memory-bound, so bytes ~ bits/16 of bf16 is a direct
    roofline win.
  * Codes are unpacked and dequantized **in VMEM, per block, right before
    the MXU dot** -- never materialized in HBM.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary") with an f32 VMEM
accumulator; bk is a multiple of the local-region (group) size so each block
sees whole regions.

Block shapes:
  x      (bm, bk)            float32 / bfloat16
  packed (bk // cpb, bn)     uint8, codes packed along K
  scale  (bk // gs, bn)      f32
  zmin   (bk // gs, bn)      f32
  out    (bm, bn)            same dtype as x
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from .pallas_compat import CompilerParams as _CompilerParams


def _unpack_block(packed, bits: int, bk: int):
    """uint8 (bk/cpb, bn) -> int-code f32 (bk, bn), codes packed along axis 0."""
    if bits not in packing.PACKABLE_BITS:
        return packed.astype(jnp.float32)
    cpb = packing.codes_per_byte(bits)
    mask = (1 << bits) - 1
    p = packed.astype(jnp.int32)                       # (bk/cpb, bn)
    shifts = jnp.arange(cpb, dtype=jnp.int32) * bits   # code i at bit i*bits
    vals = (p[:, None, :] >> shifts[None, :, None]) & mask  # (bk/cpb, cpb, bn)
    return vals.reshape(bk, -1).astype(jnp.float32)


def _kernel(x_ref, p_ref, s_ref, z_ref, o_ref, acc_ref, *,
            bits: int, group_size: int, bk: int, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_block(p_ref[...], bits, bk)            # (bk, bn) f32
    g = bk // group_size
    s = s_ref[...]                                         # (g, bn)
    z = z_ref[...]
    w = (codes.reshape(g, group_size, -1) * s[:, None, :]
         + z[:, None, :]).reshape(bk, -1)                  # dequant in VMEM
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w.astype(x_ref.dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick_bk(k: int, group_size: int, target: int = 512) -> int:
    """Largest multiple of group_size that divides K and is <= target."""
    g = k // group_size
    best = group_size
    for mult in range(1, g + 1):
        bk = group_size * mult
        if bk > target:
            break
        if g % mult == 0:
            best = bk
    return best


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "bm", "bn", "bk", "interpret"))
def quant_matmul(x, packed, scale, zmin, *, bits: int, group_size: int,
                 bm: int = 128, bn: int = 128, bk: int | None = None,
                 interpret: bool = False):
    """x (M, K) @ dequant(packed/scale/zmin) (K, N) -> (M, N).

    M, N need not be tile-aligned (padded here); K must be divisible by the
    chosen bk (a multiple of group_size).
    """
    m, k = x.shape
    cpb = packing.codes_per_byte(bits)
    n = packed.shape[1]
    if k % group_size:
        # same hazard as lut_matmul: the K grid walks whole local regions,
        # so a ragged tail region would silently vanish from the product
        raise ValueError(
            f"K={k} is not a multiple of group_size={group_size}: the "
            f"trailing {k % group_size}-wide partial local region has no "
            f"grid step and would be dropped from the matmul")
    if bk is None:
        bk = _pick_bk(k, group_size)
    if k % bk or bk % group_size:
        raise ValueError(f"K={k} bk={bk} group_size={group_size} misaligned")

    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 128))
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    x_p = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    if np_ != n:
        packed = jnp.pad(packed, ((0, 0), (0, np_ - n)))
        scale = jnp.pad(scale, ((0, 0), (0, np_ - n)))
        zmin = jnp.pad(zmin, ((0, 0), (0, np_ - n)))

    k_steps = k // bk
    grid = (mp // bm, np_ // bn, k_steps)
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, group_size=group_size,
                          bk=bk, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // cpb, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group_size, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group_size, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=f"quant_matmul_b{bits}g{group_size}",
    )(x_p, packed, scale, zmin)
    return out[:m, :n]


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult
