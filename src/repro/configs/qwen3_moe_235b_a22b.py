"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4)
moe_d_ff=1536, vocab=151936, MoE 128 experts top-8, qk_norm, head_dim=128
[hf:Qwen/Qwen3-235B-A22B family].

Analytic check: total ~235B params, ~22B active per token
(see ModelConfig.param_count / active_param_count; asserted in tests).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    vocab_size=151936,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    qk_norm=True,
    rope=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    pattern=(("attn", "moe"),),
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    vocab_size=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=0,
    qk_norm=True,
    tie_embeddings=False,
    pattern=(("attn", "moe"),),
    n_experts=8,
    top_k=2,
    moe_d_ff=96,
    capacity_factor=8.0,   # no-drop at smoke scale: decode/prefill/forward agree exactly
    dtype="float32",
)
