"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256, head_dim=64, rope theta 5e5  [hf:meta-llama/Llama-3.2-1B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    vocab_size=128256,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    ffn_kind="swiglu",
    rope=True,
    rope_theta=500_000.0,
    tie_embeddings=True,
    pattern=(("attn", "swiglu"),),
)

SMOKE = ModelConfig(
    name="llama3-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    vocab_size=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    ffn_kind="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
    pattern=(("attn", "swiglu"),),
    dtype="float32",
)
