"""Assigned input-shape cells and ``input_specs()`` stand-ins.

Four shapes per LM arch (the assignment's 40 cells):

  train_4k      seq_len=4096    global_batch=256   -> lowers train_step
  prefill_32k   seq_len=32768   global_batch=32    -> lowers prefill
  decode_32k    seq_len=32768   global_batch=128   -> lowers serve_step
                                                      (1 new token, KV=32k)
  long_500k     seq_len=524288  global_batch=1     -> lowers serve_step

``long_500k`` needs sub-quadratic attention: it RUNS for mamba2-130m (SSM),
recurrentgemma-2b (RG-LRU + window-2048 local attn) and llama4-scout
(3/4 chunk-8192 layers; the 12 global layers' 512k KV is sharded).  It is
SKIPPED for the pure full-attention archs (DESIGN.md §4) — a dense 512k KV
at batch 1 is not those models' claimed regime.

All specs are ``jax.ShapeDtypeStruct`` — weak-type-correct, shardable, no
device allocation; decode cells build the cache skeleton via ``eval_shape``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# archs with sub-quadratic paths that run the 512k cell
LONG_CONTEXT_ARCHS = ("mamba2-130m", "recurrentgemma-2b",
                      "llama4-scout-17b-a16e")


def cell_supported(arch: str, shape: str) -> tuple:
    """(supported, reason) for one (arch, shape) cell."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, ("full quadratic attention at 512k/batch-1 is outside "
                       "this arch's regime (DESIGN.md §4 shape-cell skips)")
    return True, ""


def cells():
    """All 40 (arch, shape) cells with support status."""
    from repro.configs import ARCHS
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            ok, why = cell_supported(arch, shape)
            out.append((arch, shape, ok, why))
    return out


# ---------------------------------------------------------------------------
# spec builders
# ---------------------------------------------------------------------------

def _frontend_specs(cfg: ModelConfig, batch: int) -> dict:
    if cfg.frontend == "audio_stub":
        return {"frames": jax.ShapeDtypeStruct(
            (batch, cfg.enc_len, cfg.frontend_dim), jnp.float32)}
    if cfg.frontend == "patch_stub":
        return {"patches": jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.frontend_dim), jnp.float32)}
    return {}


def _token_len(cfg: ModelConfig, seq_len: int) -> int:
    """Token count s.t. tokens + patch prefix == seq_len positions."""
    if cfg.frontend == "patch_stub":
        return seq_len - cfg.n_patches
    return seq_len


def train_specs(cfg: ModelConfig, seq_len: int, global_batch: int) -> dict:
    """Inputs of train_step: tokens + next-token labels (+ frontend)."""
    lt = _token_len(cfg, seq_len)
    specs = {"tokens": jax.ShapeDtypeStruct((global_batch, lt), jnp.int32),
             "labels": jax.ShapeDtypeStruct((global_batch, lt), jnp.int32)}
    specs.update(_frontend_specs(cfg, global_batch))
    return specs


def prefill_specs(cfg: ModelConfig, seq_len: int, global_batch: int) -> dict:
    lt = _token_len(cfg, seq_len)
    specs = {"tokens": jax.ShapeDtypeStruct((global_batch, lt), jnp.int32)}
    specs.update(_frontend_specs(cfg, global_batch))
    return specs


def cache_specs(cfg: ModelConfig, global_batch: int, max_len: int):
    """Abstract KV/state cache skeleton (eval_shape — no allocation)."""
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, global_batch, max_len))


def decode_specs(cfg: ModelConfig, seq_len: int, global_batch: int) -> dict:
    """Inputs of serve_step: one new token + the KV cache of ``seq_len``."""
    return {"tokens": jax.ShapeDtypeStruct((global_batch, 1), jnp.int32),
            "cache": cache_specs(cfg, global_batch, seq_len)}


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    cell = SHAPES[shape]
    if cell.kind == "train":
        return train_specs(cfg, cell.seq_len, cell.global_batch)
    if cell.kind == "prefill":
        return prefill_specs(cfg, cell.seq_len, cell.global_batch)
    return decode_specs(cfg, cell.seq_len, cell.global_batch)


# ---------------------------------------------------------------------------
# concrete (small) batches for smoke tests
# ---------------------------------------------------------------------------

def demo_batch(cfg: ModelConfig, batch: int, seq_len: int, key=None) -> dict:
    """Concrete batch matching train_specs, for CPU smoke tests."""
    key = key if key is not None else jax.random.key(0)
    k1, k2 = jax.random.split(key)
    lt = _token_len(cfg, seq_len)
    out = {"tokens": jax.random.randint(k1, (batch, lt), 0, cfg.vocab_size,
                                        jnp.int32)}
    out["labels"] = jnp.roll(out["tokens"], -1, axis=1)
    if cfg.frontend == "audio_stub":
        out["frames"] = jax.random.normal(
            k2, (batch, cfg.enc_len, cfg.frontend_dim), jnp.float32)
    elif cfg.frontend == "patch_stub":
        out["patches"] = jax.random.normal(
            k2, (batch, cfg.n_patches, cfg.frontend_dim), jnp.float32)
    return out
