"""internvl2-1b [vlm] — Qwen2-0.5B LM backbone: 24L d_model=896 14H
(GQA kv=2) d_ff=4864 vocab=151655  [arXiv:2404.16821].

The InternViT-300M vision frontend is a STUB per the assignment:
``input_specs`` supplies 256 precomputed patch embeddings (ViT hidden 1024
-> frontend Dense 1024->896) prepended to the token sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    vocab_size=151655,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    ffn_kind="swiglu",
    rope=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    attn_bias=True,          # Qwen2 uses QKV biases
    pattern=(("attn", "swiglu"),),
    frontend="patch_stub",
    n_patches=256,
    frontend_dim=1024,
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    vocab_size=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    ffn_kind="swiglu",
    tie_embeddings=True,
    attn_bias=True,
    pattern=(("attn", "swiglu"),),
    frontend="patch_stub",
    n_patches=4,
    frontend_dim=32,
    dtype="float32",
)
