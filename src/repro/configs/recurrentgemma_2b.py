"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000, RG-LRU + local attention 1:2 (rec, rec, attn), window 2048
[arXiv:2402.19427].

26 layers / pattern length 3 -> 8 scan-stacked superblocks + 2 tail layers
(rec, rec) — exercises the unscanned-tail path.  Sub-quadratic (window
attention + linear recurrence) -> runs the ``long_500k`` cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    vocab_size=256000,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    ffn_kind="gelu",
    rope=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    pattern=(
        ("rglru", "gelu"),
        ("rglru", "gelu"),
        ("attn_local", "gelu"),
    ),
    window=2048,
    lru_width=2560,
    conv_kernel=4,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    vocab_size=256,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    ffn_kind="gelu",
    tie_embeddings=True,
    pattern=(
        ("rglru", "gelu"),
        ("rglru", "gelu"),
        ("attn_local", "gelu"),
    ),
    window=8,
    lru_width=64,
    conv_kernel=4,
    dtype="float32",
)
