"""granite-3-2b [dense] — 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155  [hf:ibm-granite/granite-3.0-2b-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    vocab_size=49155,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    ffn_kind="swiglu",
    rope=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    pattern=(("attn", "swiglu"),),
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    vocab_size=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    ffn_kind="swiglu",
    tie_embeddings=True,
    pattern=(("attn", "swiglu"),),
    dtype="float32",
)
