"""Architecture config registry (``--arch <id>``).

One module per assigned architecture exports ``CONFIG`` (the exact published
configuration) and ``SMOKE`` (a reduced same-family config for CPU tests).
The paper's own CNNs (AlexNet / VGG-16) live in ``alexnet.py`` / ``vgg16.py``
as :class:`repro.models.convnet.ConvConfig` and feed the accuracy benchmarks;
they are not part of the 40 dry-run cells.

``get(name)`` accepts both hyphen and underscore spellings.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "whisper-large-v3",
    "granite-3-2b",
    "llama3.2-1b",
    "qwen3-8b",
    "qwen3-14b",
    "qwen3-moe-235b-a22b",
    "llama4-scout-17b-a16e",
    "internvl2-1b",
    "mamba2-130m",
    "recurrentgemma-2b",
)


def _modname(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def _load(name: str):
    key = _modname(name)
    for arch in ARCHS:
        if _modname(arch) == key:
            return importlib.import_module(f"repro.configs.{key}")
    raise KeyError(f"unknown arch {name!r}; known: {list(ARCHS)}")


def get(name: str) -> ModelConfig:
    """Full published config for ``--arch <name>``."""
    return _load(name).CONFIG


def smoke(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return _load(name).SMOKE


def names() -> tuple:
    return ARCHS
