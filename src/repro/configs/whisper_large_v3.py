"""whisper-large-v3 [audio] — enc-dec transformer backbone.

32 decoder layers (+ 32 encoder layers), d_model=1280, 20 heads (MHA:
kv=20), d_ff=5120, vocab=51866.  GELU FFN, LayerNorm, learned positions,
attention biases, no RoPE  [arXiv:2212.04356].

The conv/mel frontend is a STUB per the assignment: ``input_specs`` supplies
1500 precomputed frame embeddings (80 mel bins -> frontend Dense 80->1280).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    vocab_size=51866,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    ffn_kind="gelu",
    rope=False,
    pos_embed="learned",
    attn_bias=True,
    norm_kind="layer",
    tie_embeddings=True,
    pattern=(("attn", "gelu"),),
    n_enc_layers=32,
    enc_len=1500,
    frontend="audio_stub",
    frontend_dim=80,
    max_seq=32768,          # covers the decode_32k cell (learned positions)
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    vocab_size=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    ffn_kind="gelu",
    rope=False,
    pos_embed="learned",
    attn_bias=True,
    norm_kind="layer",
    tie_embeddings=True,
    pattern=(("attn", "gelu"),),
    n_enc_layers=2,
    enc_len=12,
    frontend="audio_stub",
    frontend_dim=16,
    max_seq=128,
    dtype="float32",
)
