"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936, qk_norm, head_dim=128  [hf:Qwen/Qwen3-14B family]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    vocab_size=151936,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    ffn_kind="swiglu",
    qk_norm=True,
    rope=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    pattern=(("attn", "swiglu"),),
)

SMOKE = ModelConfig(
    name="qwen3-14b-smoke",
    family="dense",
    n_layers=2,
    d_model=80,
    vocab_size=256,
    n_heads=5,
    n_kv_heads=1,
    head_dim=16,
    d_ff=160,
    ffn_kind="swiglu",
    qk_norm=True,
    tie_embeddings=False,
    pattern=(("attn", "swiglu"),),
    dtype="float32",
)
