"""mamba2-130m [ssm] — 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality)  [arXiv:2405.21060].

No KV cache: the ``decode_32k`` / ``long_500k`` cells carry the O(1)
recurrent state (conv tail + per-head SSM state), which is what makes this
arch run the 512k cell.  LQR applies to in/out projections; the SSM state
quantization replaces KV-cache quantization (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab_size=50280,
    d_ff=0,
    rope=False,
    tie_embeddings=True,
    pattern=(("mamba2", "none"),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    conv_kernel=4,
    ssd_chunk=256,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    vocab_size=256,
    d_ff=0,
    rope=False,
    tie_embeddings=True,
    pattern=(("mamba2", "none"),),
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_groups=1,
    conv_kernel=4,
    ssd_chunk=8,
    dtype="float32",
)
