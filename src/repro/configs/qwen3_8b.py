"""qwen3-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936, qk_norm, head_dim=128  [hf:Qwen/Qwen3-8B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    vocab_size=151936,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    ffn_kind="swiglu",
    qk_norm=True,
    rope=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    pattern=(("attn", "swiglu"),),
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    vocab_size=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    ffn_kind="swiglu",
    qk_norm=True,
    tie_embeddings=False,
    pattern=(("attn", "swiglu"),),
    dtype="float32",
)
