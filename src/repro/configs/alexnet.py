"""AlexNet (the paper's first example task) — exact Caffe shapes for the
op-count tables + the reduced trainable CNN for accuracy benchmarks."""
from repro.models.convnet import ALEXNET as CONFIG, MINI_CNN as SMOKE  # noqa
