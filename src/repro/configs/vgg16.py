"""VGG-16 (the paper's second example task) — exact shapes for the
op-count tables; accuracy benchmarks share the reduced CNN."""
from repro.models.convnet import VGG16 as CONFIG, MINI_CNN as SMOKE  # noqa
