"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8)
moe_d_ff=8192 (+ shared expert 8192), vocab=202048, MoE 16e top-1,
head_dim=128  [hf:meta-llama/Llama-4-Scout-17B-16E].

Layer layout: 3 chunked-local-attention layers (chunk 8192) : 1 global
full-attention layer (NoPE in the original; kept RoPE-free on the global
layers is immaterial to the systems study, we keep RoPE uniform).  Every
layer is MoE (interleave step 1) with one shared expert.

The ``long_500k`` cell runs: 3/4 of layers are chunk-8192 local (O(L*c)),
the 12 global layers hold the full 512k KV (sharded; DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    vocab_size=202048,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    rope=True,
    rope_theta=500_000.0,
    tie_embeddings=False,
    pattern=(
        ("attn_chunked", "moe"),
        ("attn_chunked", "moe"),
        ("attn_chunked", "moe"),
        ("attn", "moe"),
    ),
    chunk=8192,
    n_experts=16,
    top_k=1,
    moe_d_ff=8192,
    shared_ff=8192,
)

SMOKE = ModelConfig(
    name="llama4-smoke",
    family="moe",
    n_layers=4,
    d_model=64,
    vocab_size=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=0,
    tie_embeddings=False,
    pattern=(
        ("attn_chunked", "moe"),
        ("attn_chunked", "moe"),
        ("attn_chunked", "moe"),
        ("attn", "moe"),
    ),
    chunk=8,
    n_experts=4,
    top_k=1,
    moe_d_ff=96,
    shared_ff=96,
    capacity_factor=8.0,   # no-drop at smoke scale: decode/prefill/forward agree exactly
    dtype="float32",
)
