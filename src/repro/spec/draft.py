"""Drafting: k greedy tokens per slot from the low-bit planned model.

The draft engine decodes on its own *shadow* pages (the draft half of a
:class:`~repro.spec.engine.PairedKVPool`): same page ids and page tables
as the verifier pool, its own wire format (the draft plan's ``kv_bits``).
Drafting is k calls of the draft engine's single compiled decode step —
the draft pays k sequential low-bit steps so the verifier can score all
k proposals in ONE batched forward.

The draft cache needs no rewind.  After a cycle accepts m of k proposals
the stale rows (the rejected suffix) sit strictly *ahead* of the new
position, and the next cycle overwrites each one before it first becomes
attendable (row ``pos + i`` is written at draft step i, masked until
then) — see ``tests/test_spec.py::test_draft_rows_overwritten_before_read``.
"""
from __future__ import annotations

import numpy as np


def draft_proposals(draft_engine, draft_pool, tokens, page_table, pos,
                    k: int, key) -> np.ndarray:
    """Propose ``k`` greedy continuations per slot.

    ``tokens``/``pos`` are (max_slots,) — each slot's pending token and
    the position it will be written at; ``page_table`` is the shared
    (max_slots, pages_per_slot) table.  Returns proposals (max_slots, k)
    int32: column i holds the draft's token for position ``pos + i + 1``.
    Writes rows ``pos .. pos+k-1`` of the draft pool.
    """
    cur = np.asarray(tokens, np.int32)
    pos = np.asarray(pos, np.int32)
    out = np.zeros((cur.shape[0], k), np.int32)
    for i in range(k):
        cur = draft_engine.decode_step_batch(draft_pool, cur, page_table,
                                             pos + i, key)
        out[:, i] = cur
    return out
