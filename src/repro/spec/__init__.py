"""Self-speculative decoding: a low-bit QuantPlan of the model drafts,
a high-bit plan of the SAME checkpoint verifies.

The paper's result — aggressive local-quantization-region schemes keep
most of the model's quality at a fraction of the compute — makes the
2-bit plan a *free* draft model: no second checkpoint, no distillation.
``SpeculativeEngine`` wraps the paged serving stack so greedy outputs
stay token-for-token identical to the verifier-only engine while the
verifier runs one batched multi-token step per accepted run.

    draft.py    k greedy proposals per slot on the draft's shadow pages
    verify.py   batched length-(k+1) verify + longest-prefix acceptance
    engine.py   SpeculativeEngine / PairedKVPool (drop-in for PagedEngine)
"""
from .draft import draft_proposals
from .verify import accept_lengths, emitted_tokens
from .engine import PairedKVPool, SpeculativeEngine, shared_segment_keys

__all__ = ["draft_proposals", "accept_lengths", "emitted_tokens",
           "PairedKVPool", "SpeculativeEngine", "shared_segment_keys"]
