"""Verification: batched multi-token scoring + longest-prefix acceptance.

One verify cycle feeds the run ``[d_0, d_1, .., d_k]`` (the slot's
pending token plus its k draft proposals) through the verifier's single
compiled length-(k+1) paged forward
(``PagedEngine.decode_multi_batch`` -> ``transformer.paged_decode_multi``)
and greedy-scores every position: ``g_i`` is the token the verifier
would emit after seeing up to ``d_i``.  Proposal ``d_{i+1}`` is accepted
iff it equals ``g_i``; the cycle emits the accepted prefix plus the
verifier's correction token at the first mismatch.

Greedy speculative decoding is *exact*: every emitted token is a
verifier greedy token, so the output stream is byte-identical to the
verifier-only engine's — speedup without accuracy loss.  (The bonus
token ``g_k`` of an all-accepted run is deliberately NOT emitted: the
draft cache never saw ``d_k`` as an input, and skipping the bonus keeps
the draft's shadow cache gap-free without a catch-up forward.)
"""
from __future__ import annotations

import numpy as np


def accept_lengths(proposals: np.ndarray, greedy: np.ndarray) -> np.ndarray:
    """Per-slot longest accepted prefix length m in [0, k].

    ``proposals`` (B, k) — draft tokens d_1..d_k; ``greedy`` (B, k+1) —
    verifier greedy tokens g_0..g_k.  d_{i+1} is accepted iff it matches
    g_i AND every earlier proposal was accepted.
    """
    proposals = np.asarray(proposals)
    greedy = np.asarray(greedy)
    k = proposals.shape[1]
    matches = (proposals == greedy[:, :k]).astype(np.int64)
    return matches.cumprod(axis=1).sum(axis=1)


def emitted_tokens(proposals: np.ndarray, greedy: np.ndarray,
                   m: np.ndarray) -> list:
    """Per-slot emission lists for accepted lengths ``m``.

    A slot with m < k emits its m accepted proposals plus the verifier's
    correction ``g_m`` (m+1 tokens); a fully-accepted slot emits its k
    proposals (the bonus token is skipped — see module docstring).
    Every emitted token is a verifier greedy token.
    """
    k = proposals.shape[1]
    out = []
    for b in range(proposals.shape[0]):
        mb = int(m[b])
        if mb < k:
            toks = [int(t) for t in proposals[b, :mb]]
            toks.append(int(greedy[b, mb]))
        else:
            toks = [int(t) for t in proposals[b]]
        out.append(toks)
    return out
