"""SpeculativeEngine: a drop-in PagedEngine whose low-bit plan drafts.

Two :class:`~repro.plan.QuantPlan` views of ONE base checkpoint serve
together: the draft plan (e.g. uniform 2-bit) proposes ``spec_k`` greedy
tokens per slot on its own shadow pages, the verifier plan (e.g. 8-bit
or fp) scores the whole run in one batched multi-token paged forward and
accepts the longest matching prefix.  Greedy outputs are token-for-token
identical to the verifier-only engine (``tests/test_spec.py``); the
verifier runs ``< 1`` compiled steps per emitted token whenever drafts
are accepted at all.

Packed weight leaves are SHARED between draft and verifier wherever the
two plans agree per layer-segment (one ``leaf_cache`` threads both
``quantize_params`` calls) — the same dedup mechanism
``repro.fleet.FleetRegistry`` uses across tenants.

Scheduler integration is the engine step contract
(``advance_slots`` / ``lookahead_tokens`` / ``prefill_request`` /
``new_pool``), so :class:`~repro.serve.Scheduler`,
:class:`~repro.serve.Server` and the fleet router compose unchanged.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.obs import NOOP, Stopwatch
from repro.obs.profile import annotate
from repro.serve.engine import EngineConfig, PagedConfig, PagedEngine
from repro.serve.pool import PagedKVPool
from repro.spec.draft import draft_proposals
from repro.spec.verify import accept_lengths, emitted_tokens


def shared_segment_keys(cfg: ModelConfig, plan_a, plan_b) -> list:
    """Leaf-cache keys two plans have in common: the packed segments one
    shared base checkpoint materializes once for both."""
    a = set(transformer.plan_leaf_keys(cfg, plan_a))
    return [k for k in transformer.plan_leaf_keys(cfg, plan_b) if k in a]


class PairedKVPool(PagedKVPool):
    """A verifier page pool plus the draft's shadow pages, one allocator.

    Page ids are shared: page ``p`` of the verifier arrays and page ``p``
    of the draft arrays belong to the same request, so the scheduler's
    alloc/free/table bookkeeping (the :class:`PagedKVPool` base) covers
    both.  The draft side stores the SAME positions in its own wire
    format (the draft plan's kv bitwidths).  ``defrag`` permutes both
    pytrees coherently; ``truncate`` rewinds the verifier side only — the
    draft's stale rows sit ahead of the new position and are overwritten
    before they become attendable (see ``spec/draft.py``).
    """

    def __init__(self, cfg: ModelConfig, *, n_pages: int, page_size: int,
                 kv_bits=None, kv_group: int = 64, draft_kv_bits=None,
                 draft_kv_group: int = 64, dtype=None, obs=None):
        super().__init__(cfg, n_pages=n_pages, page_size=page_size,
                         kv_bits=kv_bits, kv_group=kv_group, dtype=dtype,
                         obs=obs)
        # the draft pool's own allocator is unused (page ids are shared),
        # so it stays un-instrumented: no double-counted alloc events
        self.draft = PagedKVPool(cfg, n_pages=n_pages, page_size=page_size,
                                 kv_bits=draft_kv_bits,
                                 kv_group=draft_kv_group, dtype=dtype)

    def defrag(self) -> dict[int, int]:
        mapping = super().defrag()
        perm = np.zeros((self.n_pages,), np.int32)
        for old, new in mapping.items():
            perm[new] = old
        self.draft.pages = self.draft._permute(self.draft.pages,
                                               jnp.asarray(perm))
        return mapping

    def draft_nbytes(self) -> int:
        return self.draft.nbytes()

    def total_nbytes(self) -> int:
        """Resident bytes of both sides (the draft cache is the price of
        speculation; the draft plan's kv bits keep it small)."""
        return self.nbytes() + self.draft.nbytes()


class SpeculativeEngine:
    """Draft/verify wrapper satisfying the paged-engine step contract."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 pcfg: PagedConfig, *, draft_plan, spec_k: int = 4,
                 obs=None):
        if ecfg.temperature != 0.0:
            raise ValueError(
                "speculative decoding is greedy-only: acceptance compares "
                "draft tokens against the verifier's argmax, and the "
                "token-exactness guarantee is a greedy statement")
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        if draft_plan is None:
            raise ValueError("pass the draft QuantPlan (the low-bit view "
                             "of the shared checkpoint)")
        if transformer.is_quantized_params(params):
            raise ValueError(
                "SpeculativeEngine needs the raw fp checkpoint: the draft "
                "plan packs its own view of the weights (and shares "
                "segments with the verifier via the leaf cache), which "
                "pre-packed params cannot provide")
        self.cfg, self.pcfg, self.spec_k = cfg, pcfg, spec_k
        self.ecfg = ecfg
        self._obs = obs or NOOP

        leaf_cache: dict = {}
        vparams = params
        if ecfg.plan is not None:
            vparams = transformer.quantize_params(params, cfg, ecfg.plan,
                                                  leaf_cache=leaf_cache)
        self.verifier = PagedEngine(cfg, vparams, ecfg, pcfg,
                                    obs=self._obs)
        verifier_keys = set(leaf_cache)

        # the draft inherits the cell geometry and gets its own plan; its
        # cache format comes from the draft plan's kv map when it has one,
        # else it MIRRORS the verifier's kv layout — including a verifier
        # plan's per-layer map (attached to the draft plan itself, so the
        # draft's walker/param segmentation matches its shadow cache) —
        # so the shadow pool never silently falls back to fp pages
        if getattr(draft_plan, "has_kv", False):
            d_kv_bits, d_kv_group = None, ecfg.kv_group
        else:
            v_bits, v_group = self.verifier._kv_layout
            if isinstance(v_bits, tuple):
                draft_plan = draft_plan.with_kv(
                    {f"layer.{i}": b for i, b in enumerate(v_bits)},
                    default=None, kv_group=v_group)
                d_kv_bits, d_kv_group = None, v_group
            else:
                d_kv_bits, d_kv_group = v_bits, v_group
        d_ecfg = dataclasses.replace(
            ecfg, plan=draft_plan, weight_scheme=None, a_bits=None,
            kv_bits=d_kv_bits, kv_group=d_kv_group)
        dparams = transformer.quantize_params(params, cfg, draft_plan,
                                              leaf_cache=leaf_cache)
        self.draft = PagedEngine(cfg, dparams, d_ecfg, pcfg,
                                 obs=self._obs)
        # the draft's per-micro-step timings stay distinguishable from
        # the verifier's in the shared registry
        self.draft.obs_metric_labels = {"engine": "draft"}
        self.shared_keys = [
            k for k in transformer.plan_leaf_keys(cfg, draft_plan)
            if k in verifier_keys]

        # speculation telemetry (live-budget slots only)
        self.cycles = 0           # batched verify forwards run
        self.slot_cycles = 0      # (live slot, cycle) pairs — the per-
        #                           stream cost unit: a plain engine pays
        #                           exactly one of these per emitted token
        self.drafted = 0          # draft tokens proposed
        self.accepted = 0         # draft tokens the verifier accepted
        self.emitted = 0          # tokens actually delivered

    # ------------------------------------------------------ observability
    @property
    def obs(self):
        return self._obs

    @obs.setter
    def obs(self, obs):
        """Adopting a new sink propagates to both wrapped engines (the
        Server/FleetRouter re-wire path)."""
        self._obs = obs
        self.verifier.obs = obs
        self.draft.obs = obs

    @property
    def attention_mode(self) -> str:
        """The verifier's resolved paged-attention path (the one that
        decides token-exactness and dominates device time)."""
        return self.verifier.attention_mode

    @property
    def fused_fallback(self) -> bool:
        """True when either wrapped engine silently downgraded from the
        requested fused kernel to the XLA gather+dequant path."""
        return self.verifier.fused_fallback or self.draft.fused_fallback

    def report_attention_mode(self, obs=None):
        """Forward the one-shot fused-fallback report to both engines."""
        self.verifier.report_attention_mode(obs)
        self.draft.report_attention_mode(obs)

    # ------------------------------------------------------ pool plumbing
    def new_pool(self) -> PairedKVPool:
        vb, vg = self.verifier._kv_layout
        db, dg = self.draft._kv_layout
        return PairedKVPool(self.cfg, n_pages=self.pcfg.n_pages,
                            page_size=self.pcfg.page_size, kv_bits=vb,
                            kv_group=vg, draft_kv_bits=db,
                            draft_kv_group=dg, obs=self._obs)

    def prefill_request(self, pool: PairedKVPool, tokens, page_ids,
                        key) -> int:
        """Prefill the prompt into BOTH sides' pages (same ids); the
        emitted first token is the verifier's (token-exactness)."""
        self.draft.prefill_request(pool.draft, tokens, page_ids, key)
        return self.verifier.prefill_request(pool, tokens, page_ids, key)

    # ------------------------------------------------------- scheduler API
    @property
    def lookahead_tokens(self) -> int:
        """The verify step writes rows ``pos .. pos + spec_k`` per slot."""
        return self.spec_k + 1

    def advance_slots(self, pool: PairedKVPool, tokens, page_table, pos,
                      key, budget=None):
        """One speculative cycle for every slot: draft k, verify once,
        accept the longest matching prefix.  Returns per-slot emission
        lists (1..k verifier-greedy tokens each) and per-slot rejected
        draft counts.  The caller rewinds the pool past what it consumes
        (``Scheduler.step`` -> ``pool.truncate``)."""
        k = self.spec_k
        obs = self._obs
        sw = Stopwatch(obs.clock) if obs.enabled else None
        with obs.tracer.span("draft", k=k), annotate("draft"):
            props = draft_proposals(self.draft, pool.draft, tokens,
                                    page_table, pos, k, key)
            if sw is not None:
                jax.block_until_ready(pool.draft.pages)
        if sw is not None:
            obs.metrics.histogram("serve_draft_ms").record(sw.elapsed_ms())
            sw.reset()
        run = np.concatenate(
            [np.asarray(tokens, np.int32)[:, None], props], axis=1)
        with obs.tracer.span("verify", k=k), annotate("verify"):
            greedy = self.verifier.decode_multi_batch(pool, run, page_table,
                                                      pos)
            if sw is not None:
                jax.block_until_ready(pool.pages)
        if sw is not None:
            obs.metrics.histogram("serve_verify_ms").record(sw.elapsed_ms())
        m = accept_lengths(props, greedy)
        emitted = emitted_tokens(props, greedy, m)
        rejected = [k - int(mb) for mb in m]

        self.cycles += 1
        cycle_drafted = cycle_accepted = 0
        for b, toks in enumerate(emitted):
            live = budget[b] if budget is not None else len(toks)
            if live <= 0:
                continue
            self.slot_cycles += 1
            cycle_drafted += k
            cycle_accepted += int(m[b])
            self.emitted += min(len(toks), live)
        self.drafted += cycle_drafted
        self.accepted += cycle_accepted
        if obs.enabled:
            obs.metrics.counter("spec_drafted_total").inc(cycle_drafted)
            obs.metrics.counter("spec_accepted_total").inc(cycle_accepted)
            obs.metrics.gauge("spec_acceptance_rate").set(
                self.acceptance_rate())
        return emitted, rejected

    # ------------------------------------------------------------- stats
    @property
    def decode_compilations(self) -> int:
        """Distinct batched-verify traces (1 == one compiled length-(k+1)
        step; the acceptance bar's ``decode_compilations == 1``)."""
        return self.verifier._multi_paged._cache_size()

    @property
    def draft_compilations(self) -> int:
        return self.draft._step_paged._cache_size()

    def acceptance_rate(self) -> float:
        return self.accepted / self.drafted if self.drafted else 0.0

    def verify_steps_per_token(self) -> float:
        """Per-stream verifier cost: (live slot, verify) pairs per emitted
        token.  A plain engine pays exactly 1.0; anything below 1.0 is
        decode speedup bought by accepted drafts."""
        return (self.slot_cycles / self.emitted if self.emitted
                else float("inf"))

    def shared_weight_bytes(self) -> float:
        """Wire bytes the draft re-uses from the verifier's packed leaves
        (priced with the planner's cost model)."""
        from repro.plan.costmodel import leaf_key_bytes
        return sum(leaf_key_bytes(self.cfg, k) for k in self.shared_keys)

    def spec_stats(self) -> dict:
        return {"spec_k": self.spec_k, "cycles": self.cycles,
                "drafted": self.drafted, "accepted": self.accepted,
                "emitted": self.emitted,
                "acceptance_rate": round(self.acceptance_rate(), 4),
                "verify_steps_per_token":
                    round(self.verify_steps_per_token(), 4),
                "shared_weight_bytes": self.shared_weight_bytes(),
                "verify_compilations": self.decode_compilations,
                "draft_compilations": self.draft_compilations,
                "attention_mode": self.attention_mode,
                "draft_attention_mode": self.draft.attention_mode}
