"""AdamW from scratch (optax is not installed in this environment).

Functional optimizer in the optax style:

    opt = adamw(lr_schedule, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Moments are fp32 regardless of param dtype (mixed-precision-safe); the
learning rate is resolved from the schedule at ``state.count``.  ``mask``
disables weight decay on norm/bias/scalar leaves (standard LM practice).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=("count", "mu", "nu", "master"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class OptState:
    count: jnp.ndarray           # () int32
    mu: dict                     # first moment, fp32
    nu: dict                     # second moment, fp32
    master: object = ()          # fp32 master params (mixed precision) or ()


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def default_wd_mask(params):
    """True (decay) for >=2-D leaves; False for norms/biases/scalars."""
    return jax.tree.map(lambda p: jnp.ndim(p) >= 2, params)


def adamw(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          mask: Callable | None = default_wd_mask,
          keep_master: bool = False) -> Optimizer:
    """``keep_master=True`` — mixed precision: model params may be bf16
    (halving every weight all-gather and HBM read; §Perf), the optimizer
    carries the fp32 master copy and the update is computed there."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(jnp.shape(p), jnp.float32)
        master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
                  if keep_master else ())
        return OptState(count=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(zeros, params),
                        master=master)

    def update(grads, state: OptState, params):
        count = state.count + 1
        step_lr = jnp.asarray(lr_fn(count), jnp.float32)
        b1c = 1.0 - b1 ** count.astype(jnp.float32)
        b2c = 1.0 - b2 ** count.astype(jnp.float32)

        def moment1(g, m):
            return b1 * m + (1.0 - b1) * g.astype(jnp.float32)

        def moment2(g, v):
            g = g.astype(jnp.float32)
            return b2 * v + (1.0 - b2) * g * g

        mu = jax.tree.map(moment1, grads, state.mu)
        nu = jax.tree.map(moment2, grads, state.nu)

        wd_mask = (mask(params) if mask is not None
                   else jax.tree.map(lambda _: True, params))
        base = state.master if keep_master else params

        def step(m, v, b, decay):
            mhat = m / b1c
            vhat = v / b2c
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * jnp.where(decay, 1.0, 0.0) \
                    * b.astype(jnp.float32)
            return b.astype(jnp.float32) - step_lr * u

        new_base = jax.tree.map(step, mu, nu, base, wd_mask)
        # updates are deltas in the PARAM dtype so params' =
        # round(new_master) exactly (no drift between master and params)
        updates = jax.tree.map(lambda nb, p: nb.astype(p.dtype) - p,
                               new_base, params)
        return updates, OptState(count=count, mu=mu, nu=nu,
                                 master=new_base if keep_master else ())

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
