"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    def fn(step):
        return jnp.asarray(value, jnp.float32)
    return fn


def warmup_cosine(peak: float, *, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    """Linear warmup to ``peak`` then cosine decay to ``final_frac * peak``."""
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac * peak + (1 - final_frac) * peak \
            * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn
