from .adamw import adamw, OptState, apply_updates
from .schedules import warmup_cosine, constant
from .clipping import global_norm, clip_by_global_norm
