"""Trainer: the fault-tolerant outer loop.

Responsibilities:

  * jit (or pjit, when given a mesh + rules) the train_step with donated
    state;
  * drive the index-based data pipeline (restart-exact: batch(step) is a
    pure function of step);
  * periodic atomic checkpoints; ``run()`` begins with ``restore_latest``
    so a preempted/killed job resumes from the last committed step;
  * straggler monitor on step wall-times with pluggable policy;
  * metric history (host-side floats) for the examples/benchmarks.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.straggler import StragglerMonitor
from repro.models.config import ModelConfig
from repro.models.layers import QuantPolicy, NO_QUANT
from .step import TrainHParams, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    keep_ckpts: int = 3
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, hp: TrainHParams, data,
                 tcfg: TrainerConfig, *, policy: QuantPolicy = NO_QUANT,
                 mesh=None, rules=None):
        self.cfg, self.hp, self.data, self.tcfg = cfg, hp, data, tcfg
        self.policy = policy
        self.init_state_fn, step_fn = make_train_step(cfg, hp, policy)
        if mesh is not None and rules is not None:
            from repro.distributed.sharding import batch_sharding
            abstract = jax.eval_shape(
                self.init_state_fn, jax.random.key(tcfg.seed))
            state_shardings = rules.shardings(abstract, mesh)
            sample = data.batch(0)
            bshard = batch_sharding(sample, mesh, rules.dp)
            self.step_fn = jax.jit(step_fn,
                                   in_shardings=(state_shardings, bshard),
                                   out_shardings=(state_shardings, None),
                                   donate_argnums=(0,))
            self._mesh = mesh
        else:
            self.step_fn = jax.jit(step_fn, donate_argnums=(0,))
            self._mesh = None
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
                     if tcfg.ckpt_dir else None)
        self.monitor = StragglerMonitor()
        self.history = []

    # ------------------------------------------------------------------
    def init_state(self):
        return self.init_state_fn(jax.random.key(self.tcfg.seed))

    def run(self, state=None):
        """Train to total_steps; auto-resume from the newest checkpoint."""
        start = 0
        if state is None:
            state = self.init_state()
            if self.ckpt is not None:
                restored = self.ckpt.restore_latest(state)
                if restored is not None:
                    start, tree = restored
                    state = jax.tree.map(
                        lambda like, arr: jax.numpy.asarray(
                            arr, like.dtype), state, tree)
                    print(f"[trainer] resumed from step {start}")

        for step in range(start, self.tcfg.total_steps):
            batch = self.data.batch(step)
            self.monitor.start()
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = self.monitor.stop("step")
            rec = {k: float(v) for k, v in metrics.items()}
            rec.update(step=step, wall_s=dt)
            self.history.append(rec)
            if step % self.tcfg.log_every == 0:
                print(f"[trainer] step {step:5d} loss {rec['loss']:.4f} "
                      f"grad_norm {rec['grad_norm']:.3f} {dt * 1e3:.0f} ms")
            if self.ckpt is not None and (step + 1) % self.tcfg.ckpt_every \
                    == 0:
                self.ckpt.save(step + 1, state)
        return state
