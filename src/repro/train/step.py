"""train_step builder: loss, grads, microbatch accumulation, compression.

One jit-compiled function per run::

    state, metrics = train_step(state, batch)

  * cross-entropy LM loss (labels shifted upstream by the data pipeline;
    VLM patch-prefix positions are excluded by slicing logits to the
    label length) + MoE aux loss;
  * optional **microbatch gradient accumulation** (``microsteps > 1``) via
    lax.scan over batch slices — the activation-memory lever for the
    235B-class cells;
  * optional **LQ gradient compression** (core/gradcomp.py) with error
    feedback — the paper's block format applied to the DP all-reduce;
    inside jit the quantize-dequantize runs before the pjit-inserted
    all-reduce, shrinking the collective payload when lowered with
    shard_map, and acting as the numerics-faithful reference otherwise;
  * global-norm clipping, AdamW, schedule — all in one XLA program so
    backward collectives overlap the optimizer per XLA's async scheduler.

QAT: pass a ``QuantPolicy`` with mode='qat' — projections fake-quantize
with straight-through gradients (core/qat.py), training the paper's
deployment numerics directly.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import gradcomp
from repro.distributed.actshard import constrain
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import QuantPolicy, NO_QUANT
from repro.optim import adamw, apply_updates, clip_by_global_norm


@partial(jax.tree_util.register_dataclass,
         data_fields=("params", "opt", "err", "step"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class TrainState:
    params: dict
    opt: object                 # OptState
    err: object                 # gradcomp error-feedback tree or () if off
    step: jnp.ndarray           # () int32


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: object = 3e-4                   # float or schedule fn
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    clip_norm: float = 1.0
    microsteps: int = 1
    grad_compress_bits: int | None = None   # None = fp32 all-reduce
    grad_compress_group: int = 128
    z_loss: float = 0.0                 # logit-norm regularizer
    aux_weight: float = 0.01            # MoE load-balance weight
    param_dtype: str = "float32"        # "bfloat16": fp32 master in opt


def loss_fn(params, cfg: ModelConfig, batch, *, policy: QuantPolicy,
            hp: TrainHParams):
    logits, aux = transformer.forward(params, cfg, batch, policy=policy,
                                      training=True)
    labels = batch["labels"]
    # VLM: logits cover patch prefix + tokens; loss on the token tail only
    logits = logits[:, -labels.shape[1]:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    nll = constrain(nll, "batch", "seq")
    mask = (labels >= 0).astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    if hp.z_loss:
        zl = jnp.square(jax.nn.logsumexp(logits, axis=-1))
        loss = loss + hp.z_loss * (zl * mask).sum() \
            / jnp.maximum(mask.sum(), 1.0)
    total = loss + hp.aux_weight * aux
    return total, {"loss": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, hp: TrainHParams,
                    policy: QuantPolicy = NO_QUANT):
    """Build (init_state, train_step); both pure, jit/pjit-ready."""
    mixed = hp.param_dtype != "float32"
    opt = adamw(hp.lr, b1=hp.b1, b2=hp.b2, weight_decay=hp.weight_decay,
                keep_master=mixed)
    compress = hp.grad_compress_bits is not None

    def init_state(key) -> TrainState:
        params = transformer.init_params(cfg, key)
        if mixed:
            params = jax.tree.map(
                lambda p: p.astype(hp.param_dtype), params)
        err = (gradcomp.init_error_state(params) if compress else
               jnp.zeros((), jnp.float32))
        return TrainState(params=params, opt=opt.init(params), err=err,
                          step=jnp.zeros((), jnp.int32))

    grad_of = jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b, policy=policy, hp=hp), has_aux=True)

    def accumulate_grads(params, batch):
        if hp.microsteps == 1:
            (_, metrics), grads = grad_of(params, batch)
            return grads, metrics

        def slice_micro(x, i):
            per = x.shape[0] // hp.microsteps
            return jax.lax.dynamic_slice_in_dim(x, i * per, per, axis=0)

        def body(carry, i):
            acc, macc = carry
            micro = jax.tree.map(lambda x: slice_micro(x, i), batch)
            (_, metrics), grads = grad_of(params, micro)
            acc = jax.tree.map(jnp.add, acc, grads)
            macc = jax.tree.map(jnp.add, macc, metrics)
            return (acc, macc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        m0 = {"loss": jnp.zeros((), jnp.float32),
              "aux": jnp.zeros((), jnp.float32)}
        (grads, msum), _ = jax.lax.scan(body, (zeros, m0),
                                        jnp.arange(hp.microsteps))
        inv = 1.0 / hp.microsteps
        grads = jax.tree.map(lambda g: g * inv, grads)
        metrics = jax.tree.map(lambda m: m * inv, msum)
        return grads, metrics

    def train_step(state: TrainState, batch):
        grads, metrics = accumulate_grads(state.params, batch)

        new_err = state.err
        if compress:
            corrected = gradcomp.apply_error_feedback(grads, state.err)
            quantized = jax.tree.map(
                lambda g: gradcomp.roundtrip_leaf(
                    g, hp.grad_compress_bits, hp.grad_compress_group),
                corrected)
            new_err = gradcomp.new_error(corrected, quantized)
            grads = quantized

        grads, gnorm = clip_by_global_norm(grads, hp.clip_norm)
        updates, new_opt = opt.update(grads, state.opt, state.params)
        new_params = apply_updates(state.params, updates)
        metrics = dict(metrics, grad_norm=gnorm)
        return TrainState(params=new_params, opt=new_opt, err=new_err,
                          step=state.step + 1), metrics

    return init_state, train_step
