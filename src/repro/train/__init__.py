from .step import TrainState, make_train_step, loss_fn, TrainHParams
from .trainer import Trainer, TrainerConfig
