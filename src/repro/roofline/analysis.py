"""Roofline terms from a compiled dry-run artifact (no real hardware).

Per (arch x shape x mesh):

    compute_s    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory_s     = HLO_bytes   / (chips * HBM_BW)
    collective_s = sum per collective op of operand bytes / (chips * LINK_BW)

**Normalization.** Under SPMD, ``compiled.cost_analysis()`` reports the
cost of the *per-device* partitioned module (verified empirically: a
1024^3 matmul split over 4 host devices reports total/4 flops).  The HLO
text likewise carries per-device operand shapes.  So the formulas above
are evaluated with per-device numerators and per-chip denominators —
algebraically identical to the global form (total = per_device * chips).

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text and sum the *output
operand* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (output size == bytes each participant
must receive — the wire-level lower bound; ``-start``/``-done`` pairs are
counted once).

Hardware constants (TPU v5e-class target, per chip):
    197 TFLOP/s bf16;  819 GB/s HBM;  ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# "bf16[2048,512]{1,0}" or "u8[128]" (layout suffix optional)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes per collective kind from HLO text."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind, _ = m.groups()
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                    # per-device (see module docstring)
    hlo_bytes: float                    # per-device
    coll_bytes: float                   # per-device
    coll_detail: dict
    model_flops: float                  # GLOBAL 6*N*D (6*N_active*D for MoE)
    peak_bytes_per_chip: float          # memory_analysis: peak HBM
    hw: HW = dataclasses.field(default_factory=HW)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector.

        Both sides normalized per device: global 6ND / chips vs per-device
        HLO flops.
        """
        return (self.model_flops / self.chips) / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """compute_s / bound_s: 1.0 == the step is compute-bound at peak."""
        return self.compute_s / max(self.bound_s, 1e-30)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes, "coll_detail": self.coll_detail,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_bytes_per_chip": self.peak_bytes_per_chip,
        }


def roofline_from_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                           chips: int, model_flops: float,
                           hlo_text: str | None = None) -> RooflineReport:
    """Build the report from the compiled artifact.

    Primary source: the loop-aware HLO analyzer (roofline/hlo_cost.py) —
    ``compiled.cost_analysis()`` counts while-loop bodies once, which
    undercounts scan-stacked layers by ~n_layers x (verified; see
    hlo_cost docstring).  The raw cost_analysis numbers are kept in the
    report for reference.
    """
    from . import hlo_cost
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_cost.analyze(text)

    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):
        xla_cost = xla_cost[0]

    peak_bytes = 0.0
    try:
        ma = compiled.memory_analysis()
        peak_bytes = float(
            getattr(ma, "peak_memory_in_bytes", 0.0)
            or (getattr(ma, "temp_size_in_bytes", 0.0)
                + getattr(ma, "argument_size_in_bytes", 0.0)
                + getattr(ma, "output_size_in_bytes", 0.0)))
    except Exception:
        pass

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=cost.flops, hlo_bytes=cost.bytes,
        coll_bytes=cost.coll_bytes,
        coll_detail={"bytes": cost.coll_detail, "counts": cost.coll_counts,
                     "xla_flops_once": float(xla_cost.get("flops", 0.0)),
                     "xla_bytes_once": float(
                         xla_cost.get("bytes accessed", 0.0))},
        model_flops=model_flops, peak_bytes_per_chip=peak_bytes)
