from .analysis import (HW, roofline_from_compiled, collective_bytes,
                       RooflineReport)
