"""Loop-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE (verified:
a lax.scan of 8 matmuls reports 1 matmul of flops) — useless for
scan-stacked layers (94x undercount) and flash-attention block loops.
This module re-derives per-device flops / bytes / collective-bytes from
``compiled.as_text()`` with loops handled:

  * the module text is split into named computations; each computation
    keeps a symbol table (op name -> output shape) because optimized HLO
    does not inline operand shapes;
  * the ENTRY computation is walked; ``while`` ops recurse into their
    body/condition with multiplier = trip count, read from the
    ``backend_config={"known_trip_count":{"n":...}}`` annotation (XLA
    emits it for counted loops; fallback: parse the condition's
    ``constant`` + ``compare`` direction);
  * ``fusion`` recurses for FLOPs only (fusion internals are not memory
    traffic); ``call``/``conditional`` (max branch) recurse for both;
  * FLOPs: ``dot`` = 2 * prod(output dims) * prod(lhs contracting dims);
    other ops ignored (elementwise flops are noise next to matmuls here);
  * bytes: per top-level op, operand + output sizes (post-fusion op
    boundaries are real transfers); plumbing ops (tuple /
    get-tuple-element / parameter / bitcast / constant / iota) are free;
  * collective bytes: output sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, times enclosing
    trip counts; ``-start`` counted, ``-done`` skipped.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\(.*?\)|[a-z0-9]+\[[\d,]*\])(?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count...\{.n.:.?"?(\d+)')
_REF_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dims(dim_str: str):
    return [int(d) for d in dim_str.split(",") if d]


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class OpLine:
    name: str
    out_shape: str
    opcode: str
    rest: str                 # args + attrs (rest of the line)

    def args(self) -> list:
        """Operand names (up to the closing paren of the arg list)."""
        depth, i = 1, 0
        s = self.rest
        while i < len(s) and depth:
            if s[i] == "(":
                depth += 1
            elif s[i] == ")":
                depth -= 1
            i += 1
        return _REF_RE.findall(s[:i])


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symbols: dict             # op name -> out_shape string


def parse_computations(hlo: str) -> dict:
    comps = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if stripped.endswith("{") and "=" not in stripped.split("(")[0]:
            m = _COMP_HDR_RE.match(stripped)
            if m:
                current = Computation(m.group(2), [], {})
                comps[current.name] = current
                if m.group(1):
                    comps["__entry__"] = current
                continue
        if current is None:
            continue
        if stripped.strip() == "}":
            current = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = OpLine(*m.groups())
            current.ops.append(op)
            current.symbols[op.name] = op.out_shape
    return comps


def _dot_flops(op: OpLine, comp: Computation) -> float:
    out = 1
    for _, dims in _SHAPE_RE.findall(op.out_shape):
        for d in _dims(dims):
            out *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    argnames = op.args()
    if not m or not argnames:
        return 2.0 * out
    lhs_shape = comp.symbols.get(argnames[0], "")
    shapes = _SHAPE_RE.findall(lhs_shape)
    if not shapes:
        return 2.0 * out
    lhs_dims = _dims(shapes[0][1])
    contract = 1
    for i in _dims(m.group(1)):
        if i < len(lhs_dims):
            contract *= lhs_dims[i]
    return 2.0 * out * contract


def _trip_count(op: OpLine, comps: dict) -> int:
    m = _TRIP_RE.search(op.rest)
    if m:
        return max(int(m.group(1)), 1)
    # fallback: constant in the condition computation + compare direction
    cond = _COND_RE.search(op.rest)
    if not cond or cond.group(1) not in comps:
        return 1
    const, direction = None, None
    for o in comps[cond.group(1)].ops:
        if o.opcode == "constant":
            c = re.match(r"(-?\d+)", o.rest)
            if c:
                const = int(c.group(1))
        if o.opcode == "compare":
            d = re.search(r"direction=(\w+)", o.rest)
            direction = d.group(1) if d else None
    if const is None:
        return 1
    return max(const + (1 if direction in ("LE", "GE") else 0), 1)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def __iadd__(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_bytes += other.coll_bytes
        for k in COLLECTIVES:
            self.coll_detail[k] += other.coll_detail[k]
            self.coll_counts[k] += other.coll_counts[k]
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.coll_bytes * m,
                    {k: v * m for k, v in self.coll_detail.items()},
                    {k: v * m for k, v in self.coll_counts.items()})


_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id",
             "custom-call"}


def _comp_cost(name: str, comps: dict, memo: dict) -> Cost:
    if name in memo:
        return memo[name]
    memo[name] = Cost()                       # cycle guard
    total = Cost()
    comp = comps.get(name)
    if comp is not None:
        for op in comp.ops:
            total += _op_cost(op, comp, comps, memo)
    memo[name] = total
    return total


def _op_cost(op: OpLine, comp: Computation, comps: dict,
             memo: dict) -> Cost:
    c = Cost()
    kind = op.opcode
    base_kind = kind.removesuffix("-start")

    if kind.endswith("-done") or kind.endswith("-update-done"):
        return c

    if kind == "while":
        trip = _trip_count(op, comps)
        body = _BODY_RE.search(op.rest)
        cond = _COND_RE.search(op.rest)
        if body:
            c += _comp_cost(body.group(1), comps, memo).scaled(trip)
        if cond:
            c += _comp_cost(cond.group(1), comps, memo).scaled(trip)
        return c

    if kind == "conditional":
        m = _BRANCHES_RE.search(op.rest)
        if m:
            branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
            costs = [_comp_cost(b, comps, memo) for b in branches if b]
            if costs:
                c += max(costs, key=lambda x: x.flops + x.bytes)
        c.bytes += _shape_bytes(op.out_shape)
        return c

    if kind == "fusion":
        m = _CALLS_RE.search(op.rest)
        sliced = {}
        if m:
            inner = _comp_cost(m.group(1), comps, memo)
            c.flops += inner.flops            # fusion internals: flops only
            c.coll_bytes += inner.coll_bytes
            for k in COLLECTIVES:
                c.coll_detail[k] += inner.coll_detail[k]
                c.coll_counts[k] += inner.coll_counts[k]
            sliced = _sliced_params(comps.get(m.group(1)))
        c.bytes += _shape_bytes(op.out_shape) \
            + _operand_bytes(op, comp, sliced)
        return c

    if kind in ("call", "async-start"):
        m = _CALLS_RE.search(op.rest)
        if m:
            c += _comp_cost(m.group(1), comps, memo)
        return c

    if base_kind in COLLECTIVES:
        nbytes = _shape_bytes(op.out_shape)
        c.coll_bytes += nbytes
        c.coll_detail[base_kind] += nbytes
        c.coll_counts[base_kind] += 1
        c.bytes += nbytes + _operand_bytes(op, comp)
        return c

    if kind in _FREE_OPS:
        return c

    # Slicing ops touch slice-sized data, not the (possibly scan-carried,
    # layer-stacked) full operand: a dynamic-slice of a (94, B, L, D)
    # residual stack reads one layer's slice; a dynamic-update-slice
    # writes one (XLA updates in place).  Counting full operands here
    # overstated memory terms ~100x on scan-stacked models.
    if kind in ("dynamic-slice", "slice"):
        c.bytes += 2 * _shape_bytes(op.out_shape)
        return c
    if kind == "dynamic-update-slice":
        args = op.args()
        upd = comp.symbols.get(args[1], "") if len(args) > 1 else ""
        c.bytes += 2 * _shape_bytes(upd)
        return c

    if kind == "dot":
        c.flops += _dot_flops(op, comp)

    c.bytes += _shape_bytes(op.out_shape) + _operand_bytes(op, comp)
    return c


def _sliced_params(comp: Computation | None) -> dict:
    """param index -> sliced bytes, for fused computations whose
    parameters are consumed only through (dynamic-)slice ops."""
    if comp is None:
        return {}
    param_idx = {}                      # op name -> parameter index
    for o in comp.ops:
        if o.opcode == "parameter":
            m = re.match(r"(\d+)", o.rest)
            if m:
                param_idx[o.name] = int(m.group(1))
    uses = {}                           # param name -> list of (op, bytes)
    for o in comp.ops:
        for a in o.args():
            if a in param_idx:
                uses.setdefault(a, []).append(o)
    out = {}
    for pname, consumers in uses.items():
        if consumers and all(o.opcode in ("dynamic-slice", "slice",
                                          "dynamic-update-slice")
                             for o in consumers):
            # slice reads count slice bytes; an in-place dynamic-update-
            # slice reads ~nothing of the buffer (the update data arrives
            # via another operand, counted normally)
            out[param_idx[pname]] = sum(
                _shape_bytes(o.out_shape)
                for o in consumers
                if o.opcode in ("dynamic-slice", "slice"))
    return out


def _operand_bytes(op: OpLine, comp: Computation,
                   sliced: dict | None = None) -> int:
    total = 0
    for i, name in enumerate(op.args()):
        if sliced and i in sliced:
            total += sliced[i]
        else:
            total += _shape_bytes(comp.symbols.get(name, ""))
    return total


def analyze(hlo_text: str) -> Cost:
    comps = parse_computations(hlo_text)
    memo = {}
    if "__entry__" in comps:
        return _comp_cost("__entry__", comps, memo)
    if not comps:
        return Cost()
    entry = max(comps.values(), key=lambda c: len(c.ops))
    return _comp_cost(entry.name, comps, memo)


# ---------------------------------------------------------------------------
# per-op profile: where do the bytes/flops actually go?
# ---------------------------------------------------------------------------

def top_ops(hlo_text: str, k: int = 25, key: str = "bytes") -> list:
    """Top-k individual ops by bytes or flops, loop-trip-multiplied.

    Returns [(cost, trip, opcode, name, out_shape, op_name_metadata)].
    The profiler for the dry-run world: no wall clock, but exact
    byte/flop attribution per HLO op.
    """
    comps = parse_computations(hlo_text)
    if "__entry__" not in comps:
        return []
    memo = {}
    rows = []

    def walk(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            kind = op.opcode
            if kind == "while":
                trip = _trip_count(op, comps)
                body = _BODY_RE.search(op.rest)
                if body:
                    walk(body.group(1), mult * trip)
                continue
            if kind in ("call", "async-start", "conditional"):
                m = _CALLS_RE.search(op.rest)
                if m:
                    walk(m.group(1), mult)
                continue
            c = _op_cost(op, comp, comps, memo)
            val = getattr(c, key)
            if val > 0:
                meta = re.search(r'op_name="([^"]*)"', op.rest)
                rows.append((val * mult, mult, kind, op.name,
                             op.out_shape[:60],
                             meta.group(1)[:90] if meta else ""))

    walk("__entry__", 1.0)
    rows.sort(reverse=True)
    return rows[:k]
