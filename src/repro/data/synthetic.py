"""Deterministic synthetic data pipelines.

Accuracy experiments run on procedurally generated data (DESIGN.md §5,
changed assumption (a)): no ImageNet here, so the *qualitative* claims
(LQ >> DQ at low bit, smaller regions help) are validated on learnable
synthetic tasks.

Two generators:

  * ``SyntheticLM`` — a hidden-Markov "language": a random but FIXED
    (seeded) transition matrix with Zipfian emission; a model that learns
    the transitions reaches a loss well below the unigram entropy, so loss
    curves are meaningful and quantization damage is measurable.
  * ``SyntheticClassification`` — Gaussian class prototypes + noise
    (stand-in for the paper's image-classification task): top-1 accuracy
    is the paper's Table-2 metric.

Both are **index-based**: ``batch(step)`` is a pure function of
``(seed, step)``, so the pipeline is checkpoint-free — restart at step k
reproduces the exact stream (fault-tolerance substrate).  Sharding: each
data-parallel replica draws the same global batch and slices its shard
(``shard(batch, i, n)``) — no cross-host coordination needed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 64           # HMM hidden states
    temperature: float = 0.3     # lower -> more predictable language


class SyntheticLM:
    """Deterministic HMM language model stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        s = cfg.n_states
        # sparse-ish transition structure: each state prefers ~4 successors
        logits = rng.normal(size=(s, s)).astype(np.float32)
        top = np.argsort(logits, axis=1)[:, -4:]
        boost = np.full_like(logits, -4.0)
        np.put_along_axis(boost, top, 2.0, axis=1)
        self._trans = jnp.asarray(boost / cfg.temperature)
        # Zipfian emission: state i emits tokens near (i * vocab / states)
        emit = rng.normal(size=(s, cfg.vocab_size)).astype(np.float32)
        centers = (np.arange(s)[:, None] * cfg.vocab_size // s
                   + np.arange(cfg.vocab_size)[None, :] * 0) % cfg.vocab_size
        col = np.arange(cfg.vocab_size)[None, :]
        dist = np.minimum((col - centers) % cfg.vocab_size,
                          (centers - col) % cfg.vocab_size)
        emit = emit - 0.5 * dist.astype(np.float32)
        self._emit = jnp.asarray(emit / cfg.temperature)
        self._batch = jax.jit(self._make_batch)

    def _make_batch(self, step):
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.key(cfg.seed), step)
        k0, kscan = jax.random.split(key)
        state0 = jax.random.randint(k0, (cfg.global_batch,), 0,
                                    cfg.n_states)

        def walk(state, k):
            ks, ke = jax.random.split(k)
            tok = jax.random.categorical(ke, self._emit[state], axis=-1)
            nxt = jax.random.categorical(ks, self._trans[state], axis=-1)
            return nxt, tok

        keys = jax.random.split(kscan, cfg.seq_len + 1)
        _, toks = jax.lax.scan(walk, state0, keys)
        toks = jnp.moveaxis(toks, 0, 1)                 # (B, L+1)
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}

    def batch(self, step: int) -> dict:
        """Global batch for ``step`` — pure function of (seed, step)."""
        return self._batch(jnp.asarray(step, jnp.int32))

    @staticmethod
    def shard(batch: dict, index: int, count: int) -> dict:
        """Slice one data-parallel shard out of the global batch."""
        def sl(x):
            per = x.shape[0] // count
            return x[index * per:(index + 1) * per]
        return jax.tree.map(sl, batch)


class SyntheticClassification:
    """Gaussian prototypes + noise; the paper's classification stand-in."""

    def __init__(self, *, n_classes: int, dim: int, global_batch: int,
                 seed: int = 0, noise: float = 1.0):
        self.n_classes, self.dim = n_classes, dim
        self.global_batch, self.seed, self.noise = global_batch, seed, noise
        rng = np.random.default_rng(seed)
        self._protos = jnp.asarray(
            rng.normal(size=(n_classes, dim)).astype(np.float32))
        self._batch = jax.jit(self._make_batch)

    def _make_batch(self, step):
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        kc, kn = jax.random.split(key)
        y = jax.random.randint(kc, (self.global_batch,), 0, self.n_classes)
        x = self._protos[y] + self.noise * jax.random.normal(
            kn, (self.global_batch, self.dim))
        return {"x": x, "y": y}

    def batch(self, step: int) -> dict:
        return self._batch(jnp.asarray(step, jnp.int32))


def markov_batch(cfg: DataConfig, step: int) -> dict:
    """One-shot convenience (constructs the stream each call)."""
    return SyntheticLM(cfg).batch(step)
