from .synthetic import (SyntheticLM, SyntheticClassification, markov_batch,
                        DataConfig)
