"""Multi-tenant fleet: per-plan engines behind one host budget.

    fleet.json -> FleetManifest -> FleetRegistry (priced tenants)
               -> FleetRouter (plan-tagged admission, weighted RR)
               -> FleetTelemetry (per-tenant tok/s, occupancy, rejects)

See README.md in this directory for the subsystem design and
``repro.launch.serve --fleet`` for the CLI entry point.
"""
from .registry import (FleetBudgetError, FleetManifest, FleetRegistry,
                       Tenant, TenantSpec, load_manifest)
from .router import FleetAdmissionError, FleetRouter, build_fleet
from .telemetry import FleetTelemetry, TenantStats

__all__ = [
    "FleetBudgetError", "FleetManifest", "FleetRegistry", "Tenant",
    "TenantSpec", "load_manifest",
    "FleetAdmissionError", "FleetRouter", "build_fleet",
    "FleetTelemetry", "TenantStats",
]
