"""Per-tenant serving telemetry: tok/s, latency percentiles, occupancy.

The router feeds events in (`note_*`); consumers pull JSON-able
snapshots out.  Rates are computed over the wall-clock window between
the first and the most recent observed decode step, so warmup before
traffic starts does not dilute tok/s; a degenerate window (single decode
step, or a frozen injected clock) falls back to a minimum window
(``min_window_s``) instead of reporting 0 tok/s for a tenant that
demonstrably emitted tokens.

When constructed with a :class:`repro.obs.Observability` whose metrics
the serving schedulers also record into, ``snapshot()`` additionally
reports per-tenant TTFT and inter-token-latency p50/p95 pulled from the
``serve_ttft_ms{tenant=...}`` / ``serve_itl_ms{tenant=...}`` histograms
— the latency targets the ROADMAP's SLO scheduling direction routes on.
"""
from __future__ import annotations

import dataclasses
import json
import time


@dataclasses.dataclass
class TenantStats:
    """Mutable event counters for one tenant."""
    submitted: int = 0
    rejected: int = 0            # admission-quota rejections
    completed: int = 0
    tokens: int = 0              # decode tokens emitted
    steps: int = 0               # decode steps this tenant was scheduled
    preemptions: int = 0
    rejected_tokens: int = 0     # speculative drafts the verifier refused
    #                              (cache rolled back in place — distinct
    #                              from preemptions, which re-queue a slot)
    occupancy_sum: float = 0.0   # summed per-step pool occupancy
    occupancy_peak: float = 0.0
    first_step_t: float | None = None
    last_step_t: float | None = None

    def tok_per_s(self, min_window_s: float = 0.0) -> float:
        """Tokens over the observed step window.  A tenant whose first
        and last step coincide (one decode step, or a frozen injected
        clock) still emitted its tokens — count them over the
        ``min_window_s`` floor rather than reporting a rate of zero."""
        if self.first_step_t is None or self.last_step_t is None:
            return 0.0
        dt = max(self.last_step_t - self.first_step_t, min_window_s)
        return self.tokens / dt if dt > 0 else 0.0

    def occupancy_mean(self) -> float:
        return self.occupancy_sum / self.steps if self.steps else 0.0

    def snapshot(self, min_window_s: float = 0.0) -> dict:
        return {"submitted": self.submitted, "rejected": self.rejected,
                "completed": self.completed, "tokens": self.tokens,
                "steps": self.steps, "preemptions": self.preemptions,
                "rejected_tokens": self.rejected_tokens,
                "tok_per_s": round(self.tok_per_s(min_window_s), 3),
                "occupancy_mean": round(self.occupancy_mean(), 4),
                "occupancy_peak": round(self.occupancy_peak, 4)}


class FleetTelemetry:
    """Aggregates :class:`TenantStats` across the fleet.

    ``clock`` is injectable for deterministic tests.  ``obs`` (a
    :class:`repro.obs.Observability` shared with the schedulers) lets
    snapshots report per-tenant TTFT/ITL percentiles.  ``min_window_s``
    floors the tok/s rate window (degenerate single-step windows).
    """

    def __init__(self, clock=time.perf_counter, *, obs=None,
                 min_window_s: float = 1e-6):
        self._clock = clock
        self.obs = obs
        self.min_window_s = min_window_s
        self.per_tenant: dict[str, TenantStats] = {}
        # optional judgment layers (launch/serve wires them): an
        # obs.slo.SLOTracker and an obs.health.HealthMonitor whose
        # per-tenant summaries ride along in snapshot()
        self.slo = None
        self.health = None

    def _stats(self, tenant_id: str) -> TenantStats:
        return self.per_tenant.setdefault(tenant_id, TenantStats())

    def register(self, tenant_id: str):
        """Create the tenant's (zeroed) stats row so snapshots carry a
        uniform schema even for tenants that never saw traffic."""
        self._stats(tenant_id)

    # -------------------------------------------------------------- events
    def note_submit(self, tenant_id: str):
        self._stats(tenant_id).submitted += 1

    def note_reject(self, tenant_id: str):
        self._stats(tenant_id).rejected += 1

    def note_token(self, tenant_id: str):
        self._stats(tenant_id).tokens += 1

    def note_complete(self, tenant_id: str, n_preemptions: int = 0,
                      rejected_tokens: int = 0):
        s = self._stats(tenant_id)
        s.completed += 1
        s.preemptions += n_preemptions
        s.rejected_tokens += rejected_tokens

    def note_step(self, tenant_id: str, occupancy: float):
        s = self._stats(tenant_id)
        now = self._clock()
        if s.first_step_t is None:
            s.first_step_t = now
        s.last_step_t = now
        s.steps += 1
        s.occupancy_sum += occupancy
        s.occupancy_peak = max(s.occupancy_peak, occupancy)

    def _latency_percentiles(self, tenant_id: str) -> dict:
        """Per-tenant TTFT/ITL p50/p95 from the shared obs histograms;
        empty when no obs is wired or nothing was recorded."""
        if self.obs is None or not getattr(self.obs, "enabled", False):
            return {}
        out = {}
        for key, name in (("ttft_ms", "serve_ttft_ms"),
                          ("itl_ms", "serve_itl_ms")):
            h = self.obs.metrics.find(name, tenant=tenant_id)
            if h is not None and h.count:
                out[key] = {"p50": round(h.percentile(50), 3),
                            "p95": round(h.percentile(95), 3)}
        return out

    # ----------------------------------------------------------- snapshots
    def snapshot(self) -> dict:
        per = {}
        for tid, s in self.per_tenant.items():
            per[tid] = s.snapshot(self.min_window_s)
            per[tid].update(self._latency_percentiles(tid))
            if self.slo is not None:
                slo = self.slo.tenant_summary(tid)
                if slo:
                    per[tid]["slo"] = slo
            if self.health is not None:
                h = self.health.tenant_summary(tid)
                if h is not None:
                    per[tid]["health"] = h
        # aggregate tok/s is host tokens over the union step window —
        # NOT the sum of per-tenant rates, whose windows overlap
        firsts = [s.first_step_t for s in self.per_tenant.values()
                  if s.first_step_t is not None]
        lasts = [s.last_step_t for s in self.per_tenant.values()
                 if s.last_step_t is not None]
        tokens = sum(s["tokens"] for s in per.values())
        window = (max(lasts) - min(firsts)) if firsts else 0.0
        if firsts and tokens:
            window = max(window, self.min_window_s)
        return {"tenants": per,
                "aggregate": {
                    "submitted": sum(s["submitted"] for s in per.values()),
                    "rejected": sum(s["rejected"] for s in per.values()),
                    "completed": sum(s["completed"] for s in per.values()),
                    "tokens": tokens,
                    "steps": sum(s["steps"] for s in per.values()),
                    "preemptions": sum(s["preemptions"]
                                       for s in per.values()),
                    "rejected_tokens": sum(s["rejected_tokens"]
                                           for s in per.values()),
                    "tok_per_s": round(tokens / window, 3)
                    if window > 0 else 0.0}}

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent)

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())
