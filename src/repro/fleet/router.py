"""Plan-tagged admission + weighted round-robin stepping across engines.

The router is the single front door of a multi-tenant host:

* ``submit(tenant_id, prompt, ...)`` admits a request into its tenant's
  scheduler, tagged so the eventual :class:`~repro.serve.Completion`
  reports the tenant; per-tenant ``max_queued`` quotas reject (rather
  than unboundedly queue) traffic bursts with
  :class:`FleetAdmissionError`.
* ``step()`` advances exactly one tenant's engine by one decode step,
  chosen by smooth weighted round-robin over the tenants that currently
  have work — a tenant with ``weight=3`` gets ~3x the decode steps of a
  ``weight=1`` tenant under saturation, and idle tenants never waste a
  step.

Each tenant's engine/pool/scheduler is fully private (built by the
:class:`~repro.fleet.registry.FleetRegistry` under the shared byte
budget), so interleaving tenants at step granularity cannot perturb a
tenant's greedy decode: per-tenant outputs match the tenant's solo
engine token-for-token (asserted in ``benchmarks/fleet_throughput.py``
and ``tests/test_fleet.py``).
"""
from __future__ import annotations

from repro.fleet.registry import FleetManifest, FleetRegistry, load_manifest
from repro.fleet.telemetry import FleetTelemetry


class FleetAdmissionError(RuntimeError):
    """Request rejected at the router (unknown tenant or quota)."""


class FleetRouter:
    """Routes plan-tagged requests across the registry's engines."""

    def __init__(self, registry: FleetRegistry, *,
                 telemetry: FleetTelemetry | None = None, obs=None,
                 on_token=None, on_complete=None):
        from repro.obs import NOOP
        self.registry = registry
        # one Observability spans every tenant: request lanes carry the
        # tenant tag, engine-lane spans interleave in submission order
        # (the router steps one tenant at a time), and FleetTelemetry
        # reads per-tenant TTFT/ITL percentiles from the shared registry
        self.obs = obs or NOOP
        self.telemetry = telemetry or FleetTelemetry(obs=self.obs)
        self.on_token, self.on_complete = on_token, on_complete
        self._credit = {t.tenant_id: 0 for t in registry}
        for tenant in registry:
            self._wire(tenant)

    def _wire(self, tenant):
        tid = tenant.tenant_id
        self.telemetry.register(tid)   # uniform snapshot schema when idle
        tenant.scheduler.obs = self.obs
        tenant.engine.obs = self.obs
        tenant.pool.obs = self.obs
        tenant.engine.report_attention_mode(self.obs)
        if self.obs.enabled:
            self.obs.tracer.name_thread(0, "engine")

        def tok(rid, token, _tid=tid):
            self.telemetry.note_token(_tid)
            if self.on_token:
                self.on_token(_tid, rid, token)

        def done(completion, _tid=tid):
            self.telemetry.note_complete(_tid, completion.n_preemptions,
                                         completion.rejected_tokens)
            if self.on_complete:
                self.on_complete(completion)

        tenant.scheduler.on_token = tok
        tenant.scheduler.on_complete = done

    # -------------------------------------------------------------- submit
    def submit(self, tenant_id: str, prompt, *, max_new_tokens: int = 16,
               priority: int = 0, on_token=None) -> int:
        """Admit a request for ``tenant_id``; returns its per-tenant rid.

        Raises :class:`FleetAdmissionError` for unknown tenants and when
        the tenant's ``max_queued`` admission quota is full; scheduler-
        level validation errors (impossible requests) propagate as
        ``ValueError``.
        """
        if tenant_id not in self.registry.tenants:
            raise FleetAdmissionError(
                f"unknown tenant {tenant_id!r}; registered: "
                f"{sorted(self.registry.tenants)}")
        tenant = self.registry[tenant_id]
        quota = tenant.spec.max_queued
        if quota is not None and \
                len(tenant.scheduler.queued_requests()) >= quota:
            self.telemetry.note_reject(tenant_id)
            raise FleetAdmissionError(
                f"tenant {tenant_id!r} admission queue is full "
                f"({quota} queued); retry after completions")
        rid = tenant.scheduler.submit(
            prompt, max_new_tokens=max_new_tokens, priority=priority,
            on_token=on_token, tenant=tenant_id)
        self.telemetry.note_submit(tenant_id)
        return rid

    # ---------------------------------------------------------------- step
    @property
    def has_work(self) -> bool:
        return any(t.scheduler.has_work for t in self.registry)

    def _pick(self, eligible) -> str:
        """Smooth weighted round-robin among tenants with work."""
        total = sum(t.spec.weight for t in eligible)
        best = None
        for t in eligible:
            self._credit[t.tenant_id] += t.spec.weight
            if best is None or \
                    self._credit[t.tenant_id] > self._credit[best]:
                best = t.tenant_id
        self._credit[best] -= total
        return best

    def step(self):
        """Advance one tenant one decode step.  Returns ``(tenant_id,
        completions)``, or ``None`` when no tenant has work."""
        eligible = [t for t in self.registry if t.scheduler.has_work]
        if not eligible:
            return None
        tid = self._pick(eligible)
        tenant = self.registry[tid]
        completions = tenant.scheduler.step()
        self.telemetry.note_step(tid, tenant.pool.occupancy())
        return tid, completions

    def drain(self, max_steps: int | None = None) -> dict:
        """Run until every tenant is quiescent.  Returns
        ``{tenant_id: {rid: generated tokens}}``."""
        steps = 0
        while self.has_work:
            self.step()
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError("fleet drain exceeded max_steps")
        return {t.tenant_id: t.scheduler.outputs()
                for t in self.registry}

    # ---------------------------------------------------------------- misc
    def reset_telemetry(self, telemetry: FleetTelemetry | None = None
                        ) -> FleetTelemetry:
        """Swap in fresh telemetry (e.g. per benchmark cell) and re-wire
        every tenant's callbacks onto it."""
        self.telemetry = telemetry or FleetTelemetry(obs=self.obs)
        for tenant in self.registry:
            self._wire(tenant)
        return self.telemetry

    def output(self, tenant_id: str, rid: int) -> list[int]:
        return list(self.registry[tenant_id].scheduler.request(rid)
                    .generated)

    def stats(self) -> dict:
        s = self.telemetry.snapshot()
        s["budget_mb"] = self.registry.budget_mb
        s["used_mb"] = round(self.registry.total_bytes() / 2**20, 4)
        for t in self.registry:
            live = t.scheduler.stats()
            s["tenants"].setdefault(t.tenant_id, {}).update(
                active=live["active"], queued=live["queued"],
                pool_occupancy=live["pool_occupancy"],
                attention_mode=t.engine.attention_mode,
                bytes={"weights": t.weight_bytes, "pool": t.pool_bytes})
        return s


# ---------------------------------------------------------------------------
# manifest -> running fleet
# ---------------------------------------------------------------------------

def build_fleet(manifest: FleetManifest | str, model_cfg, params, *,
                budget_mb: float | None = None, backend: str = "auto",
                seed: int = 0, telemetry: FleetTelemetry | None = None,
                obs=None, on_token=None, on_complete=None,
                fused_attention: bool = False) -> FleetRouter:
    """Build registry + router from a manifest (path or parsed).

    ``budget_mb`` overrides the manifest's budget when given.  Raises
    :class:`~repro.fleet.registry.FleetBudgetError` if the tenants do
    not fit the shared host budget.  ``obs`` threads one
    :class:`repro.obs.Observability` through every tenant's serving
    stack.
    """
    if isinstance(manifest, str):
        manifest = load_manifest(manifest)
    budget = budget_mb if budget_mb is not None else manifest.budget_mb
    registry = FleetRegistry(model_cfg, params, budget_mb=budget,
                             backend=backend, seed=seed,
                             fused_attention=fused_attention)
    for spec in manifest.tenants:
        registry.register(spec)
    return FleetRouter(registry, telemetry=telemetry, obs=obs,
                       on_token=on_token, on_complete=on_complete)
