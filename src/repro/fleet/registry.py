"""Tenant table for the multi-tenant fleet: spec -> priced, built tenant.

A *tenant* is one quantization configuration of the shared base model —
a mixed-precision :class:`~repro.plan.QuantPlan` or a uniform scheme —
served by its own :class:`~repro.serve.PagedEngine` + page pool +
scheduler.  The registry owns the tenant table and the **shared host
budget**: before an engine is ever built, each tenant is priced with the
planner's cost model (``plan/costmodel.py`` for resident weight bytes,
``serve/pool.py::pool_nbytes`` for the page pool) and registration fails
with :class:`FleetBudgetError` when the aggregate would exceed
``budget_mb``.  That makes an over-budget ``fleet.json`` manifest a hard
error at load time, not an OOM at serve time.

Pricing convention matches ``repro.launch.plan --budget-mb``: weight
bytes cover the dense decoder stack in the packed wire format (norms /
embeddings / lm_head stay fp and are outside the budget, exactly as in
the planner's search); pool bytes are the exact resident bytes of the
tenant's paged KV pool.
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.core import schemes
from repro.models import transformer
from repro.plan import QuantPlan, leaf_key_bytes, plan_cost
from repro.plan.plan import fit_group_size, fit_kv_group
from repro.serve.engine import EngineConfig, PagedConfig, PagedEngine
from repro.serve.pool import pool_nbytes
from repro.serve.scheduler import Scheduler


class FleetBudgetError(ValueError):
    """Registering this tenant would exceed the shared host budget."""


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One manifest row: who the tenant is and what it costs.

    Exactly one of ``plan`` / ``scheme`` may be set (both ``None`` serves
    fp weights).  ``weight`` is the tenant's share in the router's
    weighted round-robin; ``max_queued`` bounds its admission queue
    (``None`` = unbounded).  The remaining fields are the tenant's pool
    geometry and sampling configuration.
    """
    tenant_id: str
    plan: QuantPlan | None = None       # mixed precision per-layer plan
    scheme: str | None = None           # uniform weight scheme, e.g. "lq4w"
    a_bits: int | None = None           # runtime activation quantization
    kv_bits: int | None = None          # paged-pool wire format
    kv_group: int = 64
    weight: int = 1                     # weighted round-robin share
    max_queued: int | None = None       # admission quota (queued requests)
    max_slots: int = 4
    page_size: int = 16
    n_pages: int = 64
    max_context: int = 256
    temperature: float = 0.0
    top_k: int | None = None
    slo: object = None                  # TenantSLO targets (obs/slo.py)

    def __post_init__(self):
        if not self.tenant_id:
            raise ValueError("tenant_id must be non-empty")
        if self.plan is not None and self.scheme is not None:
            raise ValueError(f"{self.tenant_id}: pass either a plan or a "
                             f"uniform scheme, not both")
        if self.plan is not None and self.a_bits is not None:
            raise ValueError(f"{self.tenant_id}: a_bits is per-layer under "
                             f"a plan")
        if self.plan is not None and self.plan.has_kv \
                and self.kv_bits is not None:
            raise ValueError(f"{self.tenant_id}: kv_bits is per-layer under "
                             f"a plan with a kv map")
        if self.weight < 1:
            raise ValueError(f"{self.tenant_id}: weight must be >= 1")
        if self.max_queued is not None and self.max_queued < 1:
            raise ValueError(f"{self.tenant_id}: max_queued must be >= 1")

    # ------------------------------------------------------------ derived
    def resolved_plan(self, model_cfg) -> QuantPlan:
        """The tenant's plan with local-region sizes fitted to the model.

        Uniform schemes become the trivial plan (with ``a_bits`` folded
        in); fp tenants are the all-fp32 plan.  Region fitting mirrors
        the planner's ``candidates_for`` so registry pricing and the
        built engine agree with ``launch.plan`` budgets.
        """
        base = self.plan
        if base is None:
            default = schemes.get(self.scheme or "fp32")
            if self.a_bits is not None:
                default = dataclasses.replace(default, a_bits=self.a_bits)
            base = QuantPlan(default=default)
        return QuantPlan(
            assignments=tuple((n, fit_group_size(c, model_cfg))
                              for n, c in base.assignments),
            default=fit_group_size(base.default, model_cfg),
            meta=base.meta,
            kv_bits=base.kv_bits, kv_default=base.kv_default,
            kv_group=fit_kv_group(base.kv_group, model_cfg.head_dim))

    def pool_kv(self, model_cfg) -> tuple:
        """``(kv_bits, kv_group)`` of the tenant's page pool — the plan's
        per-layer map when it carries one (heterogeneous geometry),
        else the spec's uniform setting."""
        rp = self.resolved_plan(model_cfg)
        if rp.has_kv:
            return rp.resolve_kv(model_cfg), rp.kv_group
        return self.kv_bits, self.kv_group

    def engine_config(self, model_cfg) -> EngineConfig:
        if self.plan is None and self.scheme is None:
            return EngineConfig(max_len=self.max_context,
                                kv_bits=self.kv_bits, kv_group=self.kv_group,
                                a_bits=self.a_bits,
                                temperature=self.temperature,
                                top_k=self.top_k)
        return EngineConfig(max_len=self.max_context, kv_bits=self.kv_bits,
                            kv_group=self.kv_group,
                            plan=self.resolved_plan(model_cfg),
                            temperature=self.temperature, top_k=self.top_k)

    def paged_config(self) -> PagedConfig:
        return PagedConfig(max_slots=self.max_slots,
                           page_size=self.page_size, n_pages=self.n_pages,
                           max_context=self.max_context)

    # ----------------------------------------------------------- manifest
    @staticmethod
    def from_manifest(obj: dict, base_dir: str = ".") -> "TenantSpec":
        """One ``fleet.json`` tenant entry -> spec.  ``plan`` is a path to
        a QuantPlan JSON, resolved relative to the manifest file."""
        obj = dict(obj)
        plan_path = obj.pop("plan", None)
        plan = None
        if plan_path is not None:
            if not os.path.isabs(plan_path):
                plan_path = os.path.join(base_dir, plan_path)
            plan = QuantPlan.load(plan_path)
        tid = obj.pop("id", None) or obj.pop("tenant_id", None)
        if tid is None:
            raise ValueError("manifest tenant entry needs an 'id'")
        slo_obj = obj.pop("slo", None)
        slo = None
        if slo_obj is not None:
            from repro.obs.slo import TenantSLO     # lazy: obs <- fleet
            slo = TenantSLO.from_obj(slo_obj)
        return TenantSpec(tenant_id=tid, plan=plan, slo=slo, **obj)


@dataclasses.dataclass
class Tenant:
    """A registered tenant: its spec plus the built serving stack."""
    spec: TenantSpec
    engine: PagedEngine
    pool: object                  # PagedKVPool
    scheduler: Scheduler
    weight_bytes: float           # incremental wire-format weight residency
    pool_bytes: int               # exact paged-pool residency
    shared_bytes: float = 0.0     # packed leaves re-used from earlier
    #                               tenants (priced once, registry dedup)

    @property
    def tenant_id(self) -> str:
        return self.spec.tenant_id

    @property
    def total_bytes(self) -> float:
        return self.weight_bytes + self.pool_bytes


class FleetRegistry:
    """Tenant table + shared host-budget accounting.

    All tenants serve the same base ``(model_cfg, params)``; each
    registration quantizes its own copy of the weights per its plan.
    """

    def __init__(self, model_cfg, params, *, budget_mb: float | None = None,
                 backend: str = "auto", seed: int = 0,
                 share_weights: bool = True, fused_attention: bool = False):
        self.model_cfg, self.params = model_cfg, params
        self.budget_mb = budget_mb
        self.backend = backend
        self.seed = seed
        self.share_weights = share_weights
        # host-level, like backend: every tenant's decode runs the fused
        # paged-attention kernel (manifests describe tenants, not hosts)
        self.fused_attention = fused_attention
        self.tenants: dict[str, Tenant] = {}
        # packed-leaf dedup across tenants of the one shared checkpoint:
        # quantize_params segment subtrees keyed on (range, position,
        # QuantConfig) — identical leaves are materialized and PRICED once
        # (the registry is per-(arch, base params), completing the key)
        self._leaf_cache: dict = {}

    # ------------------------------------------------------------ pricing
    def _plan_keys(self, spec: TenantSpec) -> list:
        return transformer.plan_leaf_keys(
            self.model_cfg, spec.resolved_plan(self.model_cfg))

    def shared_bytes(self, spec: TenantSpec) -> float:
        """Wire bytes of the spec's packed leaves already resident via an
        earlier tenant (0 when sharing is off or the tenant serves raw fp
        params)."""
        if not self.share_weights or (spec.plan is None
                                      and spec.scheme is None):
            return 0.0
        return sum(leaf_key_bytes(self.model_cfg, k)
                   for k in self._plan_keys(spec) if k in self._leaf_cache)

    def price(self, spec: TenantSpec, *, with_sharing: bool = False) -> dict:
        """Cost-model bytes for a spec, without building anything.

        Pool bytes honor a plan's per-layer kv map: a mixed-KV tenant is
        priced with its exact heterogeneous page geometry (eval_shape over
        the real pytree), so dropping deep layers to 2-bit cache frees
        real budget headroom instead of being billed at the widest layer.

        ``with_sharing`` discounts packed leaves the registry already
        holds (cross-tenant dedup): ``weight_bytes`` becomes the tenant's
        *incremental* residency and ``shared_bytes`` reports the re-used
        wire bytes — registration charges the budget this way.
        """
        wb = plan_cost(self.model_cfg, spec.resolved_plan(self.model_cfg)
                       .resolve(self.model_cfg))["bytes"]
        kv_bits, kv_group = spec.pool_kv(self.model_cfg)
        pb = pool_nbytes(self.model_cfg, n_pages=spec.n_pages,
                         page_size=spec.page_size, kv_bits=kv_bits,
                         kv_group=kv_group)
        out = {"weight_bytes": wb, "pool_bytes": pb, "total": wb + pb}
        if with_sharing:
            sh = self.shared_bytes(spec)
            out["shared_bytes"] = sh
            out["weight_bytes"] = wb - sh
            out["total"] = wb - sh + pb
        return out

    @property
    def budget_bytes(self) -> float | None:
        return None if self.budget_mb is None else self.budget_mb * 2**20

    def total_bytes(self) -> float:
        return sum(t.total_bytes for t in self.tenants.values())

    def remaining_bytes(self) -> float:
        if self.budget_bytes is None:
            return float("inf")
        return self.budget_bytes - self.total_bytes()

    # ----------------------------------------------------------- register
    def register(self, spec: TenantSpec) -> Tenant:
        """Price, budget-check, then build the tenant's serving stack.
        Token/completion callbacks are the router's to wire
        (:meth:`FleetRouter._wire` owns the scheduler hooks)."""
        if spec.tenant_id in self.tenants:
            raise ValueError(f"duplicate tenant id {spec.tenant_id!r}")
        priced = self.price(spec, with_sharing=True)
        if priced["total"] > self.remaining_bytes():
            raise FleetBudgetError(
                f"tenant {spec.tenant_id!r} needs "
                f"{priced['total'] / 2**20:.3f} MiB "
                f"(weights {priced['weight_bytes'] / 2**20:.3f} + pool "
                f"{priced['pool_bytes'] / 2**20:.3f}, after "
                f"{priced.get('shared_bytes', 0.0) / 2**20:.3f} shared) "
                f"but only {self.remaining_bytes() / 2**20:.3f} MiB of the "
                f"{self.budget_mb:.3f} MiB host budget remain")
        ecfg = dataclasses.replace(spec.engine_config(self.model_cfg),
                                   backend=self.backend,
                                   fused_attention=self.fused_attention)
        build_params = self.params
        if self.share_weights and ecfg.plan is not None:
            # pre-pack through the registry's leaf cache: segments another
            # tenant already packed come back as the SAME device buffers
            build_params = transformer.quantize_params(
                self.params, self.model_cfg, ecfg.plan,
                leaf_cache=self._leaf_cache)
        engine = PagedEngine(self.model_cfg, build_params, ecfg,
                             spec.paged_config())
        pool = engine.new_pool()
        sched = Scheduler(engine, pool,
                          seed=self.seed + len(self.tenants))
        tenant = Tenant(spec=spec, engine=engine, pool=pool, scheduler=sched,
                        weight_bytes=priced["weight_bytes"],
                        pool_bytes=priced["pool_bytes"],
                        shared_bytes=priced.get("shared_bytes", 0.0))
        self.tenants[spec.tenant_id] = tenant
        return tenant

    def __getitem__(self, tenant_id: str) -> Tenant:
        return self.tenants[tenant_id]

    def __iter__(self):
        return iter(self.tenants.values())

    def __len__(self) -> int:
        return len(self.tenants)

    # ------------------------------------------------------------ summary
    def describe(self) -> str:
        lines = [f"FleetRegistry({len(self)} tenants, budget "
                 f"{self.budget_mb} MiB, "
                 f"used {self.total_bytes() / 2**20:.3f} MiB)"]
        for t in self:
            shared = (f" (+{t.shared_bytes / 2**20:.3f} shared)"
                      if t.shared_bytes else "")
            lines.append(
                f"  {t.tenant_id:>12}: weight={t.spec.weight} "
                f"wire {t.weight_bytes / 2**20:.3f} MiB{shared} + pool "
                f"{t.pool_bytes / 2**20:.3f} MiB "
                f"(kv_bits={t.spec.kv_bits}, slots={t.spec.max_slots}, "
                f"pages={t.spec.n_pages}x{t.spec.page_size})")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# fleet.json manifest
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetManifest:
    """Parsed ``fleet.json``: the shared arch/budget plus tenant specs.

    ``slo`` is the manifest's assembled :class:`repro.obs.slo.SLOSpec`
    (top-level ``slo:`` section merged with per-tenant inline ``slo:``
    rows), or ``None`` when the manifest declares no objectives.
    """
    arch: str
    tenants: tuple
    budget_mb: float | None = None
    slo: object = None

    def __post_init__(self):
        ids = [t.tenant_id for t in self.tenants]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate tenant ids in manifest: {ids}")
        if not self.tenants:
            raise ValueError("manifest lists no tenants")


def load_manifest(path: str) -> FleetManifest:
    with open(path) as f:
        obj = json.load(f)
    base = os.path.dirname(os.path.abspath(path))
    tenants = tuple(TenantSpec.from_manifest(t, base)
                    for t in obj.get("tenants", []))
    slo_obj = obj.get("slo")
    inline = tuple((t.tenant_id, t.slo) for t in tenants
                   if t.slo is not None)
    slo = None
    if slo_obj is not None or inline:
        from repro.obs.slo import SLOSpec           # lazy: obs <- fleet
        slo = SLOSpec.from_obj(slo_obj or {}, extra_tenants=inline)
    return FleetManifest(arch=obj["arch"], tenants=tenants,
                         budget_mb=obj.get("budget_mb"), slo=slo)
