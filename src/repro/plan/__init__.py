"""Mixed-precision planner: per-layer bitwidth search under a device budget.

    profile -> search -> QuantPlan -> models/serve

See README.md in this directory for the subsystem design and the
``repro.launch.plan`` CLI walkthrough.
"""
from .plan import QuantPlan, fit_kv_group, layer_name
from .costmodel import (LayerCost, candidate_costs, kv_bits_of_label,
                        kv_candidate_costs, kv_label, kv_layer_options,
                        kv_searchable, layer_cost, layer_dense_params,
                        layer_kv_bytes_per_token, leaf_key_bytes, plan_cost,
                        plan_kv_cost, weight_bytes)
from .sensitivity import (SensitivityProfile, layer_output_ranges,
                          profile_kv_sensitivity, profile_sensitivity)
from .search import (SearchResult, greedy_search, joint_space,
                     pareto_frontier, split_joint_assignment,
                     uniform_result)

__all__ = [
    "QuantPlan", "fit_kv_group", "layer_name",
    "LayerCost", "candidate_costs", "layer_cost", "layer_dense_params",
    "plan_cost", "weight_bytes",
    "kv_label", "kv_bits_of_label", "kv_candidate_costs",
    "kv_layer_options", "kv_searchable",
    "layer_kv_bytes_per_token", "leaf_key_bytes", "plan_kv_cost",
    "SensitivityProfile", "layer_output_ranges", "profile_sensitivity",
    "profile_kv_sensitivity",
    "SearchResult", "greedy_search", "joint_space",
    "split_joint_assignment", "pareto_frontier", "uniform_result",
]
