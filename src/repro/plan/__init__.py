"""Mixed-precision planner: per-layer bitwidth search under a device budget.

    profile -> search -> QuantPlan -> models/serve

See README.md in this directory for the subsystem design and the
``repro.launch.plan`` CLI walkthrough.
"""
from .plan import QuantPlan, layer_name
from .costmodel import (LayerCost, candidate_costs, layer_cost,
                        layer_dense_params, plan_cost, weight_bytes)
from .sensitivity import (SensitivityProfile, layer_output_ranges,
                          profile_sensitivity)
from .search import (SearchResult, greedy_search, pareto_frontier,
                     uniform_result)

__all__ = [
    "QuantPlan", "layer_name",
    "LayerCost", "candidate_costs", "layer_cost", "layer_dense_params",
    "plan_cost", "weight_bytes",
    "SensitivityProfile", "layer_output_ranges", "profile_sensitivity",
    "SearchResult", "greedy_search", "pareto_frontier", "uniform_result",
]
