"""Per-layer deployment cost under a candidate scheme.

Three currencies, all per decoder layer:

  * **bytes**  — resident weight footprint in the packed wire format
    (codes + per-region affine), the ``--budget-mb`` constraint.  Matches
    :meth:`repro.kernels.ops.QWeight.nbytes` exactly:
    ``params * bits/8 + 2 * 4 * params/group_size``; fp layers count 4 B
    per weight (the fp32 master format, as in benchmarks/table45).
  * **op counts** — multiplies/adds per generated token using the paper's
    Table-3 accounting (``core/lut.py``): LUT layers pay one multiply per
    local region, everything else one multiply+add per MAC.
  * **ms** — modeled decode latency per token from the roofline constants
    (``roofline/HW``; the benchmarks/table45 deployment regime): decode
    streams every live weight once per token, so
    ``ms = max(weight_bytes / HBM_BW, 2*MACs / PEAK) * 1e3``.
    This is the ``--budget-ms`` constraint.

Per-layer MACs/params come from the :class:`ModelConfig` block pattern
(the same accounting as ``param_count()``), so the model is shape-generic
across attention / SSM / MoE / RG-LRU mixers.
"""
from __future__ import annotations

import dataclasses

from repro.core import lut
from repro.roofline import HW


@dataclasses.dataclass(frozen=True)
class LayerCost:
    bytes: float          # resident weight bytes in wire format
    macs: int             # dense MACs per generated token
    multiplies: float     # per token, paper Table-3 convention
    adds: float
    ms: float             # modeled decode latency per token

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def layer_dense_params(model_cfg) -> list:
    """Dense (quantizable) parameter count of each decoder layer.

    Norm/router/conv leaves stay fp and are excluded — they are the
    ``_EXCLUDE_KEYS`` of ``transformer.quantize_params``.
    """
    out = []
    for i in range(model_cfg.n_layers):
        mixer, ffn = model_cfg.layer_spec(i)
        out.append(model_cfg._mixer_params(mixer)
                   + model_cfg._ffn_params(ffn))
    return out


def weight_bytes(n_params: int, qcfg) -> float:
    """Wire-format bytes for ``n_params`` weights under ``qcfg``."""
    if qcfg.w_bits is None:
        return 4.0 * n_params
    return (n_params * qcfg.w_bits / 8.0
            + 2 * 4.0 * n_params / qcfg.group_size)


def leaf_key_bytes(model_cfg, key) -> float:
    """Wire bytes behind one ``transformer.plan_leaf_keys`` segment key.

    Prices the packed subtree a leaf-cache entry holds in the planner's
    byte currency (:func:`weight_bytes` per covered layer), so sharing a
    leaf across plans/tenants discounts exactly those bytes.
    """
    sizes = layer_dense_params(model_cfg)
    p_len = len(model_cfg.pattern)
    if key[0] == "super":
        _, start, size, j, qcfg = key
        return sum(weight_bytes(sizes[s * p_len + j], qcfg)
                   for s in range(start, start + size))
    _, t, qcfg = key
    n_super = model_cfg.n_layers // p_len
    return weight_bytes(sizes[n_super * p_len + t], qcfg)


def layer_cost(n_params: int, qcfg, hw: HW | None = None) -> LayerCost:
    hw = hw or HW()
    macs = n_params                       # decode: 1 MAC per live weight
    nbytes = weight_bytes(n_params, qcfg)
    if qcfg.lut and qcfg.a_bits is not None:
        ops = lut.lut_op_counts(macs, bits=qcfg.a_bits,
                                region_size=qcfg.group_size)
    else:
        ops = lut.original_op_counts(macs)
    compute_s = 2.0 * macs / hw.peak_flops
    memory_s = nbytes / hw.hbm_bw
    return LayerCost(bytes=nbytes, macs=macs,
                     multiplies=float(ops.multiplies),
                     adds=float(ops.adds),
                     ms=max(compute_s, memory_s) * 1e3)


# ---------------------------------------------------------------------------
# KV-cache pricing (the decode-time memory bottleneck the serve layer pays)
# ---------------------------------------------------------------------------

def kv_label(bits) -> str:
    """Canonical scheme name of a cache bitwidth (``"kvfp"``, ``"kv8"``...)."""
    return "kvfp" if bits is None else f"kv{bits}"


def kv_bits_of_label(label: str):
    if label == "kvfp":
        return None
    if label.startswith("kv"):
        return int(label[2:])
    raise ValueError(f"not a kv scheme label: {label!r}")


def layer_kv_bytes_per_token(model_cfg, i: int, bits,
                             kv_group: int = 64) -> float:
    """Exact cache wire bytes layer ``i`` appends per decoded token.

    Matches the paged pool's per-page bytes / page_size byte-for-byte
    (``kvwire.kv_token_nbytes``); attention layers grow by one K+V row per
    token, fixed-size recurrent states (mamba2 / rglru) cost nothing
    *per token* and price at zero here — their residency is the pool /
    contiguous-cache accounting's job.
    """
    from repro.core import kvwire
    mixer, _ = model_cfg.layer_spec(i)
    if not mixer.startswith("attn"):
        return 0.0
    return kvwire.kv_token_nbytes(
        model_cfg.n_kv_heads, model_cfg.head_dim, bits, kv_group,
        fp_itemsize=model_cfg.activation_dtype.itemsize)


def kv_searchable(model_cfg, i: int) -> bool:
    """Whether the kv search may assign cache bits to layer ``i``.

    Only attention layers: rglru has no quantizable cache at all, and
    mamba2's SSM state — while the engine can store it quantized — is
    invisible to both the per-token byte price (fixed-size state) and the
    kv fake-quant profiler, so the search must not silently deploy it.
    """
    mixer, _ = model_cfg.layer_spec(i)
    return mixer.startswith("attn")


def kv_layer_options(model_cfg, i: int, bits_options) -> list:
    """Layer ``i``'s candidate set: the full grid on attention layers,
    the fp cache alone everywhere else."""
    if kv_searchable(model_cfg, i):
        return list(bits_options)
    return [None]


def kv_candidate_costs(model_cfg, bits_options, *, kv_group: int = 64,
                       tokens: int = 1) -> dict:
    """``{layer_name: {kv_label: {"bytes", "bytes_per_token"}}}``.

    ``tokens`` scales per-token bytes into the search's byte currency —
    price a pool's worth of context (e.g. ``n_pages * page_size``) so kv
    bytes and weight bytes share one ``--budget-mb``.  Layers without a
    searchable cache (see :func:`kv_searchable`) get the fp option only.
    """
    from .plan import layer_name
    return {layer_name(i): {
        kv_label(b): {
            "bytes": tokens * layer_kv_bytes_per_token(model_cfg, i, b,
                                                       kv_group),
            "bytes_per_token": layer_kv_bytes_per_token(model_cfg, i, b,
                                                        kv_group)}
        for b in kv_layer_options(model_cfg, i, bits_options)}
        for i in range(model_cfg.n_layers)}


def plan_kv_cost(model_cfg, kv_list, *, kv_group: int = 64,
                 tokens: int = 1) -> dict:
    """Aggregate cache cost of a resolved per-layer kv bits tuple."""
    if len(kv_list) != model_cfg.n_layers:
        raise ValueError(f"{len(kv_list)} kv entries for "
                         f"{model_cfg.n_layers} layers")
    per = [layer_kv_bytes_per_token(model_cfg, i, b, kv_group)
           for i, b in enumerate(kv_list)]
    return {"bytes_per_token": sum(per),
            "bytes": tokens * sum(per),
            "per_layer": per}


def candidate_costs(model_cfg, candidates: dict,
                    hw: HW | None = None) -> dict:
    """``{layer_name: {scheme_name: LayerCost}}`` for every candidate.

    ``candidates``: ``{scheme_name: QuantConfig}``.
    """
    from .plan import layer_name
    sizes = layer_dense_params(model_cfg)
    return {layer_name(i): {s: layer_cost(n, c, hw)
                            for s, c in candidates.items()}
            for i, n in enumerate(sizes)}


def plan_cost(model_cfg, configs, hw: HW | None = None) -> dict:
    """Aggregate cost of a resolved per-layer config tuple."""
    sizes = layer_dense_params(model_cfg)
    if len(configs) != len(sizes):
        raise ValueError(f"{len(configs)} configs for {len(sizes)} layers")
    per = [layer_cost(n, c, hw) for n, c in zip(sizes, configs)]
    return {
        "bytes": sum(p.bytes for p in per),
        "mb": sum(p.bytes for p in per) / 2**20,
        "ms": sum(p.ms for p in per),
        "multiplies": sum(p.multiplies for p in per),
        "adds": sum(p.adds for p in per),
        "per_layer": [p.to_dict() for p in per],
    }
