"""Per-layer deployment cost under a candidate scheme.

Three currencies, all per decoder layer:

  * **bytes**  — resident weight footprint in the packed wire format
    (codes + per-region affine), the ``--budget-mb`` constraint.  Matches
    :meth:`repro.kernels.ops.QWeight.nbytes` exactly:
    ``params * bits/8 + 2 * 4 * params/group_size``; fp layers count 4 B
    per weight (the fp32 master format, as in benchmarks/table45).
  * **op counts** — multiplies/adds per generated token using the paper's
    Table-3 accounting (``core/lut.py``): LUT layers pay one multiply per
    local region, everything else one multiply+add per MAC.
  * **ms** — modeled decode latency per token from the roofline constants
    (``roofline/HW``; the benchmarks/table45 deployment regime): decode
    streams every live weight once per token, so
    ``ms = max(weight_bytes / HBM_BW, 2*MACs / PEAK) * 1e3``.
    This is the ``--budget-ms`` constraint.

Per-layer MACs/params come from the :class:`ModelConfig` block pattern
(the same accounting as ``param_count()``), so the model is shape-generic
across attention / SSM / MoE / RG-LRU mixers.
"""
from __future__ import annotations

import dataclasses

from repro.core import lut
from repro.roofline import HW


@dataclasses.dataclass(frozen=True)
class LayerCost:
    bytes: float          # resident weight bytes in wire format
    macs: int             # dense MACs per generated token
    multiplies: float     # per token, paper Table-3 convention
    adds: float
    ms: float             # modeled decode latency per token

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def layer_dense_params(model_cfg) -> list:
    """Dense (quantizable) parameter count of each decoder layer.

    Norm/router/conv leaves stay fp and are excluded — they are the
    ``_EXCLUDE_KEYS`` of ``transformer.quantize_params``.
    """
    out = []
    for i in range(model_cfg.n_layers):
        mixer, ffn = model_cfg.layer_spec(i)
        out.append(model_cfg._mixer_params(mixer)
                   + model_cfg._ffn_params(ffn))
    return out


def weight_bytes(n_params: int, qcfg) -> float:
    """Wire-format bytes for ``n_params`` weights under ``qcfg``."""
    if qcfg.w_bits is None:
        return 4.0 * n_params
    return (n_params * qcfg.w_bits / 8.0
            + 2 * 4.0 * n_params / qcfg.group_size)


def layer_cost(n_params: int, qcfg, hw: HW | None = None) -> LayerCost:
    hw = hw or HW()
    macs = n_params                       # decode: 1 MAC per live weight
    nbytes = weight_bytes(n_params, qcfg)
    if qcfg.lut and qcfg.a_bits is not None:
        ops = lut.lut_op_counts(macs, bits=qcfg.a_bits,
                                region_size=qcfg.group_size)
    else:
        ops = lut.original_op_counts(macs)
    compute_s = 2.0 * macs / hw.peak_flops
    memory_s = nbytes / hw.hbm_bw
    return LayerCost(bytes=nbytes, macs=macs,
                     multiplies=float(ops.multiplies),
                     adds=float(ops.adds),
                     ms=max(compute_s, memory_s) * 1e3)


def candidate_costs(model_cfg, candidates: dict,
                    hw: HW | None = None) -> dict:
    """``{layer_name: {scheme_name: LayerCost}}`` for every candidate.

    ``candidates``: ``{scheme_name: QuantConfig}``.
    """
    from .plan import layer_name
    sizes = layer_dense_params(model_cfg)
    return {layer_name(i): {s: layer_cost(n, c, hw)
                            for s, c in candidates.items()}
            for i, n in enumerate(sizes)}


def plan_cost(model_cfg, configs, hw: HW | None = None) -> dict:
    """Aggregate cost of a resolved per-layer config tuple."""
    sizes = layer_dense_params(model_cfg)
    if len(configs) != len(sizes):
        raise ValueError(f"{len(configs)} configs for {len(sizes)} layers")
    per = [layer_cost(n, c, hw) for n, c in zip(sizes, configs)]
    return {
        "bytes": sum(p.bytes for p in per),
        "mb": sum(p.bytes for p in per) / 2**20,
        "ms": sum(p.ms for p in per),
        "multiplies": sum(p.multiplies for p in per),
        "adds": sum(p.adds for p in per),
        "per_layer": [p.to_dict() for p in per],
    }
