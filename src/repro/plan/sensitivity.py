"""Per-layer quantization-sensitivity profiling over a calibration stream.

For each decoder layer ``i`` and each candidate scheme ``c`` the profiler
runs the model with fake-quant (STE numerics — exactly the rounding the
packed kernels apply) on layer ``i`` ONLY, everything else fp, and scores
the damage against the fp logits:

  * ``mse`` — mean squared logit error,
  * ``kl``  — mean KL(softmax(fp) || softmax(quantized)), the
    accuracy-proxy the search optimizes (standard mixed-precision
    sensitivity proxy, cf. 1808.04752 §V).

Each (layer, scheme) cell is one jitted forward per calibration batch —
L x C traces of the smoke-scale model, which is what the planner targets.

The profiler also records each layer's output activation range with the
``core/calibration.py`` observers (min/max, EMA or percentile over the
same stream): wide-range layers are exactly where low-bit local regions
clip, so the ranges ship in the profile for diagnosis and for freezing
LUT affine params offline.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import calibration, schemes
from repro.models import transformer
from repro.models.layers import NO_QUANT, PlanPolicy

from .plan import layer_name


@dataclasses.dataclass(frozen=True)
class SensitivityProfile:
    """``losses[layer_name][scheme_name] -> {"kl": ..., "mse": ...}`` plus
    per-layer calibrated output ranges."""
    losses: dict
    act_ranges: dict
    n_batches: int

    def loss(self, layer: str, scheme: str, metric: str = "kl") -> float:
        return self.losses[layer][scheme][metric]

    def to_dict(self) -> dict:
        return {"losses": self.losses, "act_ranges": self.act_ranges,
                "n_batches": self.n_batches}


def _metrics(fp_logits, q_logits) -> dict:
    fp = fp_logits.astype(jnp.float32)
    q = q_logits.astype(jnp.float32)
    mse = jnp.mean((fp - q) ** 2)
    p = jax.nn.softmax(fp, axis=-1)
    kl = jnp.sum(p * (jax.nn.log_softmax(fp, -1)
                      - jax.nn.log_softmax(q, -1)), axis=-1).mean()
    return {"mse": mse, "kl": kl}


def _one_hot_policy(n_layers: int, i: int, cand: schemes.QuantConfig,
                    mode: str = "qat") -> PlanPolicy:
    cfgs = tuple(cand if j == i else schemes.FP32 for j in range(n_layers))
    return PlanPolicy(mode, cfgs)


def profile_sensitivity(params, model_cfg, batches, candidates: dict,
                        *, observer: str = "minmax",
                        **observer_kw) -> SensitivityProfile:
    """Profile every (layer, candidate scheme) cell over ``batches``.

    ``batches``: list of forward-compatible batch dicts ({'tokens': ...});
    ``candidates``: ``{scheme_name: QuantConfig}``.
    """
    if model_cfg.n_enc_layers:
        raise ValueError("sensitivity profiling supports decoder-only "
                         "models (plans cover the decoder stack)")
    n = model_cfg.n_layers

    @jax.jit
    def fp_fn(p, b):
        return transformer.forward(p, model_cfg, b, policy=NO_QUANT,
                                   training=False)[0]

    fp_logits = [fp_fn(params, b) for b in batches]

    losses = {}
    for i in range(n):
        row = {}
        for sname, cand in candidates.items():
            pol = _one_hot_policy(n, i, cand)
            q_fn = jax.jit(lambda p, b: transformer.forward(
                p, model_cfg, b, policy=pol, training=False)[0])
            acc = {"mse": 0.0, "kl": 0.0}
            for b, fp in zip(batches, fp_logits):
                m = _metrics(fp, q_fn(params, b))
                acc = {k: acc[k] + float(v) for k, v in m.items()}
            row[sname] = {k: v / len(batches) for k, v in acc.items()}
        losses[layer_name(i)] = row

    ranges = layer_output_ranges(params, model_cfg, batches,
                                 kind=observer, **observer_kw)
    act_ranges = {layer_name(i): [float(lo), float(hi)]
                  for i, (lo, hi) in enumerate(ranges)}
    return SensitivityProfile(losses=losses, act_ranges=act_ranges,
                              n_batches=len(batches))


# ---------------------------------------------------------------------------
# KV-cache sensitivity: one-hot fake-quant of each layer's K/V stream
# ---------------------------------------------------------------------------

def profile_kv_sensitivity(params, model_cfg, batches, bits_options,
                           *, kv_group: int = 64) -> dict:
    """Per-layer cache-quantization damage over the calibration stream.

    For each decoder layer ``i`` and candidate cache bitwidth ``b`` the
    model runs with layer ``i``'s post-rope K/V rounded through the wire
    format (``QuantPolicy.kv_fq`` — exactly the grid the paged pool's
    scatter applies at decode), everything else fp, scored against the fp
    logits.  Returns ``{layer_name: {kv_label: {"kl", "mse"}}}`` keyed
    with :func:`repro.plan.costmodel.kv_label`; the fp option scores an
    exact 0.0 without a forward, and layers without a searchable cache
    (rglru, mamba2 — see :func:`repro.plan.costmodel.kv_searchable`)
    carry only that fp cell, mirroring ``kv_candidate_costs``.
    """
    from .costmodel import kv_label, kv_layer_options

    if model_cfg.n_enc_layers:
        raise ValueError("kv sensitivity profiling supports decoder-only "
                         "models (plans cover the decoder stack)")
    n = model_cfg.n_layers
    if model_cfg.head_dim % kv_group:
        raise ValueError(f"kv_group {kv_group} does not divide head_dim "
                         f"{model_cfg.head_dim}")

    @jax.jit
    def fp_fn(p, b):
        return transformer.forward(p, model_cfg, b, policy=NO_QUANT,
                                   training=False)[0]

    fp_logits = [fp_fn(params, b) for b in batches]
    fp_cfgs = (schemes.FP32,) * n

    losses = {}
    for i in range(n):
        row = {}
        for bits in kv_layer_options(model_cfg, i, bits_options):
            if bits is None:
                row[kv_label(bits)] = {"mse": 0.0, "kl": 0.0}
                continue
            kv = tuple(bits if j == i else None for j in range(n))
            pol = PlanPolicy("qat", fp_cfgs, kv_bits=kv, kv_group=kv_group)
            q_fn = jax.jit(lambda p, b, pol=pol: transformer.forward(
                p, model_cfg, b, policy=pol, training=False)[0])
            acc = {"mse": 0.0, "kl": 0.0}
            for b, fp in zip(batches, fp_logits):
                m = _metrics(fp, q_fn(params, b))
                acc = {k: acc[k] + float(v) for k, v in m.items()}
            row[kv_label(bits)] = {k: v / len(batches) for k, v in acc.items()}
        losses[layer_name(i)] = row
    return losses


# ---------------------------------------------------------------------------
# per-layer activation ranges (calibration observers over an unrolled pass)
# ---------------------------------------------------------------------------

def _iter_layer_params(params, model_cfg):
    """Yield (block_params, spec) per decoder layer, unstacking the scan."""
    dec = params["decoder"]
    p_len = len(model_cfg.pattern)
    for s in range(model_cfg.n_super):
        for j, spec in enumerate(model_cfg.pattern):
            yield jax.tree.map(lambda a, s=s: a[s], dec["super"][j]), spec
    for t, blk in enumerate(dec["tail"]):
        yield blk, model_cfg.pattern[t % p_len]


def layer_output_ranges(params, model_cfg, batches, *, kind: str = "minmax",
                        **observer_kw) -> list:
    """Calibrated (lo, hi) of every decoder layer's output stream."""
    states = [calibration.init(kind, **observer_kw)
              for _ in range(model_cfg.n_layers)]
    for batch in batches:
        x, _ = transformer._embed_inputs(params, model_cfg, batch, NO_QUANT)
        if model_cfg.pos_embed == "learned":
            from repro.models import layers as _layers
            x = _layers.posembed_apply(params["pos"], x)
        x = x.astype(model_cfg.activation_dtype)
        for i, (blk, spec) in enumerate(_iter_layer_params(params,
                                                           model_cfg)):
            x, _, _ = transformer.block_apply(blk, x, spec, model_cfg,
                                              policy=NO_QUANT)
            states[i] = calibration.update(states[i], x)
    return [calibration.bounds(s) for s in states]
