"""QuantPlan: a serializable per-layer bitwidth assignment.

The repo's original deployment surface applied ONE :class:`QuantConfig`
uniformly to every projection.  A plan generalizes that to "8-bit where it
hurts, 2-bit everywhere else": an ordered mapping ``layer name -> scheme``
over the decoder stack, with a default for unnamed layers.  A uniform
config is the trivial plan (``QuantPlan.uniform``).

Layer naming: decoder block ``i`` (0-based, over the scan-stacked
superblocks then the tail) is ``"layer.{i}"``.  ``resolve(model_cfg)``
validates names against the model's block pattern and returns the
per-layer config tuple that the model layer consumes.

JSON round trip: configs serialize as a registered scheme name when one
matches (``"lq4"``) and as an explicit field dict otherwise, so plans stay
human-editable and survive scheme-registry growth.
"""
from __future__ import annotations

import dataclasses
import json

from repro.core import schemes
from repro.core.schemes import QuantConfig


def layer_name(i: int) -> str:
    return f"layer.{i}"


def fit_group_size(cfg: QuantConfig, model_cfg) -> QuantConfig:
    """Clamp the local-region size to divide ``d_model`` (small models)."""
    gs = min(cfg.group_size, model_cfg.d_model)
    while model_cfg.d_model % gs:
        gs -= 1
    return dataclasses.replace(cfg, group_size=gs)


def candidates_for(model_cfg, scheme_names) -> dict:
    """``{scheme_name: QuantConfig}`` with region sizes fitted to the model.

    The candidate set for profiling/search — e.g.
    ``candidates_for(cfg, ["lq8", "lq4", "lq2"])``.
    """
    return {n: fit_group_size(schemes.get(n), model_cfg)
            for n in scheme_names}


def _cfg_to_json(cfg: QuantConfig):
    for name in schemes.names():
        if schemes.get(name) == cfg and name != "none":
            return name
    return dataclasses.asdict(cfg)


def _cfg_from_json(obj) -> QuantConfig:
    if isinstance(obj, str):
        return schemes.get(obj)
    return QuantConfig(**obj)


@dataclasses.dataclass(frozen=True)
class QuantPlan:
    """Ordered ``layer name -> QuantConfig`` assignment + default."""
    assignments: tuple = ()             # ((name, QuantConfig), ...)
    default: QuantConfig = schemes.FP32
    meta: tuple = ()                    # ((key, value), ...) provenance

    def __post_init__(self):
        seen = set()
        for name, cfg in self.assignments:
            if name in seen:
                raise ValueError(f"duplicate plan entry {name!r}")
            seen.add(name)
            if not isinstance(cfg, QuantConfig):
                raise TypeError(f"{name!r}: expected QuantConfig, "
                                f"got {type(cfg).__name__}")

    # ------------------------------------------------------------- build
    @staticmethod
    def uniform(cfg_or_name) -> "QuantPlan":
        """The trivial plan: one scheme everywhere."""
        return QuantPlan(default=schemes.get(cfg_or_name))

    @staticmethod
    def from_assignment(assignment: dict, default="fp32",
                        meta: dict | None = None) -> "QuantPlan":
        """``{"layer.0": "lq8", ...}`` (names or QuantConfigs) -> plan."""
        items = tuple((k, schemes.get(v)) for k, v in assignment.items())
        return QuantPlan(assignments=items, default=schemes.get(default),
                         meta=tuple(sorted((meta or {}).items())))

    # ----------------------------------------------------------- resolve
    def resolve(self, model_cfg) -> tuple:
        """Validate against the model's block pattern; return per-layer
        configs (length ``model_cfg.n_layers``)."""
        n = model_cfg.n_layers
        by_name = dict(self.assignments)
        configs = []
        for i in range(n):
            configs.append(by_name.pop(layer_name(i), self.default))
        if by_name:
            raise ValueError(
                f"plan names {sorted(by_name)} out of range for "
                f"{model_cfg.name!r} with {n} layers "
                f"(pattern {model_cfg.pattern!r})")
        for i, cfg in enumerate(configs):
            if cfg.w_bits is not None and model_cfg.d_model % cfg.group_size:
                raise ValueError(
                    f"{layer_name(i)}: group_size {cfg.group_size} does not "
                    f"divide d_model {model_cfg.d_model}")
        return tuple(configs)

    def policy(self, model_cfg, *, mode: str = "serve",
               backend: str = "auto"):
        """A :class:`repro.models.layers.PlanPolicy` over this plan."""
        from repro.models.layers import PlanPolicy
        return PlanPolicy(mode, self.resolve(model_cfg), backend)

    @property
    def is_uniform(self) -> bool:
        return not self.assignments

    # -------------------------------------------------------------- JSON
    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps({
            "version": 1,
            "default": _cfg_to_json(self.default),
            "layers": {k: _cfg_to_json(v) for k, v in self.assignments},
            "meta": dict(self.meta),
        }, indent=indent)

    @staticmethod
    def from_json(text: str) -> "QuantPlan":
        obj = json.loads(text)
        return QuantPlan(
            assignments=tuple((k, _cfg_from_json(v))
                              for k, v in obj.get("layers", {}).items()),
            default=_cfg_from_json(obj.get("default", "fp32")),
            meta=tuple(sorted(obj.get("meta", {}).items())))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @staticmethod
    def load(path: str) -> "QuantPlan":
        with open(path) as f:
            return QuantPlan.from_json(f.read())

    # ----------------------------------------------------------- display
    def describe(self, model_cfg=None) -> str:
        lines = [f"QuantPlan(default={_cfg_to_json(self.default)})"]
        if model_cfg is not None:
            for i, cfg in enumerate(self.resolve(model_cfg)):
                lines.append(f"  {layer_name(i):>10}: {_cfg_to_json(cfg)}")
        else:
            for name, cfg in self.assignments:
                lines.append(f"  {name:>10}: {_cfg_to_json(cfg)}")
        return "\n".join(lines)
