"""QuantPlan: a serializable per-layer bitwidth assignment.

The repo's original deployment surface applied ONE :class:`QuantConfig`
uniformly to every projection.  A plan generalizes that to "8-bit where it
hurts, 2-bit everywhere else": an ordered mapping ``layer name -> scheme``
over the decoder stack, with a default for unnamed layers.  A uniform
config is the trivial plan (``QuantPlan.uniform``).

Layer naming: decoder block ``i`` (0-based, over the scan-stacked
superblocks then the tail) is ``"layer.{i}"``.  ``resolve(model_cfg)``
validates names against the model's block pattern and returns the
per-layer config tuple that the model layer consumes.

JSON round trip: configs serialize as a registered scheme name when one
matches (``"lq4"``) and as an explicit field dict otherwise, so plans stay
human-editable and survive scheme-registry growth.
"""
from __future__ import annotations

import dataclasses
import json

from repro.core import kvwire, schemes
from repro.core.schemes import QuantConfig


def layer_name(i: int) -> str:
    return f"layer.{i}"


def fit_group_size(cfg: QuantConfig, model_cfg) -> QuantConfig:
    """Clamp the local-region size to divide ``d_model`` (small models)."""
    gs = min(cfg.group_size, model_cfg.d_model)
    while model_cfg.d_model % gs:
        gs -= 1
    return dataclasses.replace(cfg, group_size=gs)


def fit_kv_group(kv_group: int, head_dim: int) -> int:
    """Clamp the kv wire region size to divide ``head_dim``."""
    gs = min(kv_group, head_dim)
    while head_dim % gs:
        gs -= 1
    return gs


def candidates_for(model_cfg, scheme_names) -> dict:
    """``{scheme_name: QuantConfig}`` with region sizes fitted to the model.

    The candidate set for profiling/search — e.g.
    ``candidates_for(cfg, ["lq8", "lq4", "lq2"])``.
    """
    return {n: fit_group_size(schemes.get(n), model_cfg)
            for n in scheme_names}


def _cfg_to_json(cfg: QuantConfig):
    for name in schemes.names():
        if schemes.get(name) == cfg and name != "none":
            return name
    return dataclasses.asdict(cfg)


def _cfg_from_json(obj) -> QuantConfig:
    if isinstance(obj, str):
        return schemes.get(obj)
    return QuantConfig(**obj)


@dataclasses.dataclass(frozen=True)
class QuantPlan:
    """Ordered ``layer name -> QuantConfig`` assignment + default.

    ``kv_bits`` extends the plan to the decode-time KV cache: an ordered
    ``layer name -> bits`` mapping (``None`` = fp cache) with its own
    ``kv_default``, quantized in local regions of ``kv_group`` elements
    along head_dim (the cache wire format of ``core/kvwire.py``).  Weights
    and cache are independent axes — sensitive early layers can keep an
    8-bit cache while deep layers drop to 2-bit.
    """
    assignments: tuple = ()             # ((name, QuantConfig), ...)
    default: QuantConfig = schemes.FP32
    meta: tuple = ()                    # ((key, value), ...) provenance
    kv_bits: tuple = ()                 # ((name, bits | None), ...)
    kv_default: int | None = None       # cache bits for unnamed layers
    kv_group: int = 64                  # cache local-region size (head_dim)

    def __post_init__(self):
        seen = set()
        for name, cfg in self.assignments:
            if name in seen:
                raise ValueError(f"duplicate plan entry {name!r}")
            seen.add(name)
            if not isinstance(cfg, QuantConfig):
                raise TypeError(f"{name!r}: expected QuantConfig, "
                                f"got {type(cfg).__name__}")
        seen = set()
        for name, bits in self.kv_bits:
            if name in seen:
                raise ValueError(f"duplicate kv_bits entry {name!r}")
            seen.add(name)
            kvwire.check_kv_bits(bits)
        kvwire.check_kv_bits(self.kv_default)
        if self.kv_group < 1:
            raise ValueError(f"kv_group must be >= 1, got {self.kv_group}")

    # ------------------------------------------------------------- build
    @staticmethod
    def uniform(cfg_or_name) -> "QuantPlan":
        """The trivial plan: one scheme everywhere."""
        return QuantPlan(default=schemes.get(cfg_or_name))

    @staticmethod
    def from_assignment(assignment: dict, default="fp32",
                        meta: dict | None = None,
                        kv_bits: dict | None = None,
                        kv_default: int | None = None,
                        kv_group: int = 64) -> "QuantPlan":
        """``{"layer.0": "lq8", ...}`` (names or QuantConfigs) -> plan."""
        items = tuple((k, schemes.get(v)) for k, v in assignment.items())
        return QuantPlan(assignments=items, default=schemes.get(default),
                         meta=tuple(sorted((meta or {}).items())),
                         kv_bits=tuple((kv_bits or {}).items()),
                         kv_default=kv_default, kv_group=kv_group)

    def with_kv(self, kv_bits: dict | None = None,
                default: int | None = None,
                kv_group: int | None = None) -> "QuantPlan":
        """This plan with a per-layer cache bitwidth map attached."""
        return dataclasses.replace(
            self, kv_bits=tuple((kv_bits or {}).items()), kv_default=default,
            kv_group=self.kv_group if kv_group is None else kv_group)

    # ----------------------------------------------------------- resolve
    def resolve(self, model_cfg) -> tuple:
        """Validate against the model's block pattern; return per-layer
        configs (length ``model_cfg.n_layers``)."""
        n = model_cfg.n_layers
        by_name = dict(self.assignments)
        configs = []
        for i in range(n):
            configs.append(by_name.pop(layer_name(i), self.default))
        if by_name:
            raise ValueError(
                f"plan names {sorted(by_name)} out of range for "
                f"{model_cfg.name!r} with {n} layers "
                f"(pattern {model_cfg.pattern!r})")
        for i, cfg in enumerate(configs):
            if cfg.w_bits is not None and model_cfg.d_model % cfg.group_size:
                raise ValueError(
                    f"{layer_name(i)}: group_size {cfg.group_size} does not "
                    f"divide d_model {model_cfg.d_model}")
        self.resolve_kv(model_cfg)          # kv map validates with the plan
        return tuple(configs)

    def resolve_kv(self, model_cfg) -> tuple:
        """Validate the cache map against the model; return per-layer bits
        (length ``model_cfg.n_layers``, entries in {8, 4, 2, 1, None})."""
        n = model_cfg.n_layers
        by_name = dict(self.kv_bits)
        bits = []
        for i in range(n):
            bits.append(by_name.pop(layer_name(i), self.kv_default))
        if by_name:
            raise ValueError(
                f"kv_bits names {sorted(by_name)} out of range for "
                f"{model_cfg.name!r} with {n} layers "
                f"(pattern {model_cfg.pattern!r})")
        for i, b in enumerate(bits):
            if b is None:
                continue
            kvwire.check_kv_bits(b)
            mixer, _ = model_cfg.layer_spec(i)
            if not (mixer.startswith("attn") or mixer == "mamba2"):
                raise ValueError(
                    f"{layer_name(i)}: mixer {mixer!r} has no quantizable "
                    f"cache; kv_bits applies to attention/SSM layers only")
            if mixer.startswith("attn") and model_cfg.head_dim % self.kv_group:
                raise ValueError(
                    f"{layer_name(i)}: kv_group {self.kv_group} does not "
                    f"divide head_dim {model_cfg.head_dim}")
        return tuple(bits)

    def policy(self, model_cfg, *, mode: str = "serve",
               backend: str = "auto"):
        """A :class:`repro.models.layers.PlanPolicy` over this plan."""
        from repro.models.layers import PlanPolicy
        return PlanPolicy(mode, self.resolve(model_cfg), backend,
                          kv_bits=self.resolve_kv(model_cfg),
                          kv_group=self.kv_group)

    @property
    def is_uniform(self) -> bool:
        return not self.assignments

    @property
    def has_kv(self) -> bool:
        """True when the plan says anything about the cache at all."""
        return self.kv_default is not None or any(
            b is not None for _, b in self.kv_bits)

    def uniform_kv(self, model_cfg) -> tuple:
        """``(is_uniform, bits)`` of the resolved cache map — uniform maps
        collapse to the homogeneous pool/cache layout byte-for-byte."""
        bits = set(self.resolve_kv(model_cfg))
        if len(bits) == 1:
            return True, next(iter(bits))
        return False, None

    # -------------------------------------------------------------- JSON
    def to_json(self, indent: int | None = 2) -> str:
        obj = {
            "version": 1,
            "default": _cfg_to_json(self.default),
            "layers": {k: _cfg_to_json(v) for k, v in self.assignments},
            "meta": dict(self.meta),
        }
        if self.has_kv:
            obj["kv"] = {"default": self.kv_default,
                         "layers": dict(self.kv_bits),
                         "group": self.kv_group}
        return json.dumps(obj, indent=indent)

    @staticmethod
    def from_json(text: str) -> "QuantPlan":
        obj = json.loads(text)
        kv = obj.get("kv", {})
        return QuantPlan(
            assignments=tuple((k, _cfg_from_json(v))
                              for k, v in obj.get("layers", {}).items()),
            default=_cfg_from_json(obj.get("default", "fp32")),
            meta=tuple(sorted(obj.get("meta", {}).items())),
            kv_bits=tuple(kv.get("layers", {}).items()),
            kv_default=kv.get("default"),
            kv_group=kv.get("group", 64))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @staticmethod
    def load(path: str) -> "QuantPlan":
        with open(path) as f:
            return QuantPlan.from_json(f.read())

    # ----------------------------------------------------------- display
    def describe(self, model_cfg=None) -> str:
        def kv_str(b):
            return "" if not self.has_kv else \
                f"  kv={'fp' if b is None else b}"

        lines = [f"QuantPlan(default={_cfg_to_json(self.default)}"
                 + (f", kv_default={self.kv_default}, kv_group="
                    f"{self.kv_group}" if self.has_kv else "") + ")"]
        if model_cfg is not None:
            kv = self.resolve_kv(model_cfg)
            for i, cfg in enumerate(self.resolve(model_cfg)):
                lines.append(f"  {layer_name(i):>10}: {_cfg_to_json(cfg)}"
                             f"{kv_str(kv[i])}")
        else:
            kv = dict(self.kv_bits)
            for name, cfg in self.assignments:
                lines.append(f"  {name:>10}: {_cfg_to_json(cfg)}"
                             + (kv_str(kv[name]) if name in kv else ""))
        return "\n".join(lines)
