"""Bitwidth search: greedy Pareto descent under a device budget.

Knapsack-style assignment: every decoder layer starts at its
lowest-sensitivity candidate (typically the widest format); while the
plan exceeds the budget, the search applies the single layer downgrade
with the best marginal rate

    (cost saved) / (sensitivity added)

— the greedy Pareto step of hardware-calibrated constrained search
(cf. 1909.10818).  Each applied step is recorded, so the trace IS the
plan-space Pareto path: sweeping a budget from uniform-wide to
uniform-narrow replays the same frontier.

Costs come from ``costmodel`` (bytes or modeled ms), sensitivities from
``sensitivity`` (KL or MSE vs the fp path).  Both are plain
``{layer: {scheme: value}}`` dicts so the search is decoupled from how
they were produced.
"""
from __future__ import annotations

import dataclasses

from .plan import QuantPlan

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class SearchResult:
    assignment: dict          # layer_name -> scheme_name
    cost: float               # total under cost_key
    loss: float               # total sensitivity under loss_key
    feasible: bool            # cost <= budget
    trace: tuple              # ((cost, loss, "layer.i: a->b"), ...) applied

    def plan(self, candidates: dict, *, meta: dict | None = None,
             default="fp32") -> QuantPlan:
        return QuantPlan.from_assignment(
            {k: candidates[v] for k, v in self.assignment.items()},
            default=default, meta=meta)

    def joint_plan(self, candidates: dict, *, kv_group: int = 64,
                   meta: dict | None = None, default="fp32") -> QuantPlan:
        """A joint (weight x kv) assignment -> QuantPlan with a kv map."""
        from .costmodel import kv_bits_of_label
        w, kv = split_joint_assignment(self.assignment)
        return QuantPlan.from_assignment(
            {l: candidates[s] for l, s in w.items()}, default=default,
            meta=meta,
            kv_bits={l: kv_bits_of_label(s) for l, s in kv.items()},
            kv_default=None, kv_group=kv_group)


def _totals(assignment, costs, sens, cost_key, loss_key):
    cost = sum(_get(costs[l][s], cost_key) for l, s in assignment.items())
    loss = sum(_get(sens[l][s], loss_key) for l, s in assignment.items())
    return cost, loss


def _get(cell, key):
    if isinstance(cell, dict):
        return float(cell[key])
    return float(getattr(cell, key))


def greedy_search(sens: dict, costs: dict, *, budget: float,
                  cost_key: str = "bytes",
                  loss_key: str = "kl") -> SearchResult:
    """Assign one candidate scheme per layer so total cost <= budget.

    ``sens``/``costs``: ``{layer: {scheme: cell}}`` where a cell is a dict
    or object exposing ``loss_key`` / ``cost_key``.  Layers and their
    candidate sets are taken from ``costs``; every (layer, scheme) must
    also appear in ``sens``.
    """
    layers = list(costs)
    # start: lowest sensitivity, ties broken toward cheaper
    assignment = {
        l: min(costs[l], key=lambda s: (_get(sens[l][s], loss_key),
                                        _get(costs[l][s], cost_key)))
        for l in layers}
    cost, loss = _totals(assignment, costs, sens, cost_key, loss_key)
    trace = [(cost, loss, "start")]

    while cost > budget:
        best = None          # (rate, layer, scheme, d_cost, d_loss)
        for l in layers:
            cur = assignment[l]
            c_cur = _get(costs[l][cur], cost_key)
            s_cur = _get(sens[l][cur], loss_key)
            for s in costs[l]:
                d_cost = c_cur - _get(costs[l][s], cost_key)
                if d_cost <= 0:
                    continue               # not a downgrade in this currency
                d_loss = max(_get(sens[l][s], loss_key) - s_cur, 0.0)
                rate = d_cost / (d_loss + _EPS)
                if best is None or rate > best[0]:
                    best = (rate, l, s, d_cost, d_loss)
        if best is None:                   # fully narrowed, still over budget
            break
        _, l, s, d_cost, d_loss = best
        assignment[l] = s
        cost -= d_cost
        loss += d_loss
        trace.append((cost, loss, f"{l}: ->{s}"))
    # re-total from the assignment: the clamped d_loss used for ranking can
    # overstate the running loss when sensitivities are non-monotone
    cost, loss = _totals(assignment, costs, sens, cost_key, loss_key)
    return SearchResult(assignment=assignment, cost=cost, loss=loss,
                        feasible=cost <= budget, trace=tuple(trace))


def uniform_result(scheme: str, sens: dict, costs: dict, *,
                   cost_key: str = "bytes",
                   loss_key: str = "kl") -> SearchResult:
    """The uniform plan's point in the same (cost, loss) space."""
    assignment = {l: scheme for l in costs}
    cost, loss = _totals(assignment, costs, sens, cost_key, loss_key)
    return SearchResult(assignment=assignment, cost=cost, loss=loss,
                        feasible=True,
                        trace=((cost, loss, f"uniform {scheme}"),))


# ---------------------------------------------------------------------------
# joint (weight-bits x kv-bits) space
# ---------------------------------------------------------------------------
#
# The cache is just a second cost/loss axis per layer, so the joint search
# is the same greedy descent over a product candidate grid: every joint
# scheme "lq4w|kv8" sums its weight and kv cells key-wise (the additive
# sensitivity assumption extended to the cache).  A downgrade step may then
# narrow a layer's weights, its cache, or both — whatever buys the most
# bytes per unit of added loss.

JOINT_SEP = "|"


def joint_name(w_scheme: str, kv_scheme: str) -> str:
    return f"{w_scheme}{JOINT_SEP}{kv_scheme}"


def split_joint_name(name: str) -> tuple:
    w, _, k = name.partition(JOINT_SEP)
    if not k:
        raise ValueError(f"not a joint scheme name: {name!r}")
    return w, k


def joint_space(w_cells: dict, kv_cells: dict) -> dict:
    """Product grid: ``{layer: {"w|kv": merged cell}}``.

    ``w_cells`` / ``kv_cells`` are ``{layer: {scheme: {key: float}}}``;
    merged cells sum values on shared keys and keep one-sided keys as-is
    (so weight ``bytes`` + kv ``bytes`` fold into one byte currency while
    ``ms`` or ``bytes_per_token`` survive untouched).
    """
    if set(w_cells) != set(kv_cells):
        raise ValueError("weight and kv grids cover different layers: "
                         f"{sorted(set(w_cells) ^ set(kv_cells))}")
    out = {}
    for layer, w_row in w_cells.items():
        row = {}
        for ws, wc in w_row.items():
            for ks, kc in kv_cells[layer].items():
                row[joint_name(ws, ks)] = {
                    k: float(wc.get(k, 0.0)) + float(kc.get(k, 0.0))
                    for k in set(wc) | set(kc)}
        out[layer] = row
    return out


def split_joint_assignment(assignment: dict) -> tuple:
    """A joint search assignment -> (weight map, kv map by label)."""
    w = {l: split_joint_name(s)[0] for l, s in assignment.items()}
    kv = {l: split_joint_name(s)[1] for l, s in assignment.items()}
    return w, kv


def pareto_frontier(points) -> list:
    """Non-dominated subset of (cost, loss) pairs, sorted by cost."""
    pts = sorted(set(points))
    out = []
    best_loss = float("inf")
    for c, l in pts:
        if l < best_loss:
            out.append((c, l))
            best_loss = l
    return out
