"""LQ-quantized KV-cache (+ SSM-state) wire format (core format layer).

The paper quantizes layer *inputs* at runtime (section V.B: "the inputs
have to be converted into fixed point in runtime").  The serving-era
analogue is the KV cache: decode is memory-bound on cache reads, so
storing K/V in the local-quantization-region format cuts HBM traffic by
16/bits x — the same roofline win as packed weights (DESIGN.md §5.1).

Wire format per cached tensor (quantized along the head/feature dim):

    {"packed": uint8 (..., D/cpb), "scale": f32 (..., G), "zmin": f32 (..., G)}

``bits`` is *inferred from shapes* (cpb = D // packed_D in {1,2,4,8} ->
bits in {8,4,2,1}), so the cache stays a plain pytree — it flows through
scan / pjit / donation with no static metadata.  6/5/3-bit KV is therefore
not expressible here (weights support it; the cache keeps the power-of-two
set — noted in DESIGN.md).

Supported leaves: attention K/V (B, S, KV, D) and mamba2 SSM state
(B, H, P, N) — the attention-free arch's "cache" (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing


def _infer(packed_d: int, d: int, scale_g: int):
    cpb = d // packed_d
    bits = {1: 8, 2: 4, 4: 2, 8: 1}[cpb]
    group_size = d // scale_g
    return bits, group_size


def is_quant_kv(leaf) -> bool:
    return isinstance(leaf, dict) and "packed" in leaf


is_quant_state = is_quant_kv


def kv_bits_of(q: dict, d: int) -> int:
    return _infer(q["packed"].shape[-1], d, q["scale"].shape[-1])[0]


def quantize_kv(x: jnp.ndarray, bits: int, group_size: int) -> dict:
    """x (..., D) -> wire dict, regions along the last dim."""
    d = x.shape[-1]
    if d % group_size:
        raise ValueError(f"D={d} not divisible by group_size={group_size}")
    g = d // group_size
    xg = x.astype(jnp.float32).reshape(*x.shape[:-1], g, group_size)
    xmin = xg.min(-1)
    xmax = xg.max(-1)
    levels = (1 << bits) - 1
    rng = xmax - xmin
    scale = jnp.where(rng > 0, rng / levels, jnp.ones_like(rng))
    codes = jnp.clip(jnp.round((xg - xmin[..., None]) / scale[..., None]),
                     0, levels).astype(jnp.uint8)
    return {"packed": packing.pack(codes.reshape(*x.shape), bits),
            "scale": scale, "zmin": xmin}


def dequantize_kv(q: dict, d: int, dtype=jnp.float32) -> jnp.ndarray:
    bits, group_size = _infer(q["packed"].shape[-1], d, q["scale"].shape[-1])
    codes = packing.unpack(q["packed"], bits, d).astype(jnp.float32)
    g = d // group_size
    cg = codes.reshape(*codes.shape[:-1], g, group_size)
    x = cg * q["scale"][..., None] + q["zmin"][..., None]
    return x.reshape(*codes.shape).astype(dtype)


def make_quant_kv(shape: tuple, bits: int, group_size: int) -> dict:
    """Zero-initialized wire cache for a (..., D) tensor.

    ALL leaves init to zero — including ``scale``, so an unwritten row
    dequantizes to 0 (code 0 * scale 0 + zmin 0) and the zero wire state
    is one uniform fill.  Scan-stacked cache/pool layouts build their
    leaves with ``jnp.zeros`` over this structure
    (``transformer.init_cache``, ``serve/pool.py``), and cache rewind
    (:func:`reset_page_rows`) restores exactly this state.
    """
    *lead, d = shape
    cpb = packing.codes_per_byte(bits)
    g = d // group_size
    return {"packed": jnp.zeros((*lead, d // cpb), jnp.uint8),
            "scale": jnp.zeros((*lead, g), jnp.float32),
            "zmin": jnp.zeros((*lead, g), jnp.float32)}


def update_quant_kv(q: dict, new: jnp.ndarray, slot, *, axis: int,
                    bits: int, group_size: int) -> dict:
    """Quantize ``new`` and write it at ``slot`` along ``axis``.

    ``new`` has the same rank as the cache's logical tensor; its extent
    along ``axis`` may exceed 1 (bulk prefill write).
    """
    wire = quantize_kv(new, bits, group_size)
    return {k: jax.lax.dynamic_update_slice_in_dim(
        q[k], wire[k].astype(q[k].dtype), slot, axis=axis) for k in q}


# ---------------------------------------------------------------------------
# paged layout: pool pages of the same wire format
# ---------------------------------------------------------------------------
#
# A paged pool stores a leaf as (n_pages, page_size, KV, D) — or its wire
# dict with (n_pages, page_size, KV, D/cpb) packed codes — instead of one
# contiguous (B, T, KV, D) buffer.  A request owns an ordered list of pages
# (its page table); page p of the table holds absolute token positions
# [p*page_size, (p+1)*page_size).  Page 0 is reserved as a scratch page:
# padded table entries and inactive batch slots read/write it, and the
# masking in decode_attention guarantees scratch garbage never reaches a
# real output.  Packing is along the head dim, so page_size is independent
# of kv_bits; every page is page_size * KV * (D*bits/8 + 8*D/group) bytes.

def make_paged_kv(n_pages: int, page_size: int, kv_heads: int, head_dim: int,
                  bits: int | None = None, group_size: int = 64,
                  dtype=jnp.float32):
    """One pool leaf: fp array or wire dict with (n_pages, page_size) lead."""
    shape = (n_pages, page_size, kv_heads, head_dim)
    if bits is None:
        return jnp.zeros(shape, dtype)
    return make_quant_kv(shape, bits, group_size)


def gather_pages(leaf, page_table: jnp.ndarray):
    """Gather a (B, P) page table into logical (B, P*page_size, ...) views.

    Works on fp leaves and wire dicts alike (a wire dict is a pytree of
    arrays whose page dims match).  Row order in the gathered view is the
    page-table order, so with in-order tables position t of request b lives
    at gathered index t.
    """
    def g(a):
        out = a[page_table]
        return out.reshape(page_table.shape[0], -1, *a.shape[2:])
    return jax.tree.map(g, leaf)


def scatter_token(leaf, new: jnp.ndarray, page_idx, row, *,
                  bits: int | None = None, group_size: int | None = None):
    """Write one token per batch row into its page.

    ``new`` is fp (B, 1, KV, D); ``page_idx``/``row`` are (B,) physical page
    ids and in-page rows.  Rows of inactive slots should point at the
    scratch page (duplicate scratch writes are unordered, which is fine —
    the scratch page is never read unmasked).
    """
    if is_quant_kv(leaf):
        wire = quantize_kv(new, bits, group_size)
        return jax.tree.map(
            lambda a, w: a.at[page_idx, row].set(w[:, 0].astype(a.dtype)),
            leaf, wire)
    return leaf.at[page_idx, row].set(new[:, 0].astype(leaf.dtype))


def scatter_tokens(leaf, new: jnp.ndarray, page_idx, row, *,
                   bits: int | None = None, group_size: int | None = None):
    """Write a length-L run of tokens per batch row into its pages.

    ``new`` is fp (B, L, KV, D); ``page_idx``/``row`` are (B, L) physical
    page ids and in-page rows — the speculative verify path writes all L
    candidate positions of every slot in one scatter.  Rows of inactive
    (or overflowing) slots should point at the scratch page; duplicate
    scratch writes are unordered, which is fine — the scratch page is
    never read unmasked.
    """
    if is_quant_kv(leaf):
        wire = quantize_kv(new, bits, group_size)
        return jax.tree.map(
            lambda a, w: a.at[page_idx, row].set(w.astype(a.dtype)),
            leaf, wire)
    return leaf.at[page_idx, row].set(new.astype(leaf.dtype))


def reset_table_rows(tree, table, keep_tokens, *, stacked: bool = False):
    """Un-write every row past ``keep_tokens`` tokens of one request's
    page table, in ONE fused update per leaf.

    ``table`` is the request's (scratch-padded, fixed-length) ordered
    page-id vector; entry i of the table covers token positions
    ``[i * page_size, (i+1) * page_size)``.  Rows at positions
    ``>= keep_tokens`` on the table's real (non-scratch) pages are reset
    to the zero-initialized wire state (all leaves -> 0, matching
    :func:`make_quant_kv`); scratch-padded entries are left untouched.

    This is the device half of cache rewind: a speculative verify writes
    L candidate rows, the accept decision keeps a prefix, and the pool
    un-writes the rejected suffix so its bytes are indistinguishable from
    a pool that never speculated (``serve/pool.py::PagedKVPool.truncate``)
    — one dispatch per rewind, however many pages it spans.
    """
    n_tbl = table.shape[0]

    def reset(a):
        pages = a[:, table] if stacked else a[table]   # (.., n_tbl, ps, ..)
        lead = 2 if stacked else 1
        ps = pages.shape[lead]
        pos = (jnp.arange(n_tbl)[:, None] * ps
               + jnp.arange(ps)[None])                  # (n_tbl, ps)
        mask = (pos >= keep_tokens) & (table > 0)[:, None]
        mask = mask.reshape((1,) * (lead - 1) + (n_tbl, ps)
                            + (1,) * (pages.ndim - lead - 1))
        new = jnp.where(mask, jnp.zeros((), a.dtype), pages)
        # duplicate scratch entries all scatter their own UNCHANGED rows
        # (mask is False there), so the unordered dupes are harmless
        return (a.at[:, table].set(new) if stacked
                else a.at[table].set(new))

    return jax.tree.map(reset, tree)


def scatter_prefill(leaf, contig, page_ids: jnp.ndarray, *,
                    stacked: bool = False):
    """Copy a B=1 contiguous prefill cache into pool pages.

    ``contig`` is the (S, 1, T, ...) (stacked=True) or (1, T, ...) leaf from
    a contiguous prefill; T must equal len(page_ids) * page_size.  Pages the
    request does not own map to the scratch page in ``page_ids``.
    """
    def s(pl, cl):
        if stacked:
            ps = pl.shape[2]
            c = cl.reshape(cl.shape[0], -1, ps, *cl.shape[3:])
            return pl.at[:, page_ids].set(c.astype(pl.dtype))
        ps = pl.shape[1]
        c = cl.reshape(-1, ps, *cl.shape[2:])
        return pl.at[page_ids].set(c.astype(pl.dtype))
    return jax.tree.map(s, leaf, contig)


def permute_pages(leaf, perm: jnp.ndarray, *, stacked: bool = False):
    """Reorder pages (defrag): new page i takes old page perm[i]."""
    return jax.tree.map(lambda a: a[:, perm] if stacked else a[perm], leaf)


# ---------------------------------------------------------------------------
# SSM state (mamba2): same format, quantized along the state dim N
# ---------------------------------------------------------------------------

def quantize_state(h: jnp.ndarray, bits: int = 8,
                   group_size: int = 64) -> dict:
    gs = min(group_size, h.shape[-1])
    return quantize_kv(h, bits, gs)


def dequantize_state(q: dict, n: int) -> jnp.ndarray:
    return dequantize_kv(q, n, jnp.float32)


# ---------------------------------------------------------------------------
# per-layer (heterogeneous) layout helpers
# ---------------------------------------------------------------------------

KV_BITS = (8, 4, 2, 1)     # the wire format's expressible widths (cpb 2^k)


def check_kv_bits(bits) -> None:
    """The wire format infers bits from shapes, so only power-of-two
    widths round-trip (6/5/3-bit would alias another cpb)."""
    if bits is not None and bits not in KV_BITS:
        raise ValueError(f"kv_bits must be one of {KV_BITS} or None (fp), "
                         f"got {bits!r}")


def segment_runs(values, p_len: int, n_super: int) -> list:
    """Group consecutive superblocks whose per-position values match.

    ``values`` is a per-layer list (length >= n_super * p_len); the key of
    superblock ``s`` is ``tuple(values[s*p_len + j] for j in range(p_len))``.
    Returns ``[(start_super, size, key), ...]`` — the maximal runs one
    stacked cache array (or scan body) can cover.  This is the shared
    grouping rule behind ``transformer.plan_segments`` and the
    heterogeneous pool layout in ``serve/pool.py``: per-layer kv bitwidths
    change packed leaf *shapes*, so each run gets its own stacked array.
    """
    segs = []
    s = 0
    while s < n_super:
        key = tuple(values[s * p_len + j] for j in range(p_len))
        e = s + 1
        while e < n_super and key == tuple(values[e * p_len + j]
                                           for j in range(p_len)):
            e += 1
        segs.append((s, e - s, key))
        s = e
    return segs


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------

def cache_nbytes(cache) -> int:
    """Total bytes of a (possibly mixed fp/quantized) cache pytree."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def kv_token_nbytes(kv_heads: int, head_dim: int, bits: int | None,
                    group_size: int = 64, fp_itemsize: int = 4) -> float:
    """Exact wire bytes one cached token costs for one K+V pair.

    Matches the paged-pool leaf byte-for-byte: packed codes are
    ``head_dim * bits / 8`` per head plus an f32 (scale, zmin) pair per
    local region; fp caches pay ``fp_itemsize`` per element.  Used by
    ``plan/costmodel.py`` to price per-layer cache budgets and by the
    pool-geometry property tests.
    """
    if bits is None:
        per_head = head_dim * fp_itemsize
    else:
        check_kv_bits(bits)
        per_head = head_dim * bits / 8 + 2 * 4 * (head_dim // group_size)
    return 2.0 * kv_heads * per_head
