"""Activation-range calibration for post-training quantization.

The paper quantizes inputs *at runtime* from each batch's own min/max
(section IV.C: "the inputs have to be converted into fixed point in
runtime").  That is the ``dynamic`` observer here.  For deployment paths
where the range must be frozen offline (e.g. pre-computed LUT affine
params), we provide running min/max and percentile observers over a
calibration stream -- the standard PTQ substrate the paper's BLAImark
pipeline (Fig. 6) implies but does not spell out.

All observers are pure-functional: ``init() -> state``,
``update(state, x) -> state``, ``bounds(state) -> (lo, hi)`` -- so they can
live inside jitted evaluation loops.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=("lo", "hi", "count", "hist"),
         meta_fields=("kind", "momentum", "percentile", "hist_lo", "hist_hi"))
@dataclasses.dataclass(frozen=True)
class ObserverState:
    lo: jnp.ndarray          # scalar f32
    hi: jnp.ndarray
    count: jnp.ndarray       # scalar i32, batches seen
    hist: jnp.ndarray        # (bins,) f32 histogram (percentile observer)
    kind: str                # 'minmax' | 'ema' | 'percentile'
    momentum: float
    percentile: float
    hist_lo: float
    hist_hi: float


_BINS = 2048


def init(kind: str = "minmax", *, momentum: float = 0.99,
         percentile: float = 99.9, hist_range: tuple = (-30.0, 30.0)
         ) -> ObserverState:
    if kind not in ("minmax", "ema", "percentile"):
        raise ValueError(f"unknown observer {kind!r}")
    return ObserverState(
        lo=jnp.float32(jnp.inf), hi=jnp.float32(-jnp.inf),
        count=jnp.int32(0), hist=jnp.zeros((_BINS,), jnp.float32),
        kind=kind, momentum=momentum, percentile=percentile,
        hist_lo=float(hist_range[0]), hist_hi=float(hist_range[1]))


def update(state: ObserverState, x: jnp.ndarray) -> ObserverState:
    xf = x.astype(jnp.float32)
    blo, bhi = xf.min(), xf.max()
    if state.kind == "minmax":
        lo = jnp.minimum(state.lo, blo)
        hi = jnp.maximum(state.hi, bhi)
        hist = state.hist
    elif state.kind == "ema":
        m = state.momentum
        first = state.count == 0
        lo = jnp.where(first, blo, m * state.lo + (1 - m) * blo)
        hi = jnp.where(first, bhi, m * state.hi + (1 - m) * bhi)
        hist = state.hist
    else:  # percentile: accumulate a histogram, bounds read from quantiles
        lo = jnp.minimum(state.lo, blo)
        hi = jnp.maximum(state.hi, bhi)
        edges = jnp.linspace(state.hist_lo, state.hist_hi, _BINS + 1)
        idx = jnp.clip(jnp.searchsorted(edges, xf.ravel()) - 1, 0, _BINS - 1)
        hist = state.hist.at[idx].add(1.0)
    return dataclasses.replace(state, lo=lo, hi=hi, hist=hist,
                               count=state.count + 1)


def bounds(state: ObserverState) -> tuple:
    """Calibrated (lo, hi) range for quantizer construction."""
    if state.kind in ("minmax", "ema"):
        return state.lo, state.hi
    total = state.hist.sum()
    cdf = jnp.cumsum(state.hist) / jnp.maximum(total, 1.0)
    q = state.percentile / 100.0
    centers = jnp.linspace(state.hist_lo, state.hist_hi, _BINS)
    lo_i = jnp.argmax(cdf >= (1 - q))
    hi_i = jnp.argmax(cdf >= q)
    # fall back to true min/max if the histogram is empty
    lo = jnp.where(total > 0, centers[lo_i], state.lo)
    hi = jnp.where(total > 0, centers[hi_i], state.hi)
    return lo, hi


def calibrate(fn, stream, kind: str = "minmax", **kw) -> tuple:
    """Run ``fn(batch)`` over a calibration stream; observe its outputs.

    Returns final (lo, hi).  ``fn`` maps a batch to the activation tensor
    whose range is being calibrated.
    """
    state = init(kind, **kw)
    step = jax.jit(lambda s, b: update(s, fn(b)))
    for batch in stream:
        state = step(state, batch)
    return bounds(state)
