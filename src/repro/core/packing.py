"""Bit-packing of low-precision quantization codes into int8 lanes.

TPU (like the paper's Edison CPU, section V.A) has no sub-8-bit ISA.  Codes
are therefore *stored* packed -- 8 x 1-bit, 4 x 2-bit or 2 x 4-bit per uint8
lane -- and unpacked in VMEM right before compute.  Packing is always along
the **last** axis; callers move the group axis there first.

6-bit codes (paper Table 2 includes a 6-bit column) do not tile a byte; they
are stored one-per-lane (uint8) and only count as 6-bit for accuracy /
bytes-accounting purposes (documented in DESIGN.md section 5).
"""
from __future__ import annotations

import jax.numpy as jnp

# Bit-widths that actually pack denser than one byte per code.
PACKABLE_BITS = (1, 2, 4)
SUPPORTED_BITS = (1, 2, 3, 4, 5, 6, 7, 8)


def codes_per_byte(bits: int) -> int:
    """How many codes share one uint8 lane."""
    return 8 // bits if bits in PACKABLE_BITS else 1


def packed_len(n_codes: int, bits: int) -> int:
    per = codes_per_byte(bits)
    if n_codes % per:
        raise ValueError(f"last dim {n_codes} not divisible by {per} ({bits}-bit)")
    return n_codes // per


def pack(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack integer codes (values in [0, 2^bits)) along the last axis.

    codes: any integer dtype, shape (..., K) with K % codes_per_byte(bits) == 0.
    Returns uint8 of shape (..., K // codes_per_byte(bits)).
    """
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"unsupported bits={bits}")
    if bits not in PACKABLE_BITS:
        return codes.astype(jnp.uint8)
    per = codes_per_byte(bits)
    *lead, k = codes.shape
    if k % per:
        raise ValueError(f"last dim {k} not divisible by {per} ({bits}-bit)")
    c = codes.reshape(*lead, k // per, per).astype(jnp.uint32)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)
    return (c << shifts).sum(axis=-1).astype(jnp.uint8)


def unpack(packed: jnp.ndarray, bits: int, n_codes: int | None = None) -> jnp.ndarray:
    """Inverse of :func:`pack`.  Returns uint8 codes shaped (..., n_codes)."""
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"unsupported bits={bits}")
    if bits not in PACKABLE_BITS:
        return packed.astype(jnp.uint8)
    per = codes_per_byte(bits)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)
    mask = jnp.uint32((1 << bits) - 1)
    vals = (packed[..., None].astype(jnp.uint32) >> shifts) & mask
    *lead, kp, _ = vals.shape
    out = vals.reshape(*lead, kp * per).astype(jnp.uint8)
    if n_codes is not None:
        out = out[..., :n_codes]
    return out
