"""Quantization scheme registry.

A :class:`QuantConfig` fully describes how a projection is quantized:

  * ``w_bits`` / ``a_bits``    -- weight / activation bit-widths (None = fp)
  * ``granularity``            -- 'per_tensor' (paper's DQ, section IV.B) or
                                  'per_group' (the paper's LQ, section IV.C)
  * ``group_size``             -- size of the local quantization region
  * ``lut``                    -- use the look-up-table forward path (paper
                                  section V); requires a_bits <= 4.

Named schemes mirror the paper's experiment grid:

  fp32                         -- 32-bit float baseline (section III)
  dq8 dq6 dq4 dq2              -- dynamic fixed point (one region per layer)
  lq8 lq6 lq4 lq2 lq1          -- local quantization regions (group_size=128)
  lq2_lut                      -- 2-bit LQ + LUT forward (paper section V,
                                  weights 8-bit as in paper Table 3 setup)

The registry is open: ``register("myscheme", QuantConfig(...))``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    w_bits: int | None = None          # None => float weights
    a_bits: int | None = None          # None => float activations
    granularity: str = "per_group"     # 'per_group' (LQ) | 'per_tensor' (DQ)
    group_size: int = 128              # local quantization region size
    lut: bool = False                  # paper section-V LUT forward path
    stochastic: bool = False           # stochastic rounding (QAT / gradcomp)

    def __post_init__(self):
        if self.granularity not in ("per_group", "per_tensor"):
            raise ValueError(f"bad granularity {self.granularity!r}")
        if self.lut and (self.a_bits is None or self.a_bits > 4):
            raise ValueError("LUT path needs activation bits <= 4 "
                             "(table size 2^a_bits, paper section V.A)")
        for b in (self.w_bits, self.a_bits):
            if b is not None and not (1 <= b <= 8):
                raise ValueError(f"bits must be in [1, 8], got {b}")

    @property
    def quantized(self) -> bool:
        return self.w_bits is not None or self.a_bits is not None

    def kw(self) -> dict:
        """Keyword args for core.quantize.quantize()/fake_quant()."""
        return dict(group_size=self.group_size, granularity=self.granularity)


FP32 = QuantConfig()

_REGISTRY: dict[str, QuantConfig] = {"fp32": FP32, "none": FP32}

for _b in (8, 6, 4, 2, 1):
    _REGISTRY[f"dq{_b}"] = QuantConfig(w_bits=_b, a_bits=_b,
                                       granularity="per_tensor")
    _REGISTRY[f"lq{_b}"] = QuantConfig(w_bits=_b, a_bits=_b,
                                       granularity="per_group", group_size=128)
    # weight-only variants (serving: weights offline, activations fp -- the
    # memory-roofline deployment mode on TPU, DESIGN.md section 5.1)
    _REGISTRY[f"lq{_b}w"] = QuantConfig(w_bits=_b, a_bits=None,
                                        granularity="per_group", group_size=128)

# paper Table 3 setup: weights fixed 8-bit, activations 2-bit, LUT forward
_REGISTRY["lq2_lut"] = QuantConfig(w_bits=8, a_bits=2, lut=True,
                                   granularity="per_group", group_size=128)
_REGISTRY["lq4_lut"] = QuantConfig(w_bits=8, a_bits=4, lut=True,
                                   granularity="per_group", group_size=128)


def register(name: str, cfg: QuantConfig) -> None:
    if name in _REGISTRY:
        raise ValueError(f"scheme {name!r} already registered")
    _REGISTRY[name] = cfg


def get(name_or_cfg) -> QuantConfig:
    if isinstance(name_or_cfg, QuantConfig):
        return name_or_cfg
    if name_or_cfg is None:
        return FP32
    try:
        return _REGISTRY[name_or_cfg]
    except KeyError:
        raise KeyError(f"unknown quant scheme {name_or_cfg!r}; "
                       f"known: {sorted(_REGISTRY)}") from None


def names() -> list[str]:
    return sorted(_REGISTRY)
