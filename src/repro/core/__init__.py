"""Core: the paper's contribution — local quantization regions (LQ).

Public surface:
  QTensor                       packed per-region tensor format
  quantize / dequantize / fake_quant / quant_error
  QuantConfig, schemes.get      scheme registry ("fp32", "dq8".."lq1", ...)
  lut.lut_matmul                paper section-V LUT forward
  calibration                   PTQ range observers
  qat.ste_fake_quant            QAT straight-through fake quant
  gradcomp                      LQ-block gradient compression (beyond paper)
"""
from .qtensor import QTensor, num_groups
from .quantize import quantize, dequantize, fake_quant, quant_error
from .schemes import QuantConfig, FP32, get as get_scheme, names as scheme_names
from . import packing, lut, calibration, qat, gradcomp

__all__ = [
    "QTensor", "num_groups", "quantize", "dequantize", "fake_quant",
    "quant_error", "QuantConfig", "FP32", "get_scheme", "scheme_names",
    "packing", "lut", "calibration", "qat", "gradcomp",
]
