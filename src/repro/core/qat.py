"""Quantization-aware training: straight-through-estimator fake quant.

The paper is post-training quantization only; QAT is the natural substrate
extension (training the model *through* the local-quantization-region
rounding so low-bit deployment loses less accuracy).  The STE passes
gradients through the round() as identity.
"""
from __future__ import annotations

from functools import partial

import jax

from .quantize import fake_quant as _fake_quant


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def ste_fake_quant(x, bits: int, group_size: int, granularity: str,
                   axis: int = -1):
    return _fake_quant(x, bits, group_size=group_size,
                       granularity=granularity, axis=axis)


def _fwd(x, bits, group_size, granularity, axis):
    return ste_fake_quant(x, bits, group_size, granularity, axis), None


def _bwd(bits, group_size, granularity, axis, _res, g):
    # straight-through: d(fake_quant)/dx ~= identity.  Min/max-derived affine
    # ranges cover every element, so no clip mask is needed.
    return (g,)


ste_fake_quant.defvjp(_fwd, _bwd)


def _gs_for(dim: int, group_size: int) -> int:
    """Clamp the region to the axis (small layers) keeping divisibility."""
    gs = min(group_size, dim)
    while dim % gs:
        gs -= 1
    return gs


def qat_dense_apply(w, x, cfg):
    """Dense forward with fake-quantized weights (+ activations if cfg'd).

    Both quantizers put regions along the contraction axis, so QAT sees
    exactly the rounding the deployed packed kernel will apply.
    """
    if cfg.w_bits is not None:
        # weights (K, N): regions along the contraction (first) axis
        w = ste_fake_quant(w, cfg.w_bits, _gs_for(w.shape[0],
                                                  cfg.group_size),
                           cfg.granularity, 0)
    if cfg.a_bits is not None:
        x = ste_fake_quant(x, cfg.a_bits, _gs_for(x.shape[-1],
                                                  cfg.group_size),
                           cfg.granularity, -1)
    return x @ w
