"""Look-up-table forward path (paper section V) + op-count accounting.

Paper idea: with n-bit activations there are only ``2^n`` distinct activation
codes, so the inner product of a local quantization region can be computed
without multiplies -- group the weights by their partner activation's code,
sum each bucket (adds / table writes), then combine the ``2^n`` bucket sums
with their code values (shifts + adds) and apply the region's dequantization
affine once.

Mathematically, for one region of size R with activation codes c_j in
[0, 2^n) and affine a_j = c_j * s + zmin:

    sum_j w_j a_j = s * sum_v v * T[v]  +  zmin * sum_j w_j
    where T[v] = sum_{j : c_j == v} w_j            ("the look-up table")

TPU adaptation (DESIGN.md section 5.2): T is a **one-hot partial-sum matmul**
with a binary {0,1} inner matrix -- the faithful dataflow, implemented both
here (pure jnp) and as a Pallas kernel (kernels/lut_matmul.py).  On TPU the
MXU has hardwired multipliers, so this path is the *fidelity / accounting*
implementation; the packed-int8 path (kernels/quant_matmul.py) is the
performance deployment.  The op-count model below reproduces paper Table 3.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Forward path
# ---------------------------------------------------------------------------

def lut_matmul(a_codes: jnp.ndarray, a_scale: jnp.ndarray, a_zmin: jnp.ndarray,
               w: jnp.ndarray, *, bits: int, group_size: int) -> jnp.ndarray:
    """LUT forward:  (M, K) n-bit activation codes  x  (K, N) float weights.

    a_codes: uint8 (M, K) with values in [0, 2^bits)
    a_scale, a_zmin: (M, G) per-(row, region) affine params, G = K // group_size
    Returns float32 (M, N) == dequantize(a) @ w  (up to float assoc.).
    """
    m, k = a_codes.shape
    n = w.shape[1]
    if k % group_size:
        raise ValueError(f"K={k} not divisible by group_size={group_size}")
    g = k // group_size
    v = 1 << bits

    codes = a_codes.reshape(m, g, group_size)
    # one-hot: binary {0,1} matrix (m, g, V, R) -- the "table build" dataflow
    onehot = (codes[:, :, None, :] == jnp.arange(v, dtype=codes.dtype)
              [None, None, :, None]).astype(jnp.float32)
    wg = w.astype(jnp.float32).reshape(g, group_size, n)
    # T[m, g, v, n] = sum over region elements with code v of w   (adds only)
    table = jnp.einsum("mgvr,grn->mgvn", onehot, wg)
    # combine buckets:  sum_v v * T[v]   (shift-adds in the paper's counting)
    vals = jnp.arange(v, dtype=jnp.float32)
    code_dot = jnp.einsum("v,mgvn->mgn", vals, table)
    # region affine:  s * code_dot + zmin * sum_j w_j    (1 mult per region)
    wsum = wg.sum(axis=1)                                    # (g, n)
    out = (a_scale[..., None] * code_dot
           + a_zmin[..., None] * wsum[None]).sum(axis=1)     # reduce regions
    return out


# ---------------------------------------------------------------------------
# Op-count accounting (paper Table 3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpCounts:
    multiplies: int
    adds: int

    def __add__(self, other: "OpCounts") -> "OpCounts":
        return OpCounts(self.multiplies + other.multiplies,
                        self.adds + other.adds)


def original_op_counts(macs: int) -> OpCounts:
    """Conventional multiply-accumulate: one multiply + one add per MAC."""
    return OpCounts(multiplies=macs, adds=macs)


def lut_op_counts(macs: int, *, bits: int, region_size: int) -> OpCounts:
    """Paper section V counting convention (reverse-engineered from Table 3).

    Per local region of ``region_size`` MACs with ``bits``-bit activations:

      * table build (bucket accumulation) is indexed table traffic, counted
        as table writes -- NOT ALU adds (this is the paper's convention;
        with it, Table 3's AlexNet row 666M->74M mult / 666M->222M add is
        reproduced exactly for region_size=9, bits=2);
      * bucket combine  sum_{v>0} v*T[v]  costs (2^bits - 1) adds (shifts
        free);
      * the region dequantization affine costs 1 multiply.

    So   multiplies = n_regions,  adds = n_regions * (2^bits - 1).
    """
    n_regions = macs // region_size
    return OpCounts(multiplies=n_regions,
                    adds=n_regions * ((1 << bits) - 1))


def reduction_summary(macs: int, *, bits: int, region_size: int) -> dict:
    base = original_op_counts(macs)
    lut = lut_op_counts(macs, bits=bits, region_size=region_size)
    return {
        "macs": macs,
        "orig_mult": base.multiplies, "orig_add": base.adds,
        "lut_mult": lut.multiplies, "lut_add": lut.adds,
        "mult_reduction": base.multiplies / max(lut.multiplies, 1),
        "add_reduction": base.adds / max(lut.adds, 1),
    }
