"""Quantize / dequantize with local quantization regions (paper section IV).

Two granularities:

  * ``per_tensor``  -- the prior "dynamic fixed point" scheme (DQ, eq. 6):
                       one (scale, zmin) for the whole tensor/layer.
  * ``per_group``   -- the paper's local-based quantization (LQ, eq. 7):
                       one (scale, zmin) per contiguous region of
                       ``group_size`` elements along ``axis``.

Both use the paper's asymmetric round-to-nearest affine map

    s     = (x_max - x_min) / (2^n - 1)               (eq. 5)
    Q(x)  = round((x - x_min) / s)                    (eq. 3)
    x_hat = Q(x) * s + x_min

Stochastic rounding is available for the QAT / gradient-compression paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import packing
from .qtensor import QTensor


def _affine_params(xmin, xmax, bits):
    levels = (1 << bits) - 1
    rng = xmax - xmin
    scale = jnp.where(rng > 0, rng / levels, jnp.ones_like(rng))
    return scale.astype(jnp.float32), xmin.astype(jnp.float32)


def _round(x, stochastic, key):
    if not stochastic:
        return jnp.round(x)
    noise = jax.random.uniform(key, x.shape, dtype=x.dtype) - 0.5
    return jnp.round(x + noise)


def quantize(x, bits: int, *, group_size: int | None = None, axis: int = -1,
             granularity: str = "per_group", stochastic: bool = False,
             key=None) -> QTensor:
    """Quantize ``x`` into a :class:`QTensor`.

    Layout contract: codes are stored with ``axis`` moved last (then packed);
    ``scale``/``zmin`` have shape ``(*other_dims, n_groups)`` for per_group
    and ``()`` for per_tensor.
    """
    x = jnp.asarray(x, jnp.float32)
    shape = tuple(x.shape)
    axis = axis % x.ndim
    xm = jnp.moveaxis(x, axis, -1)
    k = xm.shape[-1]
    levels = (1 << bits) - 1

    if granularity == "per_tensor":
        group_size = k
        scale, zmin = _affine_params(xm.min(), xm.max(), bits)
        q = _round((xm - zmin) / scale, stochastic, key)
    elif granularity == "per_group":
        if group_size is None:
            raise ValueError("per_group quantization needs group_size")
        if k % group_size:
            raise ValueError(f"axis dim {k} not divisible by group_size {group_size}")
        g = xm.reshape(*xm.shape[:-1], k // group_size, group_size)
        scale, zmin = _affine_params(g.min(-1), g.max(-1), bits)
        q = _round((g - zmin[..., None]) / scale[..., None], stochastic, key)
        q = q.reshape(*xm.shape)
    else:
        raise ValueError(f"unknown granularity {granularity!r}")

    codes = jnp.clip(q, 0, levels).astype(jnp.uint8)
    return QTensor(packed=packing.pack(codes, bits), scale=scale, zmin=zmin,
                   bits=bits, group_size=group_size, shape=shape, axis=axis)


def dequantize(qt: QTensor) -> jnp.ndarray:
    """Reconstruct the float32 array from a :class:`QTensor`."""
    axis = qt.axis
    k = qt.shape[axis]
    codes = packing.unpack(qt.packed, qt.bits, k).astype(jnp.float32)
    if qt.scale.ndim == 0:  # per_tensor
        xm = codes * qt.scale + qt.zmin
    else:
        g = codes.reshape(*codes.shape[:-1], k // qt.group_size, qt.group_size)
        xm = (g * qt.scale[..., None] + qt.zmin[..., None]).reshape(*codes.shape)
    return jnp.moveaxis(xm, -1, axis)


def fake_quant(x, bits: int, *, group_size: int | None = None, axis: int = -1,
               granularity: str = "per_group", stochastic: bool = False,
               key=None) -> jnp.ndarray:
    """quantize->dequantize without materializing packed codes (QAT path)."""
    x = jnp.asarray(x)
    dt = x.dtype
    xf = x.astype(jnp.float32)
    axis = axis % x.ndim
    xm = jnp.moveaxis(xf, axis, -1)
    k = xm.shape[-1]
    levels = (1 << bits) - 1
    if granularity == "per_tensor":
        scale, zmin = _affine_params(xm.min(), xm.max(), bits)
        q = jnp.clip(_round((xm - zmin) / scale, stochastic, key), 0, levels)
        out = q * scale + zmin
    else:
        if k % group_size:
            raise ValueError(f"axis dim {k} not divisible by group_size {group_size}")
        g = xm.reshape(*xm.shape[:-1], k // group_size, group_size)
        scale, zmin = _affine_params(g.min(-1), g.max(-1), bits)
        q = jnp.clip(_round((g - zmin[..., None]) / scale[..., None],
                            stochastic, key), 0, levels)
        out = (q * scale[..., None] + zmin[..., None]).reshape(*xm.shape)
    return jnp.moveaxis(out, -1, axis).astype(dt)


def quant_error(x, bits: int, **kw) -> jnp.ndarray:
    """Elementwise quantization error e_Q(x) = x - x_hat (paper eq. 4)."""
    return jnp.asarray(x, jnp.float32) - fake_quant(x, bits, **kw)
