"""QTensor: the packed local-quantization-region tensor format.

A QTensor stores a floating-point array as

  * ``packed``  -- uint8 bit-packed integer codes (see packing.py),
  * ``scale``   -- per-region quantization step  s_lk  (paper eq. 7),
  * ``zmin``    -- per-region minimum            x^lk_min,

so that  x_hat = codes * scale + zmin  within every local region.

Regions ("local quantization regions", paper section IV.C) are contiguous
blocks of ``group_size`` elements along a single *group axis* (the matmul
contraction axis for weights; the feature axis for activations).  The prior
"dynamic fixed point" scheme (paper section IV.B) is the degenerate case of a
single region spanning the whole tensor (``granularity='per_tensor'``).

QTensor is a registered pytree so it flows through jit / pjit / scan / psum
boundaries and can be stored directly inside model parameter pytrees.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import packing


@partial(jax.tree_util.register_dataclass,
         data_fields=("packed", "scale", "zmin"),
         meta_fields=("bits", "group_size", "shape", "axis"))
@dataclasses.dataclass(frozen=True)
class QTensor:
    packed: jnp.ndarray      # uint8, group axis moved last & bit-packed
    scale: jnp.ndarray       # f32, region grid shape (see quantize.py)
    zmin: jnp.ndarray        # f32, same shape as scale
    bits: int                # static
    group_size: int          # static; == size of the group axis for per_tensor
    shape: tuple             # static: original float shape
    axis: int                # static: group axis in the original shape

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def dtype(self):
        return jnp.float32

    def nbytes_ideal(self) -> int:
        """Bytes at the *nominal* bit-width (6-bit counts 6 bits) + metadata."""
        import numpy as np
        n = int(np.prod(self.shape))
        return (n * self.bits + 7) // 8 + self.scale.size * 4 + self.zmin.size * 4

    def nbytes_stored(self) -> int:
        return self.packed.size + self.scale.size * 4 + self.zmin.size * 4


def num_groups(dim: int, group_size: int) -> int:
    if dim % group_size:
        raise ValueError(f"group axis {dim} not divisible by group_size {group_size}")
    return dim // group_size
