"""LQ-block gradient compression for data-parallel all-reduce (beyond paper).

The multi-pod tie-in of the paper's technique: the *identical* local
quantization region format (per-group affine, section IV.C) is applied to
gradients before the data-parallel all-reduce, cutting cross-pod ICI/DCN
bytes by 4x (8-bit) or 8x (4-bit).  Error feedback (residual carried to the
next step) keeps SGD convergence unbiased-in-the-limit -- the standard
1-bit-Adam / PowerSGD-style correction.

Wire format per leaf: (codes uint8-packed, scale f32/G, zmin f32/G) --
compress -> all_gather(codes+affine) over the dp axis -> dequantize+mean.
Inside shard_map the gather moves exactly the compressed bytes; the HLO
collective-bytes parser (roofline/) then sees the reduction.

All functions are leaf-wise and pytree-mapped; flat (1-D-reshaped) leaves use
regions of ``group_size`` contiguous elements, mirroring Fig. 4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import packing
from .quantize import _affine_params  # shared affine derivation


def _pad_to(x, multiple):
    n = x.size
    pad = (-n) % multiple
    return jnp.pad(x.reshape(-1), (0, pad)), n


def compress_leaf(g: jnp.ndarray, bits: int, group_size: int):
    """Quantize one gradient leaf into the LQ wire format.

    Returns (packed codes uint8 (G, group_size/cpb), scale (G,), zmin (G,)).
    The leaf is flattened and zero-padded to a multiple of group_size.
    """
    flat, _ = _pad_to(g.astype(jnp.float32), group_size)
    grp = flat.reshape(-1, group_size)
    scale, zmin = _affine_params(grp.min(-1), grp.max(-1), bits)
    levels = (1 << bits) - 1
    codes = jnp.clip(jnp.round((grp - zmin[:, None]) / scale[:, None]),
                     0, levels).astype(jnp.uint8)
    return packing.pack(codes, bits), scale, zmin


def decompress_leaf(packed, scale, zmin, bits: int, group_size: int,
                    shape, size: int):
    codes = packing.unpack(packed, bits, group_size).astype(jnp.float32)
    flat = (codes * scale[:, None] + zmin[:, None]).reshape(-1)[:size]
    return flat.reshape(shape)


def compress(grads, bits: int = 8, group_size: int = 128):
    """Pytree-wide compression. Returns a pytree of wire triples."""
    return jax.tree.map(lambda g: compress_leaf(g, bits, group_size), grads,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))


def decompress(wire, like, bits: int = 8, group_size: int = 128):
    return jax.tree.map(
        lambda w, g: decompress_leaf(*w, bits, group_size, g.shape, g.size),
        wire, like, is_leaf=lambda x: isinstance(x, tuple))


def roundtrip_leaf(g, bits: int, group_size: int):
    """compress -> decompress one leaf (the quantization the wire applies)."""
    wire = compress_leaf(g, bits, group_size)
    return decompress_leaf(*wire, bits, group_size, g.shape, g.size)


# ---------------------------------------------------------------------------
# Error feedback
# ---------------------------------------------------------------------------

def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def apply_error_feedback(grads, err):
    """g' = g + e  (inject last step's quantization residual)."""
    return jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, err)


def new_error(grads_corrected, grads_quantized):
    """e' = g' - Q(g')  (residual left behind by this step's quantization)."""
    return jax.tree.map(lambda g, q: g - q, grads_corrected, grads_quantized)


def compressed_mean_over_axis(grads, axis_name: str, *, bits: int = 8,
                              group_size: int = 128):
    """Compressed data-parallel gradient mean, for use inside shard_map.

    Each replica quantizes its local gradient into the LQ wire format,
    all_gathers the compressed payload over ``axis_name`` (this is where the
    bytes cross the interconnect -- bits/32 of the fp32 volume), then
    dequantizes and averages locally.
    """
    def leaf(g):
        packed, scale, zmin = compress_leaf(g, bits, group_size)
        pk = jax.lax.all_gather(packed, axis_name)      # (R, G, gp)
        sc = jax.lax.all_gather(scale, axis_name)
        zm = jax.lax.all_gather(zmin, axis_name)
        codes = packing.unpack(pk, bits, group_size).astype(jnp.float32)
        vals = codes * sc[..., None] + zm[..., None]    # (R, G, group)
        flat = vals.mean(axis=0).reshape(-1)[:g.size]
        return flat.reshape(g.shape)
    return jax.tree.map(leaf, grads)
