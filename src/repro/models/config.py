"""ModelConfig: one dataclass covering every assigned architecture family.

A model is a stack of blocks; each block is (mixer, ffn) where mixer is one
of  attn | attn_nc | attn_local | attn_chunked | mamba2 | rglru  and ffn is
swiglu | gelu | moe | none.  ``pattern`` is the repeating block pattern
(scan-stacked superblocks + unscanned tail), which expresses dense LMs
(P=1), RecurrentGemma's rec-rec-attn 1:2 pattern, and Llama-4's
3-chunked:1-global layout uniformly.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|encdec|vlm|audio
    n_layers: int
    d_model: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                 # 0 -> d_model // n_heads
    d_ff: int = 0
    ffn_kind: str = "swiglu"          # swiglu | gelu
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 1e4
    pos_embed: str = "none"           # none (rope) | learned
    attn_bias: bool = False
    vocab_pad: int = 256              # embedding table padded to multiple
    tie_embeddings: bool = True
    # block pattern: tuple of (mixer, ffn) tuples
    pattern: tuple = (("attn", "swiglu"),)
    window: int = 0                   # local-attention window
    chunk: int = 0                    # chunked-attention chunk
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_ff: int = 0                # shared-expert hidden (Llama-4)
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_kernel: int = 4
    ssd_chunk: int = 256
    # RG-LRU
    lru_width: int = 0                # 0 -> d_model
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_len: int = 0                  # fixed encoder length (1500 frames)
    # modality frontend stubs
    frontend: str = "none"            # none | audio_stub | patch_stub
    n_patches: int = 0                # VLM patches prepended to the sequence
    frontend_dim: int = 0             # stub feature dim (pre-projection)
    # numerics
    dtype: str = "bfloat16"
    norm_kind: str = "rms"            # rms | layer (whisper)
    max_seq: int = 65536              # learned-pos table length
    remat: str = "full"               # none | full | dots

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family in ("hybrid",) and not self.lru_width:
            object.__setattr__(self, "lru_width", self.d_model)

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        v = self.vocab_size
        return -(-v // self.vocab_pad) * self.vocab_pad

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_super(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_tail(self) -> int:
        return self.n_layers % len(self.pattern)

    def layer_spec(self, i: int) -> tuple:
        return self.pattern[i % len(self.pattern)]

    def param_count(self) -> int:
        """Analytic parameter count N (used for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.padded_vocab
        total = v * d                                    # embedding
        if not self.tie_embeddings:
            total += v * d
        for i in range(self.n_layers):
            mixer, ffn = self.layer_spec(i)
            total += self._mixer_params(mixer) + self._ffn_params(ffn)
            total += 2 * d                               # norms
        if self.n_enc_layers:
            for _ in range(self.n_enc_layers):
                total += self._mixer_params("attn") + self._ffn_params(
                    self.ffn_kind) + 2 * d
            total += self.n_layers * (self._mixer_params("attn") + d)  # cross
        return total

    def _mixer_params(self, mixer: str) -> int:
        d = self.d_model
        if mixer.startswith("attn"):
            hd = self.head_dim
            return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        if mixer == "mamba2":
            din = self.ssm_expand * d
            gn = self.ssm_groups * self.ssm_state
            nh = din // self.ssm_head_dim
            in_dim = 2 * din + 2 * gn + nh
            return d * in_dim + din * d + self.conv_kernel * (din + 2 * gn)
        if mixer == "rglru":
            w = self.lru_width or d
            return 2 * d * w + 2 * w * w + w * d + self.conv_kernel * w
        raise ValueError(mixer)

    def _ffn_params(self, ffn: str) -> int:
        d = self.d_model
        if ffn == "none":
            return 0
        if ffn == "swiglu":
            return 3 * d * self.d_ff
        if ffn == "gelu":
            return 2 * d * self.d_ff
        if ffn == "moe":
            total = d * self.n_experts \
                + self.n_experts * 3 * d * self.moe_d_ff
            if self.shared_ff:
                total += 3 * d * self.shared_ff
            return total
        raise ValueError(ffn)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        per_expert = 3 * d * self.moe_d_ff
        inactive = (self.n_experts - self.top_k) * per_expert
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.layer_spec(i)[1] == "moe")
        return self.param_count() - n_moe_layers * inactive
