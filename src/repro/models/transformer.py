"""Transformer LM assembly: scan-stacked blocks, enc-dec, caches, quantized
serving.

Layer stacking: the repeating block ``pattern`` (P positions) is scan-stacked
-- params for pattern position j are stacked (S, ...) over S = n_layers // P
superblocks and iterated with ``lax.scan`` (compact HLO at 94-layer scale);
the n_layers % P remainder is an unscanned tail.  Caches mirror the same
(S, ...) layout.

Public surface:
  init_params(cfg, key)                  -> params
  forward(params, cfg, batch, policy)    -> (logits, aux)      [train path]
  init_cache(cfg, batch, max_len)        -> cache
  prefill(params, cfg, batch, cache,
          policy)                        -> (logits, cache)
  decode_step(params, cfg, tokens, cache,
              policy)                    -> (logits, cache)
  paged_decode_step(params, cfg, tokens, pages,
                    page_table, pos, policy) -> (logits, pages)
  quantize_params(params, cfg, qcfg)     -> params with QWeight leaves
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention, layers, mamba2, mlp, moe, rglru
from .config import ModelConfig
from .layers import PlanPolicy, QuantPolicy, NO_QUANT
from repro.core import kvwire, schemes
from repro.distributed.actshard import constrain
from repro.kernels import ops as kops


def _base_policy(policy):
    """Collapse a per-layer PlanPolicy to its uniform base (encoder/embed)."""
    if isinstance(policy, PlanPolicy):
        return QuantPolicy(policy.mode, policy.base_cfg, policy.backend)
    return policy


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def _norm_init(cfg, dtype):
    if cfg.norm_kind == "layer":
        return layers.layernorm_init(cfg.d_model, dtype)
    return layers.rmsnorm_init(cfg.d_model, dtype)


def _norm_apply(cfg, p, x):
    if cfg.norm_kind == "layer":
        return layers.layernorm_apply(p, x)
    return layers.rmsnorm_apply(p, x)


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, spec, *, cross: bool = False,
               dtype=jnp.float32):
    mixer, ffn = spec
    ks = jax.random.split(key, 4)
    p = {"norm1": _norm_init(cfg, dtype)}
    if mixer.startswith("attn"):
        p["mixer"] = attention.attn_init(
            ks[0], d_model=cfg.d_model, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim, qk_norm=cfg.qk_norm,
            bias=cfg.attn_bias, dtype=dtype)
    elif mixer == "mamba2":
        p["mixer"] = mamba2.mamba2_init(
            ks[0], d_model=cfg.d_model, d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
            n_groups=cfg.ssm_groups, conv_kernel=cfg.conv_kernel, dtype=dtype)
    elif mixer == "rglru":
        p["mixer"] = rglru.rglru_init(
            ks[0], d_model=cfg.d_model, width=cfg.lru_width,
            conv_kernel=cfg.conv_kernel, dtype=dtype)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")

    if cross:
        p["norm_cross"] = _norm_init(cfg, dtype)
        p["cross"] = attention.attn_init(
            ks[1], d_model=cfg.d_model, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            bias=cfg.attn_bias, dtype=dtype)

    if ffn != "none":
        p["norm2"] = _norm_init(cfg, dtype)
        if ffn == "moe":
            p["ffn"] = moe.moe_init(ks[2], d_model=cfg.d_model,
                                    d_ff=cfg.moe_d_ff,
                                    n_experts=cfg.n_experts,
                                    n_shared_ff=cfg.shared_ff, dtype=dtype)
        else:
            p["ffn"] = mlp.ffn_init(ks[2], ffn, cfg.d_model, cfg.d_ff, dtype)
    return p


def _attn_kind(mixer: str):
    return {"attn": ("full", True, None),
            "attn_nc": ("full", False, None),
            "attn_local": ("local", True, "window"),
            "attn_chunked": ("chunked", True, "chunk")}[mixer]


def block_apply(p, x, spec, cfg: ModelConfig, *, policy: QuantPolicy,
                cache=None, cache_pos=None, enc_out=None, positions=None,
                page_table=None, fused=None):
    """Returns (x, new_cache, aux)."""
    mixer, ffn = spec
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if cache is not None else None

    h = _norm_apply(cfg, p["norm1"], x)
    if mixer.startswith("attn"):
        kind, causal, wattr = _attn_kind(mixer)
        window = getattr(cfg, wattr) if wattr else None
        self_cache = cache.get("self") if cache else None
        out, sc = attention.attn_apply(
            p["mixer"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, kind=kind, causal=causal, window=window,
            qk_norm=cfg.qk_norm, rope=cfg.rope, rope_theta=cfg.rope_theta,
            positions=positions, cache=self_cache, cache_pos=cache_pos,
            page_table=page_table, fused=fused, policy=policy)
        if cache is not None:
            new_cache["self"] = sc
    elif mixer == "mamba2":
        out, sc = mamba2.mamba2_apply(
            p["mixer"], h, d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
            expand=cfg.ssm_expand, n_groups=cfg.ssm_groups,
            conv_kernel=cfg.conv_kernel, chunk=cfg.ssd_chunk,
            cache=cache.get("self") if cache else None, policy=policy)
        if cache is not None:
            new_cache["self"] = sc
    else:  # rglru
        out, sc = rglru.rglru_apply(
            p["mixer"], h, conv_kernel=cfg.conv_kernel,
            cache=cache.get("self") if cache else None, policy=policy)
        if cache is not None:
            new_cache["self"] = sc
    x = x + out

    if "cross" in p:
        h = _norm_apply(cfg, p["norm_cross"], x)
        ccache = cache.get("cross") if cache else None
        if ccache is not None and enc_out is None:
            # decode: attend over precomputed encoder K/V
            b, l, _ = h.shape
            g = cfg.n_heads // cfg.n_kv_heads
            q = layers.dense_apply(p["cross"]["wq"], h, policy).reshape(
                b, l, cfg.n_kv_heads, g, cfg.head_dim)
            out = attention.decode_attention(
                q, ccache["k"], ccache["v"], ccache["k"].shape[1] - 1)
            out = out.reshape(b, l, cfg.n_heads * cfg.head_dim)
            out = layers.dense_apply(p["cross"]["wo"], out, policy)
        else:
            out, _ = attention.attn_apply(
                p["cross"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim, kind="cross", kv_src=enc_out,
                rope=False, policy=policy)
            if cache is not None:
                # prefill: persist encoder K/V for decode
                b = enc_out.shape[0]
                lk = enc_out.shape[1]
                k = layers.dense_apply(p["cross"]["wk"], enc_out, policy
                                       ).reshape(b, lk, cfg.n_kv_heads,
                                                 cfg.head_dim)
                v = layers.dense_apply(p["cross"]["wv"], enc_out, policy
                                       ).reshape(b, lk, cfg.n_kv_heads,
                                                 cfg.head_dim)
                new_cache["cross"] = {"k": k.astype(ccache["k"].dtype),
                                      "v": v.astype(ccache["v"].dtype)}
        x = x + out

    if ffn != "none":
        h = _norm_apply(cfg, p["norm2"], x)
        if ffn == "moe":
            out, aux = moe.moe_apply(
                p["ffn"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, policy=policy)
        else:
            out = mlp.ffn_apply(p["ffn"], h, ffn, policy)
        x = x + out
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# block cache construction
# ---------------------------------------------------------------------------

def _block_cache(cfg: ModelConfig, spec, batch: int, max_len: int,
                 cross: bool, dtype, kv_quant=None):
    mixer, _ = spec
    c = {}
    if mixer.startswith("attn"):
        if mixer == "attn_local":
            s = min(max_len, cfg.window)
        elif mixer == "attn_chunked":
            s = min(max_len, cfg.chunk)
        else:
            s = max_len
        kv = (batch, s, cfg.n_kv_heads, cfg.head_dim)
        if kv_quant is not None:
            # LQ-quantized KV cache (paper's runtime input quantization
            # mapped to serving; core/kvwire.py wire format)
            bits, gs = kv_quant
            c["self"] = {"k": kvwire.make_quant_kv(kv, bits, gs),
                         "v": kvwire.make_quant_kv(kv, bits, gs)}
        else:
            c["self"] = {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}
    elif mixer == "mamba2":
        c["self"] = mamba2.mamba2_init_cache(
            batch, d_model=cfg.d_model, d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
            n_groups=cfg.ssm_groups, conv_kernel=cfg.conv_kernel, dtype=dtype,
            state_quant=kv_quant)
    else:
        c["self"] = rglru.rglru_init_cache(
            batch, width=cfg.lru_width or cfg.d_model,
            conv_kernel=cfg.conv_kernel, dtype=dtype)
    if cross:
        kv = (batch, cfg.enc_len, cfg.n_kv_heads, cfg.head_dim)
        c["cross"] = {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}
    return c


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _stack_init(key, cfg: ModelConfig, pattern, n_layers: int, *,
                cross: bool, dtype):
    p_len = len(pattern)
    n_super, n_tail = n_layers // p_len, n_layers % p_len
    keys = jax.random.split(key, n_layers + 1)
    supers = []
    for j, spec in enumerate(pattern):
        layer_keys = jnp.stack([keys[s * p_len + j] for s in range(n_super)])
        init_one = functools.partial(block_init, cfg=cfg, spec=spec,
                                     cross=cross, dtype=dtype)
        supers.append(jax.vmap(init_one)(layer_keys))
    tail = [block_init(keys[n_super * p_len + t], cfg,
                       pattern[(n_super * p_len + t) % p_len],
                       cross=cross, dtype=dtype)
            for t in range(n_tail)]
    return {"super": tuple(supers), "tail": tail}


def _maybe_remat(fn, cfg: ModelConfig, training: bool):
    if not training or cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _stack_apply(params, x, cfg: ModelConfig, pattern, *,
                 policy: QuantPolicy, caches=None, cache_pos=None,
                 enc_out=None, positions=None, page_table=None,
                 fused=None, training=False):
    """Run scan-stacked superblocks + tail.  Returns (x, caches, aux).

    With a uniform :class:`QuantPolicy` (and unsegmented params) every
    superblock runs one shared scan body.  A per-layer
    :class:`PlanPolicy` — or params pre-segmented by
    ``quantize_params(plan)`` — routes to the segmented walker, which
    scans each run of identically-configured superblocks separately.
    """
    if isinstance(policy, PlanPolicy) or "super_segments" in params \
            or (caches is not None and "super_segments" in caches):
        return _stack_apply_planned(
            params, x, cfg, pattern, policy=policy, caches=caches,
            cache_pos=cache_pos, enc_out=enc_out, positions=positions,
            page_table=page_table, fused=fused, training=training)
    aux_total = jnp.zeros((), jnp.float32)

    def body(carry, xs):
        xx, aux_acc = carry
        blk_params, blk_caches = xs
        new_caches = []
        for j, spec in enumerate(pattern):
            cj = blk_caches[j] if blk_caches is not None else None
            xx, nc, aux = block_apply(blk_params[j], xx, spec, cfg,
                                      policy=policy, cache=cj,
                                      cache_pos=cache_pos, enc_out=enc_out,
                                      positions=positions,
                                      page_table=page_table, fused=fused)
            xx = constrain(xx, "batch", "seq", "embed")
            new_caches.append(nc)
        out_caches = tuple(new_caches) if blk_caches is not None else None
        return (xx, aux_acc + aux), out_caches

    body = _maybe_remat(body, cfg, training)
    sup_caches = caches["super"] if caches is not None else None
    xs = (params["super"], sup_caches)
    if params["super"]:
        (x, aux_total), new_sup = jax.lax.scan(body, (x, aux_total), xs)
    else:
        new_sup = sup_caches

    new_tail = []
    for t, tp in enumerate(params["tail"]):
        spec = pattern[t % len(pattern)]
        ct = caches["tail"][t] if caches is not None else None
        x, nc, aux = block_apply(tp, x, spec, cfg, policy=policy, cache=ct,
                                 cache_pos=cache_pos, enc_out=enc_out,
                                 positions=positions, page_table=page_table,
                                 fused=fused)
        aux_total = aux_total + aux
        new_tail.append(nc)

    new_caches = None
    if caches is not None:
        new_caches = {"super": new_sup, "tail": new_tail}
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# per-layer (planned) stack walker
# ---------------------------------------------------------------------------

def plan_segments(configs, p_len: int, n_super: int) -> list:
    """Group consecutive superblocks whose per-position configs match.

    Returns ``[(start_super, size, per_position_cfgs), ...]`` — the
    maximal runs a single scan body can cover, so a mostly-uniform plan
    stays nearly as compact as the uniform scan.  ``configs`` entries may
    be any hashable per-layer key: plain :class:`QuantConfig` for a
    weight-only plan, or ``(QuantConfig, kv_bits)`` pairs when the plan
    also assigns per-layer cache bitwidths — a segment must be uniform in
    *both* so its stacked cache leaves share one wire shape.
    """
    return kvwire.segment_runs(configs, p_len, n_super)


def _policy_kv_list(policy, n_layers: int) -> tuple:
    """Per-layer cache bits a (possibly uniform) policy implies."""
    kv = getattr(policy, "kv_bits", ()) or ()
    return tuple(kv) if kv else (None,) * n_layers


def _combined_segments(per_layer, kv_list, p_len: int, n_super: int) -> list:
    """Walker segments keyed on (weight cfg, kv bits) per layer."""
    keys = [(pol.cfg, kv_list[i]) for i, pol in enumerate(per_layer)]
    return plan_segments(keys, p_len, n_super)


def _stack_apply_planned(params, x, cfg: ModelConfig, pattern, *, policy,
                         caches=None, cache_pos=None, enc_out=None,
                         positions=None, page_table=None, fused=None,
                         training=False):
    """Segmented stack walk: one lax.scan per run of identically-configured
    superblocks, per-layer policies for the tail.  Cache layout is
    IDENTICAL to the uniform path — segments slice and re-concatenate the
    (n_super, ...) leading axis inside the jit, so serve pools, wire
    scatter and checkpoints see the same pytrees either way.
    """
    p_len = len(pattern)
    segmented = "super_segments" in params
    if isinstance(policy, PlanPolicy):
        per_layer = [policy.layer(i) for i in range(policy.n_layers)]
        kv_list = _policy_kv_list(policy, policy.n_layers)
    else:
        per_layer = [policy] * cfg.n_layers
        kv_list = _policy_kv_list(policy, cfg.n_layers)
    n_super = len(per_layer) // p_len
    n_tail = len(per_layer) - n_super * p_len
    if segmented:
        seg_param_list = params["super_segments"]
    segs = _combined_segments(per_layer, kv_list, p_len, n_super)
    if segmented and len(segs) != len(seg_param_list):
        raise ValueError(
            f"policy implies {len(segs)} segments but params carry "
            f"{len(seg_param_list)} — plan/params mismatch")

    aux_total = jnp.zeros((), jnp.float32)
    sup_caches = cache_runs = None
    if caches is not None:
        if "super_segments" in caches:
            # heterogeneous cache: one stacked tree per run of superblocks
            # sharing a kv wire shape (serve/pool.py page geometry)
            cache_runs = list(caches["super_segments"])
            run_sizes = [jax.tree.leaves(r)[0].shape[0] for r in cache_runs]
            run_starts = [sum(run_sizes[:i]) for i in range(len(run_sizes))]
        else:
            sup_caches = caches["super"]
    new_sup_parts = []
    new_run_parts = [[] for _ in (cache_runs or ())]

    def _cache_run(start, size):
        """The kv run holding walker segment [start, start+size)."""
        for r, (rs, rn) in enumerate(zip(run_starts, run_sizes)):
            if rs <= start and start + size <= rs + rn:
                return r, start - rs
        raise ValueError(
            f"walker segment [{start}, {start + size}) straddles the "
            f"cache's kv runs {list(zip(run_starts, run_sizes))} — "
            f"plan/cache kv_bits mismatch")

    for k, (start, size, _) in enumerate(segs):
        seg_policies = tuple(per_layer[start * p_len + j]
                             for j in range(p_len))
        if segmented:
            seg_params = seg_param_list[k]
        else:
            seg_params = jax.tree.map(lambda a: a[start:start + size],
                                      params["super"])
        seg_caches = run = None
        if cache_runs is not None:
            run, off = _cache_run(start, size)
            seg_caches = cache_runs[run]
            if size != run_sizes[run]:
                seg_caches = jax.tree.map(lambda a: a[off:off + size],
                                          seg_caches)
        elif sup_caches is not None:
            seg_caches = jax.tree.map(lambda a: a[start:start + size],
                                      sup_caches)

        def body(carry, xs, seg_policies=seg_policies):
            xx, aux_acc = carry
            blk_params, blk_caches = xs
            new_caches = []
            for j, spec in enumerate(pattern):
                cj = blk_caches[j] if blk_caches is not None else None
                xx, nc, aux = block_apply(blk_params[j], xx, spec, cfg,
                                          policy=seg_policies[j], cache=cj,
                                          cache_pos=cache_pos,
                                          enc_out=enc_out,
                                          positions=positions,
                                          page_table=page_table,
                                          fused=fused)
                xx = constrain(xx, "batch", "seq", "embed")
                new_caches.append(nc)
            out = tuple(new_caches) if blk_caches is not None else None
            return (xx, aux_acc + aux), out

        body = _maybe_remat(body, cfg, training)
        # one named scope per walker segment: xprof attributes device time
        # to the same stack runs serve_phase_ms{layer_run=...} reports
        with jax.named_scope(f"segment{k}"):
            (x, aux_total), new_seg = jax.lax.scan(
                body, (x, aux_total), (seg_params, seg_caches))
        if cache_runs is not None:
            new_run_parts[run].append(new_seg)
        elif sup_caches is not None:
            new_sup_parts.append(new_seg)

    def _concat(parts):
        if len(parts) == 1:
            return parts[0]
        return jax.tree.map(lambda *leaves: jnp.concatenate(leaves, axis=0),
                            *parts)

    new_sup = sup_caches
    new_runs = None
    if cache_runs is not None:
        new_runs = [_concat(parts) if parts else cache_runs[r]
                    for r, parts in enumerate(new_run_parts)]
    elif sup_caches is not None and new_sup_parts:
        new_sup = _concat(new_sup_parts)

    new_tail = []
    tail_params = params["tail"]
    if len(tail_params) != n_tail:
        raise ValueError(f"policy covers {n_tail} tail layers but params "
                         f"carry {len(tail_params)}")
    for t, tp in enumerate(tail_params):
        spec = pattern[t % p_len]
        ct = caches["tail"][t] if caches is not None else None
        with jax.named_scope(f"tail{t}"):
            x, nc, aux = block_apply(tp, x, spec, cfg,
                                     policy=per_layer[n_super * p_len + t],
                                     cache=ct, cache_pos=cache_pos,
                                     enc_out=enc_out, positions=positions,
                                     page_table=page_table, fused=fused)
        aux_total = aux_total + aux
        new_tail.append(nc)

    new_caches = None
    if caches is not None:
        if cache_runs is not None:
            new_caches = {"super_segments": new_runs, "tail": new_tail}
        else:
            new_caches = {"super": new_sup, "tail": new_tail}
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.float32  # master params; compute casts to cfg.dtype
    ks = jax.random.split(key, 8)
    p = {"embed": layers.embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                                    dtype),
         "final_norm": _norm_init(cfg, dtype)}
    cross = cfg.n_enc_layers > 0
    p["decoder"] = _stack_init(ks[1], cfg, cfg.pattern, cfg.n_layers,
                               cross=cross, dtype=dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(ks[2], cfg.d_model,
                                         cfg.padded_vocab, dtype=dtype)
    if cfg.pos_embed == "learned":
        p["pos"] = layers.posembed_init(ks[3], cfg.max_seq, cfg.d_model,
                                        dtype)
    if cross:
        enc_pattern = (("attn_nc", cfg.ffn_kind),)
        p["encoder"] = _stack_init(ks[4], cfg, enc_pattern, cfg.n_enc_layers,
                                   cross=False, dtype=dtype)
        p["enc_norm"] = _norm_init(cfg, dtype)
        p["enc_pos"] = layers.posembed_init(ks[5], cfg.enc_len, cfg.d_model,
                                            dtype)
    if cfg.frontend != "none":
        fdim = cfg.frontend_dim or cfg.d_model
        p["frontend"] = layers.dense_init(ks[6], fdim, cfg.d_model,
                                          dtype=dtype)
    return p


def encode(params, cfg: ModelConfig, frames, *, policy=NO_QUANT,
           training=False):
    """Whisper-style encoder: frames (B, enc_len, frontend_dim) -> states."""
    policy = _base_policy(policy)      # plans cover the decoder stack only
    x = layers.dense_apply(params["frontend"], frames, policy)
    x = layers.posembed_apply(params["enc_pos"], x)
    x = x.astype(cfg.activation_dtype)
    enc_pattern = (("attn_nc", cfg.ffn_kind),)
    x, _, _ = _stack_apply(params["encoder"], x, cfg, enc_pattern,
                           policy=policy, training=training)
    return _norm_apply(cfg, params["enc_norm"], x)


def _embed_inputs(params, cfg: ModelConfig, batch, policy):
    """Token embedding (+ VLM patch prefix).  Returns (x, n_prefix)."""
    x = layers.embed_apply(params["embed"], batch["tokens"])
    n_prefix = 0
    if cfg.frontend == "patch_stub":
        patches = layers.dense_apply(params["frontend"], batch["patches"],
                                     policy)
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        n_prefix = patches.shape[1]
    return x, n_prefix


def forward(params, cfg: ModelConfig, batch, *, policy: QuantPolicy = NO_QUANT,
            training: bool = True):
    """Full-sequence forward (training / eval).  Returns (logits, aux).

    batch: {'tokens': (B, L) int32} + optional 'frames' (audio) /
    'patches' (VLM).
    """
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = encode(params, cfg, batch["frames"], policy=policy,
                         training=training)
    x, _ = _embed_inputs(params, cfg, batch, policy)
    if cfg.pos_embed == "learned":
        x = layers.posembed_apply(params["pos"], x)
    x = constrain(x.astype(cfg.activation_dtype), "batch", "seq", "embed")
    x, _, aux = _stack_apply(params["decoder"], x, cfg, cfg.pattern,
                             policy=policy, enc_out=enc_out,
                             training=training)
    x = _norm_apply(cfg, params["final_norm"], x)
    logits = _logits(params, cfg, x, policy)
    return logits, aux


def _logits(params, cfg: ModelConfig, x, policy):
    if cfg.tie_embeddings:
        logits = layers.embed_logits(params["embed"], x, cfg.vocab_size)
    else:
        logits = layers.dense_apply(params["lm_head"], x, policy)
        if cfg.vocab_size < cfg.padded_vocab:
            logits = logits.at[..., cfg.vocab_size:].set(-1e9)
    # vocab dim sharded over "model": a replicated (B, L, V) fp32 buffer is
    # ~34 GiB/device at train_4k scale (dry-run iteration 1, §Perf)
    return constrain(logits.astype(jnp.float32), "batch", "seq", "vocab")


def normalize_kv_quant(cfg: ModelConfig, kv_quant):
    """Canonicalize a cache-quantization spec.

    ``kv_quant`` is ``None`` (fp), ``(bits, group_size)`` (uniform), or
    ``(per_layer_bits, group_size)`` with a length-``n_layers`` sequence of
    ``bits | None`` entries.  A per-layer map whose entries all agree
    collapses to the uniform form, so a plan with a uniform ``kv_bits``
    map builds the exact same cache/pool pytree as the plain path.
    """
    if kv_quant is None:
        return None
    bits, gs = kv_quant
    if isinstance(bits, (tuple, list)):
        bits = tuple(bits)
        if len(bits) != cfg.n_layers:
            raise ValueError(f"per-layer kv_bits has {len(bits)} entries "
                             f"for {cfg.n_layers} layers")
        for b in bits:
            kvwire.check_kv_bits(b)
        if any(b != bits[0] for b in bits):
            return (bits, gs)
        bits = bits[0]
    if bits is None:
        return None
    kvwire.check_kv_bits(bits)
    return (bits, gs)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None, kv_quant=None) -> dict:
    """Decode cache.  ``kv_quant=(bits, group_size)`` stores attention K/V
    in the LQ wire format (bits in {8,4,2,1}; group_size divides head_dim);
    ``bits`` may be a per-layer sequence (see :func:`normalize_kv_quant`),
    in which case superblocks are stacked per run of identical kv bits
    under a ``"super_segments"`` key — packed wire shapes differ across
    bitwidths, so heterogeneous layers cannot share one stacked array.
    """
    dtype = dtype or cfg.activation_dtype
    cross = cfg.n_enc_layers > 0
    kv_quant = normalize_kv_quant(cfg, kv_quant)
    p_len = len(cfg.pattern)
    per_layer = kv_quant is not None and isinstance(kv_quant[0], tuple)

    def layer_kvq(i: int):
        if not per_layer:
            return kv_quant
        b = kv_quant[0][i]
        return None if b is None else (b, kv_quant[1])

    def stacked(stack: int, spec, kvq):
        one = _block_cache(cfg, spec, batch, max_len, cross, dtype, kvq)
        return jax.tree.map(
            lambda a: jnp.zeros((stack,) + a.shape, a.dtype), one)

    tail = [_block_cache(cfg, cfg.pattern[(cfg.n_super * p_len + t) % p_len],
                         batch, max_len, cross, dtype,
                         layer_kvq(cfg.n_super * p_len + t))
            for t in range(cfg.n_tail)]
    out = {"tail": tail, "pos": jnp.zeros((), jnp.int32)}
    if per_layer:
        runs = plan_segments(list(kv_quant[0]), p_len, cfg.n_super)
        out["super_segments"] = [
            tuple(stacked(size, spec,
                          None if key[j] is None else (key[j], kv_quant[1]))
                  for j, spec in enumerate(cfg.pattern))
            for _, size, key in runs]
    else:
        out["super"] = tuple(stacked(cfg.n_super, spec, kv_quant)
                             for spec in cfg.pattern)
    return out


def _layer_caches(cache) -> dict:
    """The decoder-stack view of a cache dict (either super layout)."""
    key = "super_segments" if "super_segments" in cache else "super"
    return {key: cache[key], "tail": cache["tail"]}


def prefill(params, cfg: ModelConfig, batch, cache, *,
            policy: QuantPolicy = NO_QUANT, logits_pos=None):
    """Process the prompt, filling the cache.  Returns (logits_last, cache).

    ``logits_pos`` (traced scalar) selects which position's logits to
    return instead of the last — right-padded prompts (continuous-batching
    prefill buckets) read logits at their true last token; causal masking
    makes positions < logits_pos independent of the pad tail.
    """
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = encode(params, cfg, batch["frames"], policy=policy)
    x, _ = _embed_inputs(params, cfg, batch, policy)
    if cfg.pos_embed == "learned":
        x = layers.posembed_apply(params["pos"], x)
    x = x.astype(cfg.activation_dtype)
    l = x.shape[1]
    # named scopes are HLO metadata only (no numerics / retrace impact):
    # they label phases in xprof captures (repro.obs.profile)
    with jax.named_scope("prefill"):
        x, new_caches, _ = _stack_apply(
            params["decoder"], x, cfg, cfg.pattern, policy=policy,
            caches=_layer_caches(cache),
            cache_pos=None, enc_out=enc_out, positions=None)
    if logits_pos is None:
        x = x[:, -1:]
    else:
        x = jax.lax.dynamic_slice_in_dim(x, logits_pos, 1, axis=1)
    with jax.named_scope("lm_head"):
        x = _norm_apply(cfg, params["final_norm"], x)
        logits = _logits(params, cfg, x, policy)
    new_caches["pos"] = jnp.asarray(l, jnp.int32)
    return logits, new_caches


def decode_step(params, cfg: ModelConfig, tokens, cache, *,
                policy: QuantPolicy = NO_QUANT):
    """One decode step.  tokens (B, 1) int32.  Returns (logits, cache)."""
    pos = cache["pos"]
    x = layers.embed_apply(params["embed"], tokens)
    if cfg.pos_embed == "learned":
        x = layers.posembed_apply(params["pos"], x, offset=pos)
    x = x.astype(cfg.activation_dtype)
    with jax.named_scope("decode_step"):
        x, new_caches, _ = _stack_apply(
            params["decoder"], x, cfg, cfg.pattern, policy=policy,
            caches=_layer_caches(cache),
            cache_pos=pos, enc_out=None, positions=None)
    with jax.named_scope("lm_head"):
        x = _norm_apply(cfg, params["final_norm"], x)
        logits = _logits(params, cfg, x, policy)
    new_caches["pos"] = pos + 1
    return logits, new_caches


def paged_decode_step(params, cfg: ModelConfig, tokens, pages, page_table,
                      pos, *, policy: QuantPolicy = NO_QUANT, fused=None):
    """One continuous-batching decode step over a paged KV pool.

    tokens (B, 1) int32; pages {'super': ..., 'tail': ...} with shared
    (n_pages, page_size, KV, ...) leaves per layer; page_table (B, P) int32
    physical page ids per slot (scratch page 0 pads unused entries); pos
    (B,) int32 — the absolute position each slot's token is written at.
    Inactive slots point at the scratch page and are masked by the caller.
    ``fused`` ('pallas' | 'interpret' | None) routes every layer's
    attention through the fused paged kernel instead of gather+dequant.
    Returns (logits (B, 1, V), new pages).
    """
    if cfg.pos_embed == "learned":
        raise ValueError("paged decode needs per-slot positions; learned "
                         "positional embeddings are not supported")
    x = layers.embed_apply(params["embed"], tokens)
    x = x.astype(cfg.activation_dtype)
    with jax.named_scope("paged_decode_step"):
        x, new_pages, _ = _stack_apply(
            params["decoder"], x, cfg, cfg.pattern, policy=policy,
            caches=_layer_caches(pages),
            cache_pos=pos, enc_out=None, positions=pos[:, None],
            page_table=page_table, fused=fused)
    with jax.named_scope("lm_head"):
        x = _norm_apply(cfg, params["final_norm"], x)
        logits = _logits(params, cfg, x, policy)
    return logits, new_pages


def paged_decode_multi(params, cfg: ModelConfig, tokens, pages, page_table,
                       pos, *, policy: QuantPolicy = NO_QUANT, fused=None):
    """Length-L batched decode over the paged pool — the speculative
    verify step (one compiled forward scores all L candidate tokens).

    tokens (B, L) int32 — slot b's candidate run, whose token i sits at
    absolute position ``pos[b] + i``; pages / page_table / pos as in
    :func:`paged_decode_step`.  Every layer scatters all L tokens' K/V
    into the slot's pages, then attends causally (query i over cache
    positions ``<= pos + i``, which includes candidates 0..i).  Returns
    (logits (B, L, V), new pages) — logits at *every* position, so the
    caller can greedy-score the whole run and accept the longest matching
    prefix.  L == 1 reduces exactly to :func:`paged_decode_step`.
    """
    if cfg.pos_embed == "learned":
        raise ValueError("paged decode needs per-slot positions; learned "
                         "positional embeddings are not supported")
    l = tokens.shape[1]
    x = layers.embed_apply(params["embed"], tokens)
    x = x.astype(cfg.activation_dtype)
    positions = pos[:, None] + jnp.arange(l)[None]
    with jax.named_scope("paged_decode_multi"):
        x, new_pages, _ = _stack_apply(
            params["decoder"], x, cfg, cfg.pattern, policy=policy,
            caches=_layer_caches(pages),
            cache_pos=pos, enc_out=None, positions=positions,
            page_table=page_table, fused=fused)
    with jax.named_scope("lm_head"):
        x = _norm_apply(cfg, params["final_norm"], x)
        logits = _logits(params, cfg, x, policy)
    return logits, new_pages


# ---------------------------------------------------------------------------
# quantized serving params (the paper's technique as deployment format)
# ---------------------------------------------------------------------------

_EXCLUDE_KEYS = {"router"}          # fp32-sensitive leaves


def _quantize_tree(tree, qcfg: schemes.QuantConfig):
    """Pack every Dense weight in ``tree`` under one QuantConfig."""
    if qcfg.w_bits is None:
        return tree
    bits, gs = qcfg.w_bits, qcfg.group_size

    def quant_w(w):
        if w.ndim == 2:
            return kops.quantize_weight(w, bits, gs)
        # stacked: (S, K, N) or (S, E, K, N) or (E, K, N)
        from repro.kernels import ref as kref
        flat = w.reshape((-1,) + w.shape[-2:])
        packed, scale, zmin = jax.vmap(
            lambda ww: kref.quantize_weight(ww, bits, gs))(flat)
        lead = w.shape[:-2]
        return kops.QWeight(
            packed=packed.reshape(lead + packed.shape[1:]),
            scale=scale.reshape(lead + scale.shape[1:]),
            zmin=zmin.reshape(lead + zmin.shape[1:]),
            bits=bits, group_size=gs, k=w.shape[-2], n=w.shape[-1])

    def walk(tree, path=()):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if k in _EXCLUDE_KEYS:
                    out[k] = v
                elif k == "w" and hasattr(v, "ndim") and v.ndim >= 2 \
                        and v.shape[-2] % gs == 0:
                    out[k] = quant_w(v)
                elif k in ("wi_gate", "wi_up", "wo") and hasattr(v, "ndim") \
                        and not isinstance(v, dict) and v.ndim >= 3 \
                        and v.shape[-2] % gs == 0:
                    out[k] = quant_w(v)       # MoE expert stacks
                else:
                    out[k] = walk(v, path + (k,))
            return out
        if isinstance(tree, (list, tuple)):
            t = [walk(v, path + (i,)) for i, v in enumerate(tree)]
            return type(tree)(t) if isinstance(tree, tuple) else t
        return tree

    return walk(tree)


def quantize_params(params, cfg: ModelConfig, qcfg, *,
                    leaf_cache: dict | None = None) -> dict:
    """Replace Dense weights with packed :class:`QWeight` (local quantization
    regions along the contraction axis).  Stacked (scan) and expert weights
    are quantized with vmap; norms / router / conv / scalar leaves stay fp.

    ``qcfg`` is either one :class:`QuantConfig` applied uniformly to the
    whole tree, or a :class:`repro.plan.QuantPlan` (anything exposing
    ``resolve(cfg)``): decoder layers are packed per the plan, with
    consecutive identically-configured superblocks re-stacked into
    ``super_segments`` so the planned scan walker keeps one compiled body
    per segment; non-decoder leaves (embed / lm_head / encoder) stay fp.

    ``leaf_cache`` dedups packed leaves across plans over ONE shared base
    checkpoint: segment subtrees are keyed on ``(start, size, position,
    QuantConfig)`` and re-used (same device buffers) when another plan
    produced the identical segment — the mechanism behind draft/verifier
    weight sharing in ``repro.spec`` and cross-tenant sharing in
    ``repro.fleet``.  Callers must pass one cache per base checkpoint;
    keys do not capture the fp params' identity.
    """
    if hasattr(qcfg, "resolve"):               # QuantPlan (duck-typed)
        return _quantize_params_plan(params, cfg, qcfg,
                                     leaf_cache=leaf_cache)
    return _quantize_tree(params, qcfg)


def is_quantized_params(params) -> bool:
    """Whether ``params`` already carry plan-packed decoder segments."""
    dec = params.get("decoder", {}) if isinstance(params, dict) else {}
    return "super_segments" in dec


def plan_leaf_keys(cfg: ModelConfig, plan) -> list:
    """The ``leaf_cache`` keys ``quantize_params(plan)`` reads/writes.

    One key per (segment, pattern position) stacked subtree plus one per
    tail layer; two plans share a packed leaf exactly when they produce
    the same key (same superblock range, position, and weight config) —
    kv bitwidths shape the segment *boundaries* but not the packed
    contents, so they appear only through the ranges.  This is how
    ``repro.spec`` counts draft/verifier sharing and ``repro.fleet``
    prices deduped tenants.
    """
    configs = plan.resolve(cfg)
    kv = (plan.resolve_kv(cfg) if hasattr(plan, "resolve_kv")
          else (None,) * cfg.n_layers)
    p_len = len(cfg.pattern)
    segs = plan_segments(list(zip(configs, kv)), p_len, cfg.n_super)
    keys = [("super", start, size, j, seg_key[j][0])
            for start, size, seg_key in segs for j in range(p_len)]
    keys += [("tail", t, configs[cfg.n_super * p_len + t])
             for t in range(cfg.n_tail)]
    return keys


def _quantize_params_plan(params, cfg: ModelConfig, plan, *,
                          leaf_cache: dict | None = None) -> dict:
    configs = plan.resolve(cfg)
    kv = (plan.resolve_kv(cfg) if hasattr(plan, "resolve_kv")
          else (None,) * cfg.n_layers)
    p_len = len(cfg.pattern)
    dec = params["decoder"]

    def cached(key, make):
        if leaf_cache is None:
            return make()
        if key not in leaf_cache:
            leaf_cache[key] = make()
        return leaf_cache[key]

    # segment on the combined (weight, kv) key so param segments line up
    # with the planned walker's — a kv boundary splits the scan even when
    # the weight scheme is unchanged across it
    segs = plan_segments(list(zip(configs, kv)), p_len, cfg.n_super)
    seg_trees = []
    for start, size, seg_key in segs:
        pos_trees = []
        for j in range(p_len):
            def make(start=start, size=size, j=j, qc=seg_key[j][0]):
                sub = jax.tree.map(lambda a: a[start:start + size],
                                   dec["super"][j])
                return _quantize_tree(sub, qc)
            pos_trees.append(cached(("super", start, size, j,
                                     seg_key[j][0]), make))
        seg_trees.append(tuple(pos_trees))
    tail = [cached(("tail", t, configs[cfg.n_super * p_len + t]),
                   lambda t=t, blk=blk, qc=configs[cfg.n_super * p_len + t]:
                   _quantize_tree(blk, qc))
            for t, blk in enumerate(dec["tail"])]
    out = dict(params)
    out["decoder"] = {"super_segments": seg_trees, "tail": tail}
    return out
