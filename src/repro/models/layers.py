"""Primitive layers: Dense (quantization-aware), norms, embeddings, RoPE.

Functional style: ``*_init(key, ...) -> params`` (nested dicts of arrays),
``*_apply(params, x, ...) -> y``.  Params are plain pytrees so they flow
through jit / pjit / scan and the checkpoint manager unchanged.

Quantization integration (the paper's technique as a first-class feature):
a Dense weight may be

  * a float array                     -- fp / QAT training path,
  * a :class:`repro.kernels.QWeight`  -- packed local-quantization-region
                                         deployment format; the forward pass
                                         dispatches to kernels.quant_matmul.

``QuantPolicy`` carries the scheme + mode through the model without
threading extra arguments everywhere.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import schemes, qat
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """How projections behave in the forward pass.

    mode:
      'none'   float weights, float activations
      'qat'    straight-through fake quant on weights (+acts if configured)
      'serve'  weights are QWeight (packed); optional runtime act quant / LUT
    """
    mode: str = "none"
    cfg: schemes.QuantConfig = schemes.FP32
    backend: str = "auto"      # kernel backend: auto | pallas | interpret | ref
    kv_fq: tuple | None = None  # (bits, group): fake-quant K/V when uncached

    @staticmethod
    def train_fp():
        return QuantPolicy("none", schemes.FP32)

    @staticmethod
    def serve(cfg, backend="auto"):
        return QuantPolicy("serve", schemes.get(cfg), backend)

    @staticmethod
    def qat(cfg):
        return QuantPolicy("qat", schemes.get(cfg))


NO_QUANT = QuantPolicy.train_fp()


@dataclasses.dataclass(frozen=True)
class PlanPolicy:
    """Per-layer quantization policy: one :class:`QuantConfig` per decoder
    layer (a resolved :class:`repro.plan.QuantPlan`).

    Quacks like a :class:`QuantPolicy` (mode / cfg / backend) for projections
    outside the planned stack — embedding, lm_head, frontend, encoder — which
    run under ``base_cfg`` (fp by default).  ``layer(i)`` yields the plain
    per-layer policy that ``block_apply`` consumes; the stack walker groups
    consecutive superblocks with identical configs so the scan stays compact.
    """
    mode: str                                   # 'qat' | 'serve'
    configs: tuple                              # per-layer QuantConfig
    backend: str = "auto"
    base_cfg: schemes.QuantConfig = schemes.FP32
    kv_bits: tuple = ()                         # per-layer cache bits | None
    kv_group: int = 64                          # cache local-region size

    @property
    def cfg(self) -> schemes.QuantConfig:
        return self.base_cfg

    def layer_kv(self, i: int) -> int | None:
        """Cache bitwidth of decoder layer ``i`` (None = fp cache)."""
        return self.kv_bits[i] if self.kv_bits else None

    def layer(self, i: int) -> QuantPolicy:
        kv = self.layer_kv(i)
        return QuantPolicy(self.mode, self.configs[i], self.backend,
                           kv_fq=None if kv is None else (kv, self.kv_group))

    @property
    def n_layers(self) -> int:
        return len(self.configs)


def policy_for_layer(policy, i: int) -> QuantPolicy:
    """Resolve a (possibly per-layer) policy for decoder/conv layer ``i``."""
    if isinstance(policy, PlanPolicy):
        return policy.layer(i)
    return policy


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, *, dtype=jnp.float32,
               bias: bool = False, scale: float | None = None):
    w_scale = scale if scale is not None else in_dim ** -0.5
    p = {"w": (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
               * w_scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_apply(p, x, policy: QuantPolicy = NO_QUANT):
    w = p["w"]
    if isinstance(w, kops.QWeight):
        cfg = policy.cfg
        y = kops.quant_dense(x, w, a_bits=cfg.a_bits, lut=cfg.lut,
                             backend=policy.backend)
    elif policy.mode == "qat" and policy.cfg.quantized:
        y = qat.qat_dense_apply(w.astype(jnp.float32),
                                x.astype(jnp.float32), policy.cfg)
        y = y.astype(x.dtype)
    else:
        y = x @ w.astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def quantize_dense(p, cfg: schemes.QuantConfig):
    """Convert a Dense param dict to the packed serving format."""
    if cfg.w_bits is None:
        return p
    w = p["w"].astype(jnp.float32)
    out = dict(p)
    out["w"] = kops.quantize_weight(w, cfg.w_bits, cfg.group_size)
    return out


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, dim), jnp.float32)
                      * dim ** -0.5).astype(dtype)}


def embed_apply(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def embed_logits(p, x, true_vocab: int | None = None):
    """Tied read-out: x @ table^T, padded vocab rows masked to -inf."""
    table = p["table"].astype(x.dtype)
    logits = x @ table.T
    if true_vocab is not None and true_vocab < table.shape[0]:
        pad = table.shape[0] - true_vocab
        neg = jnp.full((pad,), -1e9, logits.dtype)
        logits = logits.at[..., true_vocab:].set(neg)
    return logits


def posembed_init(key, max_len: int, dim: int, dtype=jnp.float32):
    return {"pos": (jax.random.normal(key, (max_len, dim), jnp.float32)
                    * 0.02).astype(dtype)}


def posembed_apply(p, x, offset=0):
    L = x.shape[-2]
    pos = jax.lax.dynamic_slice_in_dim(p["pos"], offset, L, axis=0)
    return x + pos.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x (..., L, H, D), positions (..., L) int32 -> same shape."""
    freqs = rope_freqs(x.shape[-1], theta)                     # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., L, D/2)
    cos = jnp.cos(ang)[..., None, :]                           # (..., L, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
