"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

Gated diagonal linear recurrence:

    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t)  per-channel decay, c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Prefill/train uses ``lax.associative_scan`` over the first-order linear
recurrence (O(log L) depth); decode is the O(1) step.  The block wraps the
recurrence with the RecurrentGemma residual-block plumbing: in-proj + short
causal conv, a gelu gate branch, and an out-proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .layers import QuantPolicy, NO_QUANT

_C = 8.0


def rglru_init(key, *, d_model: int, width: int | None = None,
               conv_kernel: int = 4, dtype=jnp.float32):
    width = width or d_model
    ks = jax.random.split(key, 6)
    # Lambda init so decay a in [0.9, 0.999] at r=0.5 (paper appendix)
    u = jax.random.uniform(ks[0], (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u ** (2.0 / _C))))  # softplus^-1
    return {
        "in_x": layers.dense_init(ks[1], d_model, width, dtype=dtype),
        "in_gate": layers.dense_init(ks[2], d_model, width, dtype=dtype),
        "conv_w": (jax.random.normal(ks[3], (conv_kernel, width),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "w_a": layers.dense_init(ks[4], width, width, dtype=dtype, bias=True),
        "w_x": layers.dense_init(ks[5], width, width, dtype=dtype, bias=True),
        "Lambda": lam,
        "out": layers.dense_init(
            jax.random.fold_in(key, 7), width, d_model, dtype=dtype),
    }


def _rglru_scan(x, r, i, lam, h0=None):
    """x, r, i: (B, L, W) f32.  Returns (h (B,L,W), h_last)."""
    log_a = -_C * jax.nn.softplus(lam)[None, None, :] * r       # (B,L,W) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)
    if h0 is not None:
        # fold h0 into the first step's injection
        gated = gated.at[:, 0].add(a[:, 0] * h0)
    def combine(l, r_):
        a1, b1 = l
        a2, b2 = r_
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h, h[:, -1]


def rglru_apply(p, x, *, conv_kernel: int = 4, cache=None,
                policy: QuantPolicy = NO_QUANT):
    """x (B, L, d_model) -> (y, new_cache).

    cache: {'conv': (B, K-1, W), 'h': (B, W)} for decode / cached prefill.
    """
    from .mamba2 import _causal_conv
    b, l, _ = x.shape
    xb = layers.dense_apply(p["in_x"], x, policy)
    gate = jax.nn.gelu(layers.dense_apply(p["in_gate"], x, policy))

    new_cache = cache
    if cache is None or l > 1:
        conv = _causal_conv(xb, p["conv_w"], p["conv_b"])
        if cache is not None:
            k = p["conv_w"].shape[0]
            tail = jnp.pad(xb, ((0, 0), (k - 1, 0), (0, 0)))[:, -(k - 1):]
    else:
        hist = jnp.concatenate([cache["conv"], xb], axis=1)
        conv = ((hist.astype(jnp.float32)
                 * p["conv_w"].astype(jnp.float32)).sum(1, keepdims=True)
                + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
        tail = hist[:, 1:]

    cf = conv.astype(jnp.float32)
    r = jax.nn.sigmoid(layers.dense_apply(p["w_a"], conv,
                                          policy).astype(jnp.float32))
    i = jax.nn.sigmoid(layers.dense_apply(p["w_x"], conv,
                                          policy).astype(jnp.float32))
    lam = p["Lambda"]

    if cache is None or l > 1:
        h0 = None if cache is None else cache["h"]
        h, h_last = _rglru_scan(cf, r, i, lam, h0=h0)
        if cache is not None:
            new_cache = {"conv": tail.astype(cache["conv"].dtype),
                         "h": h_last}
    else:
        log_a = -_C * jax.nn.softplus(lam)[None, None, :] * r
        a = jnp.exp(log_a)
        h = a * cache["h"][:, None] + jnp.sqrt(
            jnp.maximum(1.0 - a * a, 1e-12)) * (i * cf)
        new_cache = {"conv": tail, "h": h[:, -1]}

    y = h.astype(x.dtype) * gate
    return layers.dense_apply(p["out"], y, policy), new_cache


def rglru_init_cache(batch: int, *, width: int, conv_kernel: int = 4,
                     dtype=jnp.float32):
    return {"conv": jnp.zeros((batch, conv_kernel - 1, width), dtype),
            "h": jnp.zeros((batch, width), jnp.float32)}
