"""Mamba-2 (SSD, state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm: within-chunk quadratic ("attention-like") term plus
an inter-chunk linear recurrence over chunk states -- O(L * chunk) compute,
O(L) memory, lax.scan across chunks.  Decode is the O(1) recurrence

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;   y_t = C_t h_t + D x_t

LQR applicability (DESIGN.md section 4): in/out/x projections quantize like
any Dense; there is no KV cache, so the serving-cache quantization feature
maps to the recurrent state (serve/kvcache.py quantizes h with the same
per-region format).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .layers import QuantPolicy, NO_QUANT
from repro.core import kvwire


def mamba2_init(key, *, d_model: int, d_state: int, head_dim: int = 64,
                expand: int = 2, n_groups: int = 1, conv_kernel: int = 4,
                dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * n_groups * d_state
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * n_groups * d_state + n_heads
    return {
        "in_proj": layers.dense_init(ks[0], d_model, in_dim, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_kernel, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "norm": layers.rmsnorm_init(d_inner, dtype),
        "out_proj": layers.dense_init(ks[3], d_inner, d_model, dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d.  x (B, L, C), w (K, C) -> (B, L, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssd_chunked(xh, dt, a_head, bmat, cmat, chunk: int, h0=None):
    """Chunked SSD scan.

    xh (B,L,H,P); dt (B,L,H); a_head (H,) negative; bmat/cmat (B,L,G,N).
    Returns (y (B,L,H,P), h_final (B,H,P,N)).
    """
    b, l, h, p = xh.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    q = min(chunk, l)
    l_p = -(-l // q) * q
    if l_p != l:
        pad = l_p - l
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = l_p // q

    xb = (xh * dt[..., None]).astype(jnp.float32)               # dt-weighted
    a = (dt * a_head[None, None, :]).astype(jnp.float32)        # (B,L,H) <= 0
    ac = a.reshape(b, nc, q, h)
    cum = jnp.cumsum(ac, axis=2)                                # inclusive
    xc = xb.reshape(b, nc, q, h, p)
    bc = bmat.reshape(b, nc, q, g, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, g, n).astype(jnp.float32)

    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) (C_i . B_j) x~_j
    cb = jnp.einsum("bnqgs,bnkgs->bnqkg", cc, bc)               # (B,nc,Q,Q,G)
    cb = jnp.repeat(cb, rep, axis=-1)                           # -> heads
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    mask = jnp.tril(jnp.ones((q, q), bool))
    scores = jnp.where(mask[None, None, :, :, None], cb * decay, 0.0)
    y_intra = jnp.einsum("bnqkh,bnkhp->bnqhp", scores, xc)

    # chunk states: S_n = sum_k exp(cum_last - cum_k) B_k (x)_k
    sdecay = jnp.exp(cum[:, :, -1:, :] - cum)                   # (B,nc,Q,H)
    s_chunk = jnp.einsum("bnkgs,bnkh,bnkhp->bnhps",
                         bc, sdecay, xc)                        # (B,nc,H,P,N)
    cdecay = jnp.exp(cum[:, :, -1, :])                          # (B,nc,H)

    def step(hprev, inp):
        cd, s = inp                                             # (B,H),(B,H,P,N)
        hnew = cd[..., None, None] * hprev + s
        return hnew, hprev

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hfin, hprevs = jax.lax.scan(step, h0,
                                (jnp.moveaxis(cdecay, 1, 0),
                                 jnp.moveaxis(s_chunk, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)                         # (B,nc,H,P,N)

    # inter-chunk: y_i += exp(cum_i) C_i . h_{chunk-1}
    cexp = jnp.repeat(cc, rep, axis=3) if g != h else cc
    y_inter = jnp.einsum("bnqhs,bnqh,bnhps->bnqhp",
                         cexp, jnp.exp(cum), hprevs)
    y = (y_intra + y_inter).reshape(b, l_p, h, p)[:, :l]
    return y, hfin


def mamba2_apply(p, x, *, d_state: int, head_dim: int = 64, expand: int = 2,
                 n_groups: int = 1, conv_kernel: int = 4, chunk: int = 256,
                 cache=None, policy: QuantPolicy = NO_QUANT):
    """x (B, L, d_model) -> (y, new_cache).

    cache (decode): {'conv': (B, K-1, conv_dim), 'ssm': (B, H, P, N)}.
    L == 1 when cache is active (single-token decode); otherwise full scan.
    """
    b, l, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    gdim = n_groups * d_state

    # LQ-quantized recurrent state (the attention-free arch's "KV cache",
    # DESIGN.md §4): dequantize on entry, requantize on exit.
    squant = cache is not None and kvwire.is_quant_state(cache.get("ssm"))
    if squant:
        sbits, sgroup = kvwire._infer(cache["ssm"]["packed"].shape[-1],
                                      d_state, cache["ssm"]["scale"].shape[-1])
        cache = dict(cache, ssm=kvwire.dequantize_state(cache["ssm"],
                                                        d_state))

    zxbcdt = layers.dense_apply(p["in_proj"], x, policy)
    z, xr, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + gdim,
                 2 * d_inner + 2 * gdim], axis=-1)

    conv_in = jnp.concatenate([xr, bmat, cmat], axis=-1)
    new_cache = cache
    if cache is None or l > 1:
        conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"],
                                            p["conv_b"]))
        if cache is not None:  # prefill into cache: keep conv tail
            k = p["conv_w"].shape[0]
            tail = jnp.pad(conv_in, ((0, 0), (k - 1, 0), (0, 0)))[:, -(k - 1):]
            new_conv = tail.astype(cache["conv"].dtype)
    else:
        hist = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,K,C)
        conv_out = jax.nn.silu(
            (hist.astype(jnp.float32) * p["conv_w"].astype(jnp.float32)
             ).sum(axis=1, keepdims=True)
            + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
        new_conv = hist[:, 1:]

    xr, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + gdim], axis=-1)
    xh = xr.reshape(b, l, n_heads, head_dim)
    bmat = bmat.reshape(b, l, n_groups, d_state)
    cmat = cmat.reshape(b, l, n_groups, d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])          # (B,L,H)
    a_head = -jnp.exp(p["A_log"])                                # (H,) < 0

    if cache is None or l > 1:
        h0 = None if cache is None else cache["ssm"]
        y, hfin = _ssd_chunked(xh.astype(jnp.float32), dt, a_head,
                               bmat, cmat, chunk, h0=h0)
        new_cache = None if cache is None else {"conv": new_conv, "ssm": hfin}
    else:
        # O(1) decode recurrence
        h_prev = cache["ssm"]                                    # (B,H,P,N)
        rep = n_heads // n_groups
        b1 = jnp.repeat(bmat[:, 0], rep, axis=1)                 # (B,H,N)
        c1 = jnp.repeat(cmat[:, 0], rep, axis=1)
        dt1 = dt[:, 0]                                           # (B,H)
        decay = jnp.exp(dt1 * a_head[None, :])                   # (B,H)
        inject = (dt1[..., None, None]
                  * xh[:, 0].astype(jnp.float32)[..., None]
                  * b1[:, :, None, :].astype(jnp.float32))       # (B,H,P,N)
        h_new = decay[..., None, None] * h_prev + inject
        y = jnp.einsum("bhpn,bhn->bhp", h_new,
                       c1.astype(jnp.float32))[:, None]          # (B,1,H,P)
        hfin = h_new
        new_cache = {"conv": new_conv, "ssm": hfin}

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, l, d_inner).astype(x.dtype)
    y = layers.rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = layers.dense_apply(p["out_proj"], y, policy)
    if cache is None:
        return out, None
    if squant:
        new_cache = dict(new_cache, ssm=kvwire.quantize_state(
            new_cache["ssm"], sbits, sgroup))
    return out, new_cache


def mamba2_init_cache(batch: int, *, d_model: int, d_state: int,
                      head_dim: int = 64, expand: int = 2, n_groups: int = 1,
                      conv_kernel: int = 4, dtype=jnp.float32,
                      state_quant=None):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * n_groups * d_state
    ssm_shape = (batch, n_heads, head_dim, d_state)
    if state_quant is not None:
        bits, gs = state_quant
        ssm = kvwire.make_quant_kv(ssm_shape, bits, min(gs, d_state))
    else:
        ssm = jnp.zeros(ssm_shape, jnp.float32)
    return {
        "conv": jnp.zeros((batch, conv_kernel - 1, conv_dim), dtype),
        "ssm": ssm,
    }
