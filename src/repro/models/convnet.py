"""CNNs (the paper's own example models) via im2col -> quantized matmul.

Convolution is lowered to ``im2col`` patches x kernel matrix so every
conv/fc layer flows through the same quantization-aware Dense path
(``layers.dense_apply``) as the transformer projections — conv kernels get
local quantization regions along the patch (K = kh*kw*cin) axis exactly
like the paper's conv1 example (region 11x11x3 = 363, section VI.D).

Two uses:
  * exact AlexNet / VGG-16 layer shapes for the paper's op-count tables
    (ALEXNET / VGG16 configs + ``conv_macs``);
  * a reduced trainable CNN (``MINI_CNN``) for the accuracy benchmarks
    (synthetic classification stands in for ImageNet; DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers
from .layers import QuantPolicy, NO_QUANT


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    kind: str                  # conv | pool | fc
    out: int = 0               # channels (conv) / units (fc)
    kernel: int = 3
    stride: int = 1
    pad: int = 0
    groups: int = 1            # AlexNet's split convolutions


@dataclasses.dataclass(frozen=True)
class ConvConfig:
    name: str
    input_hw: int
    in_ch: int
    n_classes: int
    layers: tuple


# --- the paper's models (exact shapes; for op-count accounting) -----------

ALEXNET = ConvConfig(
    name="alexnet", input_hw=227, in_ch=3, n_classes=1000,  # Caffe's 227
    layers=(
        ConvLayer("conv", 96, 11, 4, 0),
        ConvLayer("pool", kernel=3, stride=2),
        ConvLayer("conv", 256, 5, 1, 2, groups=2),
        ConvLayer("pool", kernel=3, stride=2),
        ConvLayer("conv", 384, 3, 1, 1),
        ConvLayer("conv", 384, 3, 1, 1, groups=2),
        ConvLayer("conv", 256, 3, 1, 1, groups=2),
        ConvLayer("pool", kernel=3, stride=2),
        ConvLayer("fc", 4096),
        ConvLayer("fc", 4096),
        ConvLayer("fc", 1000),
    ))

_VGG = []
for ch, reps in [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]:
    _VGG += [ConvLayer("conv", ch, 3, 1, 1)] * reps
    _VGG += [ConvLayer("pool", kernel=2, stride=2)]
VGG16 = ConvConfig(name="vgg16", input_hw=224, in_ch=3, n_classes=1000,
                   layers=tuple(_VGG + [ConvLayer("fc", 4096),
                                        ConvLayer("fc", 4096),
                                        ConvLayer("fc", 1000)]))

# --- reduced trainable CNN (accuracy benchmarks) ---------------------------

MINI_CNN = ConvConfig(
    name="mini-cnn", input_hw=16, in_ch=3, n_classes=32,
    layers=(
        ConvLayer("conv", 16, 3, 1, 1),
        ConvLayer("pool", kernel=2, stride=2),
        ConvLayer("conv", 32, 3, 1, 1),
        ConvLayer("pool", kernel=2, stride=2),
        ConvLayer("fc", 128),
        ConvLayer("fc", 32),
    ))


# ---------------------------------------------------------------------------
# shape walking / op counting (paper Tables 3)
# ---------------------------------------------------------------------------

def walk_shapes(cfg: ConvConfig):
    """Yield (layer, h, w, cin, macs) for conv/fc layers."""
    h = w = cfg.input_hw
    c = cfg.in_ch
    out = []
    flat = None
    for layer in cfg.layers:
        if layer.kind == "conv":
            ho = (h + 2 * layer.pad - layer.kernel) // layer.stride + 1
            wo = (w + 2 * layer.pad - layer.kernel) // layer.stride + 1
            k = layer.kernel * layer.kernel * (c // layer.groups)
            macs = ho * wo * layer.out * k
            out.append((layer, ho, wo, c, macs))
            h, w, c = ho, wo, layer.out
        elif layer.kind == "pool":
            h = (h - layer.kernel) // layer.stride + 1
            w = (w - layer.kernel) // layer.stride + 1
        else:                                   # fc
            fin = flat if flat is not None else h * w * c
            macs = fin * layer.out
            out.append((layer, 1, 1, fin, macs))
            flat = layer.out
    return out


def conv_macs(cfg: ConvConfig, *, conv_only: bool = True) -> int:
    return sum(m for layer, _, _, _, m in walk_shapes(cfg)
               if not conv_only or layer.kind == "conv")


# ---------------------------------------------------------------------------
# trainable forward (im2col -> dense path)
# ---------------------------------------------------------------------------

def _im2col(x, kernel: int, stride: int, pad: int):
    """x (B, H, W, C) -> patches (B, Ho, Wo, k*k*C)."""
    b, h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - kernel) // stride + 1
    wo = (w + 2 * pad - kernel) // stride + 1
    idx_h = (jnp.arange(ho) * stride)[:, None] + jnp.arange(kernel)[None]
    idx_w = (jnp.arange(wo) * stride)[:, None] + jnp.arange(kernel)[None]
    patches = x[:, idx_h][:, :, :, idx_w]       # (B,Ho,k,Wo,k,C)
    patches = jnp.moveaxis(patches, 2, 3)       # (B,Ho,Wo,k,k,C)
    return patches.reshape(b, ho, wo, kernel * kernel * c)


def init_params(cfg: ConvConfig, key) -> list:
    params = []
    h = w = cfg.input_hw
    c = cfg.in_ch
    flat = None
    for i, layer in enumerate(cfg.layers):
        k = jax.random.fold_in(key, i)
        if layer.kind == "conv":
            kin = layer.kernel * layer.kernel * c
            params.append(layers.dense_init(k, kin, layer.out, bias=True))
            h = (h + 2 * layer.pad - layer.kernel) // layer.stride + 1
            w = (w + 2 * layer.pad - layer.kernel) // layer.stride + 1
            c = layer.out
        elif layer.kind == "pool":
            params.append({})
            h = (h - layer.kernel) // layer.stride + 1
            w = (w - layer.kernel) // layer.stride + 1
        else:
            fin = flat if flat is not None else h * w * c
            params.append(layers.dense_init(k, fin, layer.out, bias=True))
            flat = layer.out
    return params


def n_quant_layers(cfg: ConvConfig) -> int:
    """Number of quantizable (conv/fc) layers — the plan's index space."""
    return sum(1 for layer in cfg.layers if layer.kind != "pool")


def apply(params: list, cfg: ConvConfig, x, *,
          policy: QuantPolicy = NO_QUANT):
    """x (B, H, W, C) -> logits (B, n_classes).

    ``policy`` may be a per-layer :class:`repro.models.layers.PlanPolicy`
    (one config per conv/fc layer, pools excluded) — the CNN analogue of
    the planned transformer stack; the paper's conv1-region example
    (section VI.D) then gets its own bitwidth independent of fc layers.
    """
    if isinstance(policy, layers.PlanPolicy) \
            and policy.n_layers != n_quant_layers(cfg):
        raise ValueError(f"plan covers {policy.n_layers} layers; "
                         f"{cfg.name} has {n_quant_layers(cfg)} conv/fc")
    flat = False
    qi = 0
    for p, layer in zip(params, cfg.layers):
        if layer.kind != "pool":
            lpolicy = layers.policy_for_layer(policy, qi)
            qi += 1
        if layer.kind == "conv":
            patches = _im2col(x, layer.kernel, layer.stride, layer.pad)
            x = jax.nn.relu(layers.dense_apply(p, patches, lpolicy))
        elif layer.kind == "pool":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, layer.kernel, layer.kernel, 1),
                (1, layer.stride, layer.stride, 1), "VALID")
        else:
            if not flat:
                x = x.reshape(x.shape[0], -1)
                flat = True
            x = layers.dense_apply(p, x, lpolicy)
            if layer is not cfg.layers[-1]:
                x = jax.nn.relu(x)
    return x


def quantize_params(params: list, cfg: ConvConfig, configs) -> list:
    """Pack each conv/fc layer's weights per its config (plan deployment).

    ``configs``: one QuantConfig per conv/fc layer, in layer order.
    """
    if len(configs) != n_quant_layers(cfg):
        raise ValueError(f"{len(configs)} configs for "
                         f"{n_quant_layers(cfg)} conv/fc layers")
    out = []
    qi = 0
    for p, layer in zip(params, cfg.layers):
        if layer.kind == "pool":
            out.append(p)
            continue
        qcfg = configs[qi]
        qi += 1
        if qcfg.w_bits is None:
            out.append(p)
            continue
        if p["w"].shape[0] % qcfg.group_size:
            raise ValueError(
                f"layer {qi - 1} ({layer.kind}): group_size "
                f"{qcfg.group_size} does not divide fan-in "
                f"{p['w'].shape[0]}; fit the region size first "
                f"(e.g. repro.plan.plan.fit_group_size)")
        out.append(layers.quantize_dense(p, qcfg))
    return out
