"""CNNs (the paper's own example models) via im2col -> quantized matmul.

Convolution is lowered to ``im2col`` patches x kernel matrix so every
conv/fc layer flows through the same quantization-aware Dense path
(``layers.dense_apply``) as the transformer projections — conv kernels get
local quantization regions along the patch (K = kh*kw*cin) axis exactly
like the paper's conv1 example (region 11x11x3 = 363, section VI.D).

Two uses:
  * exact AlexNet / VGG-16 layer shapes for the paper's op-count tables
    (ALEXNET / VGG16 configs + ``conv_macs``);
  * a reduced trainable CNN (``MINI_CNN``) for the accuracy benchmarks
    (synthetic classification stands in for ImageNet; DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers
from .layers import QuantPolicy, NO_QUANT


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    kind: str                  # conv | pool | fc
    out: int = 0               # channels (conv) / units (fc)
    kernel: int = 3
    stride: int = 1
    pad: int = 0
    groups: int = 1            # AlexNet's split convolutions


@dataclasses.dataclass(frozen=True)
class ConvConfig:
    name: str
    input_hw: int
    in_ch: int
    n_classes: int
    layers: tuple


# --- the paper's models (exact shapes; for op-count accounting) -----------

ALEXNET = ConvConfig(
    name="alexnet", input_hw=227, in_ch=3, n_classes=1000,  # Caffe's 227
    layers=(
        ConvLayer("conv", 96, 11, 4, 0),
        ConvLayer("pool", kernel=3, stride=2),
        ConvLayer("conv", 256, 5, 1, 2, groups=2),
        ConvLayer("pool", kernel=3, stride=2),
        ConvLayer("conv", 384, 3, 1, 1),
        ConvLayer("conv", 384, 3, 1, 1, groups=2),
        ConvLayer("conv", 256, 3, 1, 1, groups=2),
        ConvLayer("pool", kernel=3, stride=2),
        ConvLayer("fc", 4096),
        ConvLayer("fc", 4096),
        ConvLayer("fc", 1000),
    ))

_VGG = []
for ch, reps in [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]:
    _VGG += [ConvLayer("conv", ch, 3, 1, 1)] * reps
    _VGG += [ConvLayer("pool", kernel=2, stride=2)]
VGG16 = ConvConfig(name="vgg16", input_hw=224, in_ch=3, n_classes=1000,
                   layers=tuple(_VGG + [ConvLayer("fc", 4096),
                                        ConvLayer("fc", 4096),
                                        ConvLayer("fc", 1000)]))

# --- reduced trainable CNN (accuracy benchmarks) ---------------------------

MINI_CNN = ConvConfig(
    name="mini-cnn", input_hw=16, in_ch=3, n_classes=32,
    layers=(
        ConvLayer("conv", 16, 3, 1, 1),
        ConvLayer("pool", kernel=2, stride=2),
        ConvLayer("conv", 32, 3, 1, 1),
        ConvLayer("pool", kernel=2, stride=2),
        ConvLayer("fc", 128),
        ConvLayer("fc", 32),
    ))


# ---------------------------------------------------------------------------
# shape walking / op counting (paper Tables 3)
# ---------------------------------------------------------------------------

def walk_shapes(cfg: ConvConfig):
    """Yield (layer, h, w, cin, macs) for conv/fc layers."""
    h = w = cfg.input_hw
    c = cfg.in_ch
    out = []
    flat = None
    for layer in cfg.layers:
        if layer.kind == "conv":
            ho = (h + 2 * layer.pad - layer.kernel) // layer.stride + 1
            wo = (w + 2 * layer.pad - layer.kernel) // layer.stride + 1
            k = layer.kernel * layer.kernel * (c // layer.groups)
            macs = ho * wo * layer.out * k
            out.append((layer, ho, wo, c, macs))
            h, w, c = ho, wo, layer.out
        elif layer.kind == "pool":
            h = (h - layer.kernel) // layer.stride + 1
            w = (w - layer.kernel) // layer.stride + 1
        else:                                   # fc
            fin = flat if flat is not None else h * w * c
            macs = fin * layer.out
            out.append((layer, 1, 1, fin, macs))
            flat = layer.out
    return out


def conv_macs(cfg: ConvConfig, *, conv_only: bool = True) -> int:
    return sum(m for layer, _, _, _, m in walk_shapes(cfg)
               if not conv_only or layer.kind == "conv")


# ---------------------------------------------------------------------------
# trainable forward (im2col -> dense path)
# ---------------------------------------------------------------------------

def _im2col(x, kernel: int, stride: int, pad: int):
    """x (B, H, W, C) -> patches (B, Ho, Wo, k*k*C)."""
    b, h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - kernel) // stride + 1
    wo = (w + 2 * pad - kernel) // stride + 1
    idx_h = (jnp.arange(ho) * stride)[:, None] + jnp.arange(kernel)[None]
    idx_w = (jnp.arange(wo) * stride)[:, None] + jnp.arange(kernel)[None]
    patches = x[:, idx_h][:, :, :, idx_w]       # (B,Ho,k,Wo,k,C)
    patches = jnp.moveaxis(patches, 2, 3)       # (B,Ho,Wo,k,k,C)
    return patches.reshape(b, ho, wo, kernel * kernel * c)


def init_params(cfg: ConvConfig, key) -> list:
    params = []
    h = w = cfg.input_hw
    c = cfg.in_ch
    flat = None
    for i, layer in enumerate(cfg.layers):
        k = jax.random.fold_in(key, i)
        if layer.kind == "conv":
            kin = layer.kernel * layer.kernel * c
            params.append(layers.dense_init(k, kin, layer.out, bias=True))
            h = (h + 2 * layer.pad - layer.kernel) // layer.stride + 1
            w = (w + 2 * layer.pad - layer.kernel) // layer.stride + 1
            c = layer.out
        elif layer.kind == "pool":
            params.append({})
            h = (h - layer.kernel) // layer.stride + 1
            w = (w - layer.kernel) // layer.stride + 1
        else:
            fin = flat if flat is not None else h * w * c
            params.append(layers.dense_init(k, fin, layer.out, bias=True))
            flat = layer.out
    return params


def apply(params: list, cfg: ConvConfig, x, *,
          policy: QuantPolicy = NO_QUANT):
    """x (B, H, W, C) -> logits (B, n_classes)."""
    flat = False
    for p, layer in zip(params, cfg.layers):
        if layer.kind == "conv":
            patches = _im2col(x, layer.kernel, layer.stride, layer.pad)
            x = jax.nn.relu(layers.dense_apply(p, patches, policy))
        elif layer.kind == "pool":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, layer.kernel, layer.kernel, 1),
                (1, layer.stride, layer.stride, 1), "VALID")
        else:
            if not flat:
                x = x.reshape(x.shape[0], -1)
                flat = True
            x = layers.dense_apply(p, x, policy)
            if layer is not cfg.layers[-1]:
                x = jax.nn.relu(x)
    return x
