"""Attention: GQA with RoPE / qk-norm, flash-style blocked softmax,
exact local-window and chunked variants, and single-token decode.

Implementations (pure JAX; lax.scan keeps HLO compact and VMEM bounded):

  flash_attention        double-scan (q blocks outer, kv blocks inner) with
                         online max/denominator -- O(q_blk * kv_blk) live
                         memory, differentiable, causal or bidirectional.
  local_attention        exact O(L * window) sliding-window / chunked
                         attention via chunk reshape + previous-chunk concat
                         (RecurrentGemma local layers; Llama-4 chunked layers
                         with lookback=0).
  decode_attention       one query step against a KV cache (+window).

GQA layout: q (B, L, KV, G, D) grouped by kv head -- k/v are never
materialized repeated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import layers
from .layers import QuantPolicy, NO_QUANT
from repro.core import kvwire as kvcache
from repro.distributed.actshard import constrain
from repro.kernels import paged_attention as paged_attn

NEG_INF = -1e30


def _mask(qpos, kpos, *, causal: bool, window: int | None):
    """(Lq, Lk) bool allowed matrix from absolute positions."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


# ---------------------------------------------------------------------------
# flash-style blocked attention (custom VJP: per-block recompute backward)
# ---------------------------------------------------------------------------
#
# Naive autodiff through the forward scans saves every block's f32
# probability tensor — the full (B, H, Lq, Lk) attention matrix in HBM,
# 584 GB/device/step on the llama3.2-1b train cell (§Perf iteration 3).
# The custom VJP saves only (out, logsumexp) per row and recomputes
# p = exp(s - lse) blockwise in the backward — the standard
# FlashAttention dataflow, expressed as lax.scans.

def _blocks(q, k, v, q_block, kv_block):
    b, lq, kvh, g, d = q.shape
    lk = k.shape[1]
    qb, kb = min(q_block, lq), min(kv_block, lk)
    lq_p, lk_p = -(-lq // qb) * qb, -(-lk // kb) * kb
    if lq_p != lq:
        q = jnp.pad(q, ((0, 0), (0, lq_p - lq), (0, 0), (0, 0), (0, 0)))
    if lk_p != lk:
        k = jnp.pad(k, ((0, 0), (0, lk_p - lk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, lk_p - lk), (0, 0), (0, 0)))
    nq, nk = lq_p // qb, lk_p // kb
    qs = jnp.moveaxis(q.reshape(b, nq, qb, kvh, g, d), 1, 0)
    ks = jnp.moveaxis(k.reshape(b, nk, kb, kvh, d), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nk, kb, kvh, d), 1, 0)
    return qs, ks, vs, (b, lq, lk, kvh, g, d, qb, kb, nq, nk)


def _fwd_impl(q, k, v, causal, window, q_block, kv_block, q_offset):
    """Returns (out (B,Lq,KV,G,D), lse (B,KV,G,Lq))."""
    qs, ks, vs, (b, lq, lk, kvh, g, d, qb, kb, nq, nk) = _blocks(
        q, k, v, q_block, kv_block)
    scale = d ** -0.5

    def outer(_, qi_qblk):
        qi, qblk = qi_qblk
        qpos = q_offset + qi * qb + jnp.arange(qb)

        def inner(carry, kj_kv):
            acc, m_run, l_run = carry
            kj, kblk, vblk = kj_kv
            kpos = kj * kb + jnp.arange(kb)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            allowed = _mask(qpos, kpos, causal=causal, window=window)
            allowed &= (kpos < lk)[None, :]
            s = jnp.where(allowed[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))               # (b,kv,g,qb)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vblk.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, kvh, g, qb, d), jnp.float32)
        m0 = jnp.full((b, kvh, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qb), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            inner, (acc0, m0, l0), (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))                # (b,kv,g,qb)
        return None, (jnp.moveaxis(out, 3, 1), lse)

    _, (outs, lses) = jax.lax.scan(outer, None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * qb, kvh, g, d)[:, :lq]
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, kvh, g, nq * qb)[..., :lq]
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, q_block, kv_block, q_offset):
    out, _ = _fwd_impl(q, k, v, causal, window, q_block, kv_block, q_offset)
    return out


def _flash_fwd(q, k, v, causal, window, q_block, kv_block, q_offset):
    out, lse = _fwd_impl(q, k, v, causal, window, q_block, kv_block,
                         q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_block, kv_block, q_offset, res, dout):
    q, k, v, out, lse = res
    qs, ks, vs, (b, lq, lk, kvh, g, d, qb, kb, nq, nk) = _blocks(
        q, k, v, q_block, kv_block)
    scale = d ** -0.5
    lq_p, lk_p = nq * qb, nk * kb
    dout_p = jnp.pad(dout.astype(jnp.float32),
                     ((0, 0), (0, lq_p - lq), (0, 0), (0, 0), (0, 0)))
    out_p = jnp.pad(out.astype(jnp.float32),
                    ((0, 0), (0, lq_p - lq), (0, 0), (0, 0), (0, 0)))
    lse_p = jnp.pad(lse, ((0, 0), (0, 0), (0, 0), (0, lq_p - lq)))
    dos = jnp.moveaxis(dout_p.reshape(b, nq, qb, kvh, g, d), 1, 0)
    # delta_i = sum_d dout_id * out_id  (per q row)
    delta = jnp.einsum("blkgd,blkgd->bkgl", dout_p, out_p)      # (b,kv,g,Lq)
    deltas = jnp.moveaxis(delta.reshape(b, kvh, g, nq, qb), 3, 0)
    lses = jnp.moveaxis(lse_p.reshape(b, kvh, g, nq, qb), 3, 0)

    def recompute_p(qblk, kblk, qi, kj):
        qpos = q_offset + qi * qb + jnp.arange(qb)
        kpos = kj * kb + jnp.arange(kb)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qblk.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        allowed = _mask(qpos, kpos, causal=causal, window=window)
        allowed &= (kpos < lk)[None, :]
        return jnp.where(allowed[None, None, None], s, NEG_INF)

    # pass 1: dq — outer over q blocks, inner over kv blocks
    def dq_outer(_, xs):
        qi, qblk, doblk, dlt, lseblk = xs

        def dq_inner(dq_acc, kj_kv):
            kj, kblk, vblk = kj_kv
            s = recompute_p(qblk, kblk, qi, kj)
            p = jnp.exp(s - lseblk[..., None])                  # (b,kv,g,qb,kb)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", doblk,
                            vblk.astype(jnp.float32))
            ds = p * (dp - dlt[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bkgqs,bskd->bqkgd", ds,
                                         kblk.astype(jnp.float32))
            return dq_acc, None

        dq0 = jnp.zeros((b, qb, kvh, g, d), jnp.float32)
        dq_blk, _ = jax.lax.scan(dq_inner, dq0, (jnp.arange(nk), ks, vs))
        return None, dq_blk

    _, dq_blocks = jax.lax.scan(dq_outer, None,
                                (jnp.arange(nq), qs, dos, deltas, lses))
    dq = jnp.moveaxis(dq_blocks, 0, 1).reshape(b, lq_p, kvh, g, d)[:, :lq]

    # pass 2: dk/dv — outer over kv blocks, inner over q blocks
    def dkv_outer(_, xs):
        kj, kblk, vblk = xs

        def dkv_inner(carry, qxs):
            dk_acc, dv_acc = carry
            qi, qblk, doblk, dlt, lseblk = qxs
            s = recompute_p(qblk, kblk, qi, kj)
            p = jnp.exp(s - lseblk[..., None])
            # dv_j = sum_i p_ij do_i  (sum over q rows and groups)
            dv_acc = dv_acc + jnp.einsum("bkgqs,bqkgd->bskd", p, doblk)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", doblk,
                            vblk.astype(jnp.float32))
            ds = p * (dp - dlt[..., None]) * scale
            dk_acc = dk_acc + jnp.einsum("bkgqs,bqkgd->bskd", ds,
                                         qblk.astype(jnp.float32))
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, kb, kvh, d), jnp.float32)
        (dk_blk, dv_blk), _ = jax.lax.scan(
            dkv_inner, (z, z), (jnp.arange(nq), qs, dos, deltas, lses))
        return None, (dk_blk, dv_blk)

    _, (dk_blocks, dv_blocks) = jax.lax.scan(dkv_outer, None,
                                             (jnp.arange(nk), ks, vs))
    dk = jnp.moveaxis(dk_blocks, 0, 1).reshape(b, lk_p, kvh, d)[:, :lk]
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(b, lk_p, kvh, d)[:, :lk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block", "q_offset"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    q_block: int = 512, kv_block: int = 1024,
                    q_offset: int = 0):
    """q (B, Lq, KV, G, D); k, v (B, Lk, KV, D) -> (B, Lq, KV, G, D).

    ``q_offset`` shifts query absolute positions (cached prefill
    continuation).  Blocks are masked, not skipped, in this baseline --
    the causal-pair-list optimization is a recorded perf iteration.
    """
    return _flash(q, k, v, causal, window, q_block, kv_block, q_offset)


# ---------------------------------------------------------------------------
# exact local-window / chunked attention (O(L * window))
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("window", "lookback"))
def local_attention(q, k, v, *, window: int, lookback: int = 1):
    """Causal sliding-window (lookback=1) or within-chunk (lookback=0)
    attention.  q (B, L, KV, G, D); k, v (B, L, KV, D).

    lookback=1: each chunk of size ``window`` attends to itself + previous
    chunk, masked to kpos in (qpos - window, qpos] -- exact sliding window.
    lookback=0: attention is confined to the chunk (Llama-4 chunked layers;
    ``window`` = chunk size).
    """
    b, l, kvh, g, d = q.shape
    c = window
    l_p = -(-l // c) * c
    pad = l_p - l
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = l_p // c
    qc = q.reshape(b, nc, c, kvh, g, d)
    kc = k.reshape(b, nc, c, kvh, d)
    vc = v.reshape(b, nc, c, kvh, d)

    if lookback:
        prev = jnp.pad(kc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
        kcat = jnp.concatenate([prev, kc], axis=2)             # (b,nc,2c,..)
        pv = jnp.pad(vc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
        vcat = jnp.concatenate([pv, vc], axis=2)
        kpos_rel = jnp.arange(2 * c) - c                       # vs chunk start
    else:
        kcat, vcat = kc, vc
        kpos_rel = jnp.arange(c)

    qpos_rel = jnp.arange(c)
    allowed = (kpos_rel[None, :] <= qpos_rel[:, None])
    allowed &= kpos_rel[None, :] > (qpos_rel[:, None] - window)
    # chunk 0 has no previous chunk: mask kpos_rel < 0 there
    chunk_ids = jnp.arange(nc)
    valid_prev = (chunk_ids[:, None, None] > 0) | (kpos_rel >= 0)[None, None]
    allowed = allowed[None] & valid_prev                       # (nc, c, 2c)

    s = jnp.einsum("bnckgd,bnskd->bnkgcs", qc.astype(jnp.float32),
                   kcat.astype(jnp.float32)) * (d ** -0.5)
    s = jnp.where(allowed[None, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnkgcs,bnskd->bnckgd", p, vcat.astype(jnp.float32))
    out = out.reshape(b, l_p, kvh, g, d)[:, :l]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode: one token against a cache
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, pos, *, window: int | None = None,
                     chunk: int | None = None, key_positions=None):
    """q (B, Lq, KV, G, D); caches (B, S, KV, D); pos int — scalar, or (B,)
    for continuous batching where every slot sits at its own position.
    ``Lq`` is usually 1 (plain decode); the speculative verify path sends
    a length-Lq run whose query i sits at absolute position ``pos + i``
    and attends causally over cache slots ``<= pos + i`` (the run's own
    K/V having been written to the cache first).  ``key_positions`` (S,)
    gives each cache slot's absolute position (ring buffers); default slot
    s holds position s.  ``window`` restricts to a sliding window;
    ``chunk`` to the current chunk (Llama-4).
    """
    b, lq, kvh, g, d = q.shape
    s_len = k_cache.shape[1]
    spos = jnp.arange(s_len) if key_positions is None else key_positions
    posb = jnp.broadcast_to(jnp.asarray(pos), (b,))
    qpos = posb[:, None] + jnp.arange(lq)                       # (B, Lq)
    valid = (spos[None, None, :] <= qpos[..., None]) & (spos >= 0)
    if window is not None:
        valid &= spos[None, None, :] > (qpos[..., None] - window)
    if chunk is not None:
        valid &= spos[None, None, :] >= (qpos[..., None] // chunk) * chunk
    # keep caches in their storage dtype: preferred_element_type gives the
    # f32 accumulation without materializing an upcast (B, S, KV, D) copy
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k_cache,
                   preferred_element_type=jnp.float32) * (d ** -0.5)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (projections + dispatch)
# ---------------------------------------------------------------------------

def attn_init(key, *, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qk_norm: bool = False, bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], d_model, n_heads * head_dim,
                                dtype=dtype, bias=bias),
        "wk": layers.dense_init(ks[1], d_model, n_kv * head_dim,
                                dtype=dtype, bias=bias),
        "wv": layers.dense_init(ks[2], d_model, n_kv * head_dim,
                                dtype=dtype, bias=bias),
        "wo": layers.dense_init(ks[3], n_heads * head_dim, d_model,
                                dtype=dtype, bias=bias),
    }
    if qk_norm:
        p["q_norm"] = layers.rmsnorm_init(head_dim, dtype)
        p["k_norm"] = layers.rmsnorm_init(head_dim, dtype)
    return p


def _project_qkv(p, x, kv_src, *, n_heads, n_kv, head_dim, qk_norm, rope,
                 positions, rope_theta, policy: QuantPolicy):
    b, l = x.shape[:2]
    g = n_heads // n_kv
    q = layers.dense_apply(p["wq"], x, policy).reshape(b, l, n_kv, g, head_dim)
    lk = kv_src.shape[1]
    k = layers.dense_apply(p["wk"], kv_src, policy).reshape(b, lk, n_kv,
                                                            head_dim)
    v = layers.dense_apply(p["wv"], kv_src, policy).reshape(b, lk, n_kv,
                                                            head_dim)
    if qk_norm:
        q = layers.rmsnorm_apply(p["q_norm"], q)
        k = layers.rmsnorm_apply(p["k_norm"], k)
    if rope:
        q = layers.apply_rope(q.reshape(b, l, n_kv * g, head_dim),
                              positions, rope_theta).reshape(q.shape)
        k = layers.apply_rope(k, positions, rope_theta)
    return q, k, v


def attn_apply(p, x, *, n_heads: int, n_kv: int, head_dim: int,
               kind: str = "full", causal: bool = True,
               window: int | None = None, qk_norm: bool = False,
               rope: bool = True, rope_theta: float = 1e4,
               positions=None, kv_src=None, cache=None, cache_pos=None,
               page_table=None, fused: str | None = None,
               policy: QuantPolicy = NO_QUANT):
    """One attention block.

    kind: 'full' | 'local' (sliding window) | 'chunked' (within-chunk) |
          'cross' (kv from kv_src, no causal, no rope on q/k).
    cache: None (train/prefill-no-cache) or dict(k=(B,S,KV,D), v=...) --
      * decode: x has L==1, cache_pos is this token's position scalar;
      * prefill-into-cache: L>1 writes [0:L) and attends within x.
    page_table: (B, P) int32 physical page ids — paged decode.  cache leaves
      then carry a shared (n_pages, page_size, KV, ...) pool instead of a
      per-request (B, S, KV, ...) buffer, cache_pos is a (B,) per-slot
      position vector, and the step writes this token's K/V into its page
      before attending over the gathered page views (kind 'full' only).
    fused: None (XLA gather+dequant path) or 'pallas'/'interpret' — run the
      paged branch through the fused flash-decode kernel
      (``kernels/paged_attention.py``), which streams wire pages through
      VMEM and dequantizes in-register instead of materializing the pool.
    Returns (out, new_cache).
    """
    b, l, _ = x.shape
    g = n_heads // n_kv
    if positions is None:
        base = 0 if cache_pos is None else cache_pos
        positions = base + jnp.arange(l)[None]
    src = x if kind != "cross" else kv_src
    q, k, v = _project_qkv(p, x, src, n_heads=n_heads, n_kv=n_kv,
                           head_dim=head_dim, qk_norm=qk_norm,
                           rope=rope and kind != "cross",
                           positions=positions, rope_theta=rope_theta,
                           policy=policy)
    if cache is None and kind != "cross" and \
            getattr(policy, "kv_fq", None) is not None:
        # cache-free forward under a kv-quantized policy: round K/V through
        # the wire format so sensitivity profiling sees exactly the decode
        # numerics (post-rope, per-position local regions along head_dim)
        fq_bits, fq_group = policy.kv_fq
        k = kvcache.dequantize_kv(kvcache.quantize_kv(k, fq_bits, fq_group),
                                  head_dim, k.dtype)
        v = kvcache.dequantize_kv(kvcache.quantize_kv(v, fq_bits, fq_group),
                                  head_dim, v.dtype)

    new_cache = cache
    ring = kind in ("local", "chunked")   # fixed-size rotating cache
    quant = cache is not None and kvcache.is_quant_kv(cache.get("k"))
    if quant:
        qbits, qgroup = kvcache._infer(
            cache["k"]["packed"].shape[-1], head_dim,
            cache["k"]["scale"].shape[-1])
    if cache is not None and kind != "cross" and page_table is not None:
        if kind != "full":
            raise ValueError("paged cache supports decode of 'full' "
                             "attention only")
        page_size = (cache["k"]["packed"] if quant else cache["k"]).shape[1]
        wpos = cache_pos[:, None] + jnp.arange(l)           # (B, L) absolute
        # positions beyond the slot's table (a speculative run tailing past
        # max_context) write the scratch page instead of clamping onto the
        # slot's own last page, where they would corrupt live rows
        limit = page_table.shape[1] * page_size
        page_idx = jnp.take_along_axis(
            page_table, jnp.minimum(wpos // page_size,
                                    page_table.shape[1] - 1), axis=1)
        page_idx = jnp.where(wpos < limit, page_idx, 0)
        row = wpos % page_size
        kw = dict(bits=qbits, group_size=qgroup) if quant else {}
        qk = kvcache.scatter_tokens(cache["k"], k, page_idx, row, **kw)
        qv = kvcache.scatter_tokens(cache["v"], v, page_idx, row, **kw)
        if fused is not None:
            new_cache = {"k": qk, "v": qv}
            out = paged_attn.paged_attention(
                q, qk, qv, page_table, cache_pos,
                interpret=fused == "interpret")
            out = out.reshape(b, l, n_heads * head_dim)
            return layers.dense_apply(p["wo"], out, policy), new_cache
        if quant:
            k_cache = kvcache.dequantize_kv(
                kvcache.gather_pages(qk, page_table), head_dim, q.dtype)
            v_cache = kvcache.dequantize_kv(
                kvcache.gather_pages(qv, page_table), head_dim, q.dtype)
        else:
            k_cache = kvcache.gather_pages(qk, page_table)
            v_cache = kvcache.gather_pages(qv, page_table)
        new_cache = {"k": qk, "v": qv}
        out = decode_attention(q, k_cache, v_cache, cache_pos)
    elif cache is not None and kind != "cross":
        s_len = (cache["k"]["packed"] if quant else cache["k"]).shape[1]
        if l == 1:  # decode step
            slot = cache_pos % s_len if ring else cache_pos
            if quant:
                # LQ-quantized cache (serve/kvcache.py): write the new slot
                # in wire format, attend over the dequantized view.  HBM
                # holds only packed codes + per-region affine.
                qk = kvcache.update_quant_kv(cache["k"], k, slot, axis=1,
                                             bits=qbits, group_size=qgroup)
                qv = kvcache.update_quant_kv(cache["v"], v, slot, axis=1,
                                             bits=qbits, group_size=qgroup)
                new_cache = {"k": qk, "v": qv}
                k_cache = kvcache.dequantize_kv(qk, head_dim, q.dtype)
                v_cache = kvcache.dequantize_kv(qv, head_dim, q.dtype)
            else:
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
                new_cache = {"k": k_cache, "v": v_cache}
            key_pos = None
            if ring:  # slot s holds absolute position pos - ((pos - s) % S)
                key_pos = cache_pos - ((cache_pos - jnp.arange(s_len))
                                       % s_len)
            out = decode_attention(
                q, k_cache, v_cache, cache_pos,
                window=window if kind == "local" else None,
                chunk=window if kind == "chunked" else None,
                key_positions=key_pos)
        else:       # prefill: write cache, attend within the prefix
            if quant:
                if ring and l >= s_len:
                    idx = (jnp.arange(s_len) - l) % s_len
                    keep_k, keep_v = k[:, l - s_len:][:, idx], \
                        v[:, l - s_len:][:, idx]
                    new_cache = {
                        "k": kvcache.quantize_kv(keep_k, qbits, qgroup),
                        "v": kvcache.quantize_kv(keep_v, qbits, qgroup)}
                else:
                    new_cache = {
                        "k": kvcache.update_quant_kv(
                            cache["k"], k, 0, axis=1, bits=qbits,
                            group_size=qgroup),
                        "v": kvcache.update_quant_kv(
                            cache["v"], v, 0, axis=1, bits=qbits,
                            group_size=qgroup)}
            else:
                kc = k.astype(cache["k"].dtype)
                vc = v.astype(cache["v"].dtype)
                if ring and l >= s_len:
                    # keep the last s_len tokens at slots (t % s_len)
                    idx = (jnp.arange(s_len) - l) % s_len
                    k_cache = kc[:, l - s_len:][:, idx]
                    v_cache = vc[:, l - s_len:][:, idx]
                else:
                    k_cache = jax.lax.dynamic_update_slice_in_dim(
                        cache["k"], kc, 0, axis=1)
                    v_cache = jax.lax.dynamic_update_slice_in_dim(
                        cache["v"], vc, 0, axis=1)
                new_cache = {"k": k_cache, "v": v_cache}
            out = _dispatch(q, k, v, kind, causal, window)
    else:
        out = _dispatch(q, k, v, kind, causal, window)

    out = out.reshape(b, l, n_heads * head_dim)
    return layers.dense_apply(p["wo"], out, policy), new_cache


def _dispatch(q, k, v, kind, causal, window):
    # Shard the full-sequence attention on the kv-head dim ("kv_heads" ->
    # "model" in the launcher's rules).  Without this GSPMD replicates the
    # (B, KV, G, L, L)-blocked score tensors across the model axis — the
    # llama3.2-1b train cell paid 7.4 TB/device of HBM traffic (§Perf
    # iteration 2).  Decode keeps its KV-sequence sharding instead.
    q = constrain(q, "batch", None, "kv_heads", None, None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    if kind == "full":
        return flash_attention(q, k, v, causal=causal)
    if kind == "cross":
        return flash_attention(q, k, v, causal=False)
    if kind == "local":
        return local_attention(q, k, v, window=window, lookback=1)
    if kind == "chunked":
        return local_attention(q, k, v, window=window, lookback=0)
    raise ValueError(f"unknown attention kind {kind!r}")
