"""Feed-forward blocks: SwiGLU (modern LMs) and GELU (whisper-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .layers import QuantPolicy, NO_QUANT


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "wi_gate": layers.dense_init(ks[0], d_model, d_ff, dtype=dtype),
        "wi_up": layers.dense_init(ks[1], d_model, d_ff, dtype=dtype),
        "wo": layers.dense_init(ks[2], d_ff, d_model, dtype=dtype),
    }


def swiglu_apply(p, x, policy: QuantPolicy = NO_QUANT):
    gate = layers.dense_apply(p["wi_gate"], x, policy)
    up = layers.dense_apply(p["wi_up"], x, policy)
    return layers.dense_apply(p["wo"], jax.nn.silu(gate) * up, policy)


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {
        "wi": layers.dense_init(ks[0], d_model, d_ff, dtype=dtype, bias=True),
        "wo": layers.dense_init(ks[1], d_ff, d_model, dtype=dtype, bias=True),
    }


def gelu_mlp_apply(p, x, policy: QuantPolicy = NO_QUANT):
    h = jax.nn.gelu(layers.dense_apply(p["wi"], x, policy))
    return layers.dense_apply(p["wo"], h, policy)


def ffn_init(key, kind: str, d_model: int, d_ff: int, dtype=jnp.float32):
    if kind == "swiglu":
        return swiglu_init(key, d_model, d_ff, dtype)
    if kind == "gelu":
        return gelu_mlp_init(key, d_model, d_ff, dtype)
    raise ValueError(f"unknown ffn kind {kind!r}")


def ffn_apply(p, x, kind: str, policy: QuantPolicy = NO_QUANT):
    if kind == "swiglu":
        return swiglu_apply(p, x, policy)
    return gelu_mlp_apply(p, x, policy)
