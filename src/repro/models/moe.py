"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

Design (DESIGN.md section 6):

  * top-k routing with a dense router (kept fp32 -- tiny, numerically
    sensitive; DESIGN.md section 4);
  * **gather dispatch**: tokens are routed into per-expert buffers of
    static ``capacity`` via a cumsum rank -- a gather, NOT the GShard
    one-hot einsum (which costs O(T^2 d) and would swamp the roofline);
    overflow tokens are dropped (standard dropping MoE);
  * expert FFNs are SwiGLU computed as batched einsum over the expert
    axis; with expert-parallel sharding the (E, C, d) buffers shard over
    the 'model' mesh axis and GSPMD inserts the all-to-alls;
  * combine: weighted scatter-add back to token positions.

Auxiliary load-balancing loss (Switch-style) is returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .layers import QuantPolicy, NO_QUANT
from repro.distributed.actshard import constrain


def moe_init(key, *, d_model: int, d_ff: int, n_experts: int,
             n_shared_ff: int = 0, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    std = d_model ** -0.5
    p = {
        "router": {"w": jax.random.normal(ks[0], (d_model, n_experts),
                                          jnp.float32) * std},
        "wi_gate": (jax.random.normal(ks[1], (n_experts, d_model, d_ff),
                                      jnp.float32) * std).astype(dtype),
        "wi_up": (jax.random.normal(ks[2], (n_experts, d_model, d_ff),
                                    jnp.float32) * std).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_experts, d_ff, d_model),
                                 jnp.float32) * (d_ff ** -0.5)).astype(dtype),
    }
    if n_shared_ff:
        from . import mlp
        p["shared"] = mlp.swiglu_init(ks[4], d_model, n_shared_ff, dtype)
    return p


def _expert_ffn(p, x, policy: QuantPolicy):
    """x (E, C, d) -> (E, C, d) through per-expert SwiGLU."""
    wg, wu, wo = p["wi_gate"], p["wi_up"], p["wo"]
    if isinstance(wg, layers.kops.QWeight):
        # batched packed experts: vmap the quant matmul over the expert axis
        qmm = jax.vmap(lambda xx, qq: layers.kops.quant_matmul(
            xx, qq, backend=policy.backend), in_axes=(0, 0))
        gate = qmm(x, wg)
        up = qmm(x, wu)
        return qmm(jax.nn.silu(gate) * up, wo)
    dt = x.dtype
    gate = jnp.einsum("ecd,edf->ecf", x, wg.astype(dt))
    up = jnp.einsum("ecd,edf->ecf", x, wu.astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up, wo.astype(dt))


def moe_apply(p, x, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25,
              policy: QuantPolicy = NO_QUANT):
    """x (B, L, d) -> (out (B, L, d), aux_loss scalar)."""
    from repro.distributed import actshard
    rules = actshard.current_rules()
    if rules and rules.get("moe_shard_map") and not isinstance(
            p["wi_gate"], layers.kops.QWeight):
        return _moe_apply_ep(p, x, n_experts=n_experts, top_k=top_k,
                             capacity_factor=capacity_factor,
                             mesh=rules["__mesh__"],
                             dp_axes=tuple(rules["batch"]),
                             ep_axis=rules.get("moe_ep_axis", "model"))
    b, l, d = x.shape
    t = b * l
    xt = constrain(x.reshape(t, d), "flat_tokens", None)

    logits = (xt.astype(jnp.float32)
              @ p["router"]["w"].astype(jnp.float32))          # (T, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)        # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(int(capacity_factor * t * top_k / n_experts), 1)

    # rank of each (token, k) assignment within its expert, via one-hot cumsum
    flat_ids = expert_ids.reshape(-1)                          # (T*K,)
    onehot = jax.nn.one_hot(flat_ids, n_experts, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - 1                      # (T*K, E)
    pos_in_expert = jnp.take_along_axis(
        rank, flat_ids[:, None], axis=1)[:, 0]                 # (T*K,)
    keep = pos_in_expert < capacity

    # scatter (token row, weight) into expert buffers
    slot = flat_ids * capacity + jnp.where(keep, pos_in_expert, 0)
    slot = jnp.where(keep, slot, n_experts * capacity)          # drop -> pad
    token_idx = jnp.repeat(jnp.arange(t), top_k)
    src = jnp.zeros((n_experts * capacity + 1,), jnp.int32)
    src = src.at[slot].set(token_idx, mode="drop")
    src_tok = src[:n_experts * capacity].reshape(n_experts, capacity)
    filled = jnp.zeros((n_experts * capacity + 1,), bool
                       ).at[slot].set(keep, mode="drop")
    filled = filled[:n_experts * capacity].reshape(n_experts, capacity)

    # gather dispatch (memory-bound, no O(T^2) einsum); expert buffers
    # pinned to the EP axis so GSPMD emits the all-to-all instead of
    # falling back to replicated scatter (§Perf)
    xe = jnp.take(xt, src_tok.reshape(-1), axis=0
                  ).reshape(n_experts, capacity, d)
    xe = jnp.where(filled[..., None], xe, 0)
    # 2-D shard the expert buffers: experts over the EP ("model") axis AND
    # capacity over dp — E alone divides the work by E, not by the mesh
    # (a capacity dim left replicated over 32 data ranks cost 32x expert
    # flops on the scout train cell; §Perf)
    xe = constrain(xe, "experts", "batch", None)

    ye = _expert_ffn(p, xe, policy)                             # (E, C, d)
    ye = constrain(ye, "experts", "batch", None)

    # combine: weighted scatter-add back to tokens
    w_flat = gate_vals.reshape(-1)                              # (T*K,)
    wbuf = jnp.zeros((n_experts * capacity + 1,), jnp.float32
                     ).at[slot].set(jnp.where(keep, w_flat, 0.0), mode="drop")
    wbuf = wbuf[:n_experts * capacity].reshape(n_experts, capacity)
    contrib = ye.astype(jnp.float32) * wbuf[..., None]
    out = jnp.zeros((t, d), jnp.float32).at[src_tok.reshape(-1)].add(
        jnp.where(filled[..., None], contrib, 0).reshape(-1, d))
    out = constrain(out, "flat_tokens", None)
    out = out.astype(x.dtype).reshape(b, l, d)

    if "shared" in p:
        from . import mlp
        out = out + mlp.swiglu_apply(p["shared"], x, policy)

    # Switch-style load-balancing aux loss
    me = probs.mean(axis=0)                                     # (E,)
    ce = jax.nn.one_hot(expert_ids[:, 0], n_experts).mean(axis=0)
    aux = n_experts * jnp.sum(me * ce)
    return out, aux


# ---------------------------------------------------------------------------
# shard_map expert-parallel dispatch (the production EP path; §Perf)
# ---------------------------------------------------------------------------
#
# GSPMD's best layout for the gather/scatter dispatch still all-gathers the
# token table across data ranks (~180 s/step collective on the 235B train
# cell after 2-D buffer sharding).  The structural fix: tokens never leave
# their data shard.  Activations are replicated over the model axis, so
# each (data_i, model_j) device dispatches its LOCAL tokens to its LOCAL
# e_loc = E/ep experts, runs them, scatters back locally, and a single
# psum over the model axis completes the combine — per-layer cross-chip
# traffic collapses to one (T_local, d) reduction.

def _moe_apply_ep(p, x, *, n_experts: int, top_k: int,
                  capacity_factor: float, mesh, dp_axes: tuple,
                  ep_axis: str):
    from jax.sharding import PartitionSpec as P

    b, l, d = x.shape
    ep = mesh.shape[ep_axis]
    if n_experts % ep:
        raise ValueError(f"E={n_experts} not divisible by |{ep_axis}|={ep}")
    e_loc = n_experts // ep
    has_shared = "shared" in p

    def body(xb, router_w, wg, wu, wo):
        b_loc = xb.shape[0]
        t = b_loc * l
        xt = xb.reshape(t, d)
        logits = xt.astype(jnp.float32) @ router_w       # (T_loc, E) fp32
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        capacity = max(int(capacity_factor * t * top_k / n_experts), 1)
        my_lo = jax.lax.axis_index(ep_axis) * e_loc

        flat_ids = expert_ids.reshape(-1)                # (T_loc*K,)
        local = (flat_ids >= my_lo) & (flat_ids < my_lo + e_loc)
        loc_ids = jnp.where(local, flat_ids - my_lo, e_loc)
        onehot = jax.nn.one_hot(loc_ids, e_loc, dtype=jnp.int32)
        rank = jnp.cumsum(onehot, axis=0) - 1
        pos = jnp.take_along_axis(
            rank, jnp.clip(loc_ids, 0, e_loc - 1)[:, None], axis=1)[:, 0]
        keep = local & (pos < capacity)

        slot = jnp.where(keep, loc_ids * capacity + pos,
                         e_loc * capacity)
        token_idx = jnp.repeat(jnp.arange(t), top_k)
        src = jnp.zeros((e_loc * capacity + 1,), jnp.int32
                        ).at[slot].set(token_idx, mode="drop")
        src_tok = src[:e_loc * capacity].reshape(e_loc, capacity)
        filled = jnp.zeros((e_loc * capacity + 1,), bool
                           ).at[slot].set(keep, mode="drop")
        filled = filled[:e_loc * capacity].reshape(e_loc, capacity)

        xe = jnp.take(xt, src_tok.reshape(-1), axis=0
                      ).reshape(e_loc, capacity, d)
        xe = jnp.where(filled[..., None], xe, 0)

        dt = xe.dtype
        gate = jnp.einsum("ecd,edf->ecf", xe, wg.astype(dt))
        up = jnp.einsum("ecd,edf->ecf", xe, wu.astype(dt))
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up,
                        wo.astype(dt))

        w_flat = gate_vals.reshape(-1)
        wbuf = jnp.zeros((e_loc * capacity + 1,), jnp.float32
                         ).at[slot].set(jnp.where(keep, w_flat, 0.0),
                                        mode="drop")
        wbuf = wbuf[:e_loc * capacity].reshape(e_loc, capacity)
        contrib = ye.astype(jnp.float32) * wbuf[..., None]
        out = jnp.zeros((t, d), jnp.float32).at[src_tok.reshape(-1)].add(
            jnp.where(filled[..., None], contrib, 0).reshape(-1, d))
        # combine across expert shards: the ONLY cross-chip traffic
        out = jax.lax.psum(out.astype(xb.dtype), ep_axis)

        me = probs.mean(axis=0)
        ce = jax.nn.one_hot(expert_ids[:, 0], n_experts).mean(axis=0)
        aux = n_experts * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, dp_axes)
        return out.reshape(b_loc, l, d), aux

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_axes, None, None), P(), P(ep_axis, None, None),
                  P(ep_axis, None, None), P(ep_axis, None, None)),
        out_specs=(P(dp_axes, None, None), P()),
        check_vma=False)
    out, aux = fn(x, p["router"]["w"].astype(jnp.float32),
                  p["wi_gate"], p["wi_up"], p["wo"])

    if has_shared:
        from . import mlp
        out = out + mlp.swiglu_apply(p["shared"], x, NO_QUANT)
    return out, aux
