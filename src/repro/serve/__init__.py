from .kvcache import (quantize_kv, dequantize_kv, make_quant_kv,
                      update_quant_kv, is_quant_kv, kv_bits_of,
                      quantize_state, dequantize_state, is_quant_state,
                      cache_nbytes)
from .engine import Engine, EngineConfig, greedy_sample, temperature_sample
