from .kvcache import (quantize_kv, dequantize_kv, make_quant_kv,
                      update_quant_kv, is_quant_kv, kv_bits_of,
                      make_paged_kv, gather_pages, scatter_token,
                      scatter_tokens, scatter_prefill, permute_pages,
                      reset_table_rows,
                      quantize_state, dequantize_state, is_quant_state,
                      cache_nbytes)
from .engine import (Engine, EngineConfig, PagedConfig, PagedEngine,
                     greedy_sample, temperature_sample)
from .pool import PagedKVPool, make_pool_pages, pool_nbytes
from .scheduler import Completion, Request, Scheduler
from .server import RequestParams, Server
