"""Synchronous-loop serving front end over the continuous-batching stack.

    server = Server(cfg, params, ecfg, pcfg)
    rid = server.submit(prompt, RequestParams(max_new_tokens=32))
    while server.has_work:
        server.step()          # or: server.drain()

``step()`` advances the whole cell one decode step (admitting whatever
fits first) and returns the completions it produced.  Token streaming is
push-based: per-request ``on_token`` callbacks fire as tokens are sampled,
global ``on_token``/``on_complete`` callbacks observe every request.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig
from repro.serve.engine import EngineConfig, PagedConfig, PagedEngine
from repro.serve.scheduler import Completion, Scheduler


@dataclasses.dataclass(frozen=True)
class RequestParams:
    """Per-request sampling/scheduling parameters."""
    max_new_tokens: int = 16
    priority: int = 0
    tenant: str | None = None    # fleet tenant tag; echoed on Completion


class Server:
    """Owns the paged engine, the page pool, and the scheduler."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 pcfg: PagedConfig, *, engine=None, on_token=None,
                 on_complete=None, seed: int = 0, obs=None):
        """``engine`` swaps in a prebuilt engine satisfying the paged-engine
        step contract (e.g. :class:`repro.spec.SpeculativeEngine`); by
        default a :class:`PagedEngine` is built from the configs.
        ``obs`` (a :class:`repro.obs.Observability`) threads tracing +
        latency metrics through the engine, pool, and scheduler."""
        from repro.obs import NOOP
        self.obs = obs or NOOP
        self.engine = engine or PagedEngine(cfg, params, ecfg, pcfg,
                                            obs=self.obs)
        if engine is not None and obs is not None:
            self.engine.obs = obs       # prebuilt engine: adopt our obs
        self.engine.report_attention_mode(self.obs)
        self.pool = self.engine.new_pool()
        self.scheduler = Scheduler(self.engine, self.pool,
                                   on_token=on_token,
                                   on_complete=on_complete, seed=seed,
                                   obs=self.obs)

    def set_obs(self, obs):
        """Swap the observability sink on a live server (e.g. attach a
        fresh tracer after jit warmup, keeping compile time out of the
        latency histograms)."""
        self.obs = self.engine.obs = self.pool.obs = obs
        self.scheduler.obs = obs
        if obs.enabled:
            obs.tracer.name_thread(0, "engine")
        self.engine.report_attention_mode(obs)

    def attach_quality(self, monitor):
        """Attach a :class:`repro.obs.numerics.QualityMonitor`: the
        scheduler calls its ``on_step`` tap after every decode step.
        Pass ``None`` to detach.  Returns the monitor."""
        self.scheduler.quality = monitor
        return monitor

    def attach_profiler(self, profiler):
        """Attach a :class:`repro.obs.profile.PhaseProfiler`: the
        scheduler calls its ``on_step`` tap after every decode step.
        Pass ``None`` to detach.  Returns the profiler."""
        self.scheduler.profiler = profiler
        return profiler

    # ------------------------------------------------------------- public
    def submit(self, prompt, params: RequestParams = RequestParams(), *,
               on_token=None) -> int:
        """Enqueue a request; returns its request id immediately."""
        return self.scheduler.submit(
            prompt, max_new_tokens=params.max_new_tokens,
            priority=params.priority, on_token=on_token,
            tenant=params.tenant)

    def step(self) -> list[Completion]:
        """Advance every in-flight request by one token."""
        return self.scheduler.step()

    def drain(self, max_steps: int | None = None) -> dict[int, list[int]]:
        """Run to quiescence; returns {rid: generated tokens}."""
        return self.scheduler.drain(max_steps=max_steps)

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def output(self, rid: int) -> list[int]:
        return list(self.scheduler.request(rid).generated)

    def stats(self) -> dict:
        s = self.scheduler.stats()
        s["pool_bytes"] = self.pool.nbytes()
        s["decode_compilations"] = self.engine.decode_compilations
        s["attention_mode"] = self.engine.attention_mode
        return s
