"""Serving-facing re-export of the core KV-cache wire format.

The format itself lives in :mod:`repro.core.kvwire` (it is the paper's
local-quantization-region format applied to cached tensors); model code
imports it from core to avoid serve<->models import cycles.  The paged
layout helpers (gather_pages / scatter_token / scatter_prefill /
permute_pages) are the device half of the continuous-batching pool in
:mod:`repro.serve.pool` — prefill and decode operate on gathered page
views rather than one monolithic (B, T, ...) cache.
"""
from repro.core.kvwire import (quantize_kv, dequantize_kv, make_quant_kv,
                               update_quant_kv, is_quant_kv, kv_bits_of,
                               make_paged_kv, gather_pages, scatter_token,
                               scatter_tokens, scatter_prefill,
                               permute_pages, reset_table_rows,
                               quantize_state, dequantize_state,
                               is_quant_state, cache_nbytes, _infer,
                               KV_BITS, check_kv_bits, segment_runs,
                               kv_token_nbytes)

__all__ = ["quantize_kv", "dequantize_kv", "make_quant_kv",
           "update_quant_kv", "is_quant_kv", "kv_bits_of",
           "make_paged_kv", "gather_pages", "scatter_token",
           "scatter_tokens", "scatter_prefill", "permute_pages",
           "reset_table_rows",
           "quantize_state", "dequantize_state", "is_quant_state",
           "cache_nbytes",
           "KV_BITS", "check_kv_bits", "segment_runs", "kv_token_nbytes"]
