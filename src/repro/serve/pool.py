"""Paged KV-cache pool: block storage for the quantized wire format.

The pool owns every layer's K/V storage as shared page arrays —
``(n_super, n_pages, page_size, KV, ...)`` for scan-stacked superblock
positions, ``(n_pages, page_size, KV, ...)`` for the unscanned tail — in
the LQ wire format when ``kv_bits`` is set (core/kvwire.py) or fp
otherwise.  Requests own ordered page lists (page tables); the device-side
gather/scatter lives in core/kvwire.py and models/attention.py; this class
is the host-side allocator: alloc/free/defrag plus accounting.

Page 0 is reserved as a scratch page.  Padded page-table entries and
inactive decode slots read and write it; decode masking guarantees its
garbage never reaches a real output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kvwire
from repro.models.config import ModelConfig
from repro.obs import NOOP


def _check_paged_support(cfg: ModelConfig):
    for mixer, _ in cfg.pattern:
        if mixer != "attn":
            raise ValueError(
                f"paged KV pool supports full-attention decoders only; "
                f"mixer {mixer!r} needs the contiguous Engine path")
    if cfg.n_enc_layers:
        raise ValueError("paged serving does not support encoder-decoder")
    if cfg.frontend != "none":
        raise ValueError("paged serving does not support modality frontends")
    if cfg.pos_embed == "learned":
        raise ValueError("paged serving needs rope (per-slot positions)")


def make_pool_pages(cfg: ModelConfig, *, n_pages: int, page_size: int,
                    kv_bits=None, kv_group: int = 64, dtype=None):
    """Build the zero-initialized page pytree of a :class:`PagedKVPool`.

    ``kv_bits`` is ``None`` (fp), one int (every layer shares the wire
    format), or a per-layer sequence of ``bits | None`` — the
    heterogeneous page geometry of a mixed-KV :class:`~repro.plan.QuantPlan`.
    Homogeneous pools stack superblock leaves under ``"super"`` as before;
    a genuinely mixed map stores one stacked leaf per run of superblocks
    sharing a wire shape under ``"super_segments"`` (packed widths differ,
    so heterogeneous layers cannot share an array), mirroring
    ``transformer.init_cache``.  Page ids stay *global*: page ``p`` of
    every layer's array belongs to the same request, whatever that
    layer's bitwidth — only the bytes behind a page differ per layer.

    Module-level so callers can price a pool without materializing it:
    ``jax.eval_shape(lambda: make_pool_pages(...))`` yields the structure
    abstractly (see :func:`pool_nbytes`, used by the fleet registry's
    host-budget accounting).
    """
    from repro.models.transformer import normalize_kv_quant

    _check_paged_support(cfg)
    if n_pages < 2:
        raise ValueError("need at least one allocatable page + scratch")
    kvq = normalize_kv_quant(cfg, (kv_bits, kv_group))
    per_layer = kvq is not None and isinstance(kvq[0], tuple)
    if kvq is not None and cfg.head_dim % kv_group:
        raise ValueError(f"head_dim={cfg.head_dim} not divisible by "
                         f"kv_group={kv_group}")
    dtype = dtype or cfg.activation_dtype

    def leaf(stack: int | None, bits):
        one = kvwire.make_paged_kv(n_pages, page_size, cfg.n_kv_heads,
                                   cfg.head_dim, bits, kv_group, dtype)
        if stack is None:
            return one
        return jax.tree.map(
            lambda a: jnp.zeros((stack,) + a.shape, a.dtype), one)

    p_len = len(cfg.pattern)
    if per_layer:
        bits_list = kvq[0]
        runs = kvwire.segment_runs(list(bits_list), p_len, cfg.n_super)
        sup = [tuple({"self": {"k": leaf(size, key[j]),
                               "v": leaf(size, key[j])}}
                     for j in range(p_len))
               for _, size, key in runs]
        tail = [{"self": {"k": leaf(None, bits_list[cfg.n_super * p_len + t]),
                          "v": leaf(None, bits_list[cfg.n_super * p_len + t])}}
                for t in range(cfg.n_tail)]
        return {"super_segments": sup, "tail": tail}

    bits = None if kvq is None else kvq[0]
    sup = tuple({"self": {"k": leaf(cfg.n_super, bits),
                          "v": leaf(cfg.n_super, bits)}}
                for _ in cfg.pattern)
    tail = [{"self": {"k": leaf(None, bits), "v": leaf(None, bits)}}
            for _ in range(cfg.n_tail)]
    return {"super": sup, "tail": tail}


def pool_nbytes(cfg: ModelConfig, *, n_pages: int, page_size: int,
                kv_bits=None, kv_group: int = 64, dtype=None) -> int:
    """Resident bytes of a pool with this geometry, without building it.

    Exact by construction (``eval_shape`` over the real pytree), including
    per-layer heterogeneous ``kv_bits`` maps — the fleet registry prices
    mixed-KV tenants with these bytes, not a uniform over-approximation.
    """
    pages = jax.eval_shape(lambda: make_pool_pages(
        cfg, n_pages=n_pages, page_size=page_size, kv_bits=kv_bits,
        kv_group=kv_group, dtype=dtype))
    return kvwire.cache_nbytes(pages)


class PagedKVPool:
    """Block/paged KV storage + host-side page allocator.

    n_pages counts physical pages including the reserved scratch page 0, so
    ``n_pages - 1`` pages are allocatable.  ``kv_bits`` in {8, 4, 2, 1} —
    one int, or a per-layer map (heterogeneous page geometry) — stores
    pages in the packed wire format; packing is along head_dim, so
    page_size is independent of kv_bits (see serve/README.md).  The
    allocator below is bitwidth-blind: a page id spans every layer's
    array, so alloc/free/defrag never need to know the geometry.
    """

    def __init__(self, cfg: ModelConfig, *, n_pages: int, page_size: int,
                 kv_bits=None, kv_group: int = 64, dtype=None, obs=None):
        self.cfg = cfg
        self.n_pages, self.page_size = n_pages, page_size
        self.kv_bits, self.kv_group = kv_bits, kv_group
        self.obs = obs or NOOP     # allocator events + occupancy gauge
        self.pages = make_pool_pages(cfg, n_pages=n_pages,
                                     page_size=page_size, kv_bits=kv_bits,
                                     kv_group=kv_group, dtype=dtype)
        sup_key = ("super_segments" if "super_segments" in self.pages
                   else "super")
        self._permute = jax.jit(lambda pages, perm: {
            sup_key: kvwire.permute_pages(pages[sup_key], perm,
                                          stacked=True),
            "tail": kvwire.permute_pages(pages["tail"], perm)})
        self._reset_table = jax.jit(lambda pages, table, keep: {
            sup_key: kvwire.reset_table_rows(pages[sup_key], table, keep,
                                             stacked=True),
            "tail": kvwire.reset_table_rows(pages["tail"], table, keep)})

        self._free = list(range(n_pages - 1, 0, -1))   # LIFO free list
        self.page_tables: dict[int, list[int]] = {}    # rid -> ordered pages

    # ---------------------------------------------------------- allocator
    @property
    def n_allocatable(self) -> int:
        return self.n_pages - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return self.n_allocatable - self.n_free

    def occupancy(self) -> float:
        return self.n_allocated / self.n_allocatable

    def alloc(self, rid: int, n: int = 1) -> bool:
        """Append n pages to rid's table; all-or-nothing on exhaustion."""
        if n > len(self._free):
            if self.obs.enabled:
                # flight-recorder anomaly trigger (obs/flight.py)
                self.obs.event("alloc_fail", rid=int(rid), n_pages=n,
                               free=len(self._free))
                self.obs.metrics.counter("pool_alloc_fail_total").inc()
            return False
        got = [self._free.pop() for _ in range(n)]
        self.page_tables.setdefault(rid, []).extend(got)
        if self.obs.enabled:
            self.obs.event("alloc", rid=int(rid), n_pages=n)
            self.obs.metrics.counter("pool_alloc_total").inc(n)
            self.obs.metrics.gauge("pool_occupancy").set(self.occupancy())
        return True

    def free(self, rid: int) -> int:
        """Release every page owned by rid; returns how many."""
        pages = self.page_tables.pop(rid, [])
        self._free.extend(reversed(pages))
        if pages and self.obs.enabled:
            self.obs.event("free", rid=int(rid), n_pages=len(pages))
            self.obs.metrics.gauge("pool_occupancy").set(self.occupancy())
        return len(pages)

    def pages_of(self, rid: int) -> list[int]:
        return list(self.page_tables.get(rid, []))

    # ------------------------------------------------------------- rewind
    def truncate(self, rid: int, keep_tokens: int) -> int:
        """Un-write rid's cache past ``keep_tokens`` tokens (speculative
        rollback): trailing rows of the partially-kept page and every
        wholly-unused trailing page are reset to the zero-initialized wire
        state (across every layer, at that layer's own format), and the
        trailing pages return to the free list.  No realloc — the kept
        prefix stays in place, so after a rewind the pool is
        byte-indistinguishable from one that never speculated.  Returns
        the number of pages released.
        """
        if keep_tokens < 0:
            raise ValueError(f"keep_tokens must be >= 0, got {keep_tokens}")
        tbl = self.page_tables.get(rid, [])
        keep_pages = -(-keep_tokens // self.page_size)
        if keep_pages > len(tbl):
            raise ValueError(
                f"truncate({rid}, {keep_tokens}) needs {keep_pages} pages "
                f"but the request owns {len(tbl)}")
        drop = tbl[keep_pages:]
        if keep_tokens < len(tbl) * self.page_size and tbl:
            # one fused dispatch resets the partial page's tail AND every
            # dropped page (fixed-length scratch-padded table -> one trace)
            padded = np.zeros((self.n_pages,), np.int32)
            padded[:len(tbl)] = tbl
            self.pages = self._reset_table(
                self.pages, jnp.asarray(padded),
                jnp.asarray(keep_tokens, jnp.int32))
        if drop:
            del self.page_tables[rid][keep_pages:]
            self._free.extend(reversed(drop))
        if self.obs.enabled:
            self.obs.event("rewind", rid=int(rid),
                           keep_tokens=int(keep_tokens),
                           released_pages=len(drop))
            self.obs.metrics.counter("pool_rewind_total").inc()
            self.obs.metrics.gauge("pool_occupancy").set(self.occupancy())
        return len(drop)

    def table_array(self, rid: int, max_pages: int) -> np.ndarray:
        """rid's page table as (max_pages,) int32, scratch-padded."""
        tbl = self.page_tables.get(rid, [])
        out = np.zeros((max_pages,), np.int32)
        out[:len(tbl)] = tbl
        return out

    # ------------------------------------------------------------- defrag
    def defrag(self) -> dict[int, int]:
        """Compact allocated pages into [1, n_allocated], preserving each
        request's page order.  Rewrites page tables and physically permutes
        the pool (jitted gather).  Returns the old->new page mapping."""
        perm = np.empty((self.n_pages,), np.int32)
        perm[0] = 0
        mapping: dict[int, int] = {}
        nxt = 1
        for rid, tbl in self.page_tables.items():
            for old in tbl:
                mapping[old] = nxt
                perm[nxt] = old
                nxt += 1
        leftovers = [p for p in range(1, self.n_pages) if p not in mapping]
        perm[nxt:] = leftovers
        self.pages = self._permute(self.pages, jnp.asarray(perm))
        self.page_tables = {rid: [mapping[p] for p in tbl]
                            for rid, tbl in self.page_tables.items()}
        self._free = list(range(self.n_pages - 1, nxt - 1, -1))
        if self.obs.enabled:
            self.obs.event("defrag", moved=sum(
                1 for old, new in mapping.items() if old != new))
            self.obs.metrics.counter("pool_defrag_total").inc()
        return mapping

    # --------------------------------------------------------- accounting
    def nbytes(self) -> int:
        return kvwire.cache_nbytes(self.pages)

    def page_nbytes(self) -> int:
        """Bytes of one page across all layers."""
        return self.nbytes() // self.n_pages
