"""Continuous-batching request scheduler over the paged engine.

Lifecycle (see serve/README.md): submit -> QUEUED -> (admit: prefill into
freshly allocated pages, take a decode slot) -> RUNNING -> interleaved
decode steps with every other in-flight request -> COMPLETE.  Admission is
FCFS within a priority lane, higher lanes first.  When the page pool is
exhausted mid-decode the scheduler preempts the lowest-priority,
latest-arrived victim (recompute-style: its pages are freed and it
re-queues at the front of its lane; on re-admission its prompt + generated
prefix is re-prefilled and decoding resumes from its last token).

With an fp KV cache, preempt/resume is bit-exact.  With a quantized cache
the re-prefilled prefix is attended at full precision during the resume
prefill only, so a resumed continuation may deviate from the uninterrupted
run — the same trade vLLM's recompute preemption makes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import numpy as np

from repro.obs import NOOP
from repro.serve.engine import PagedEngine
from repro.serve.pool import PagedKVPool

QUEUED, RUNNING, COMPLETE = "queued", "running", "complete"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    priority: int = 0
    on_token: Callable[[int, int], None] | None = None   # (rid, token)
    generated: list[int] = dataclasses.field(default_factory=list)
    state: str = QUEUED
    n_preemptions: int = 0
    rejected_tokens: int = 0  # draft tokens a speculative verify rejected
    arrival: int = 0          # submit order; FCFS tiebreak + victim choice
    tenant: str | None = None  # fleet routing tag (fleet/router.py)
    # observability state (populated only when the scheduler's obs is
    # enabled; None otherwise — absolute clock readings in seconds)
    t_submit: float | None = None   # submit() instant
    t_queued: float | None = None   # last (re-)enqueue instant
    t_first: float | None = None    # first emitted token (TTFT anchor)
    t_last: float | None = None     # latest emitted token (ITL anchor)
    trace_tid: int = 0              # the request's trace lane


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    tokens: tuple[int, ...]
    n_preemptions: int
    tenant: str | None = None
    rejected_tokens: int = 0


class Scheduler:
    """Admits a stream of requests and interleaves their decode steps."""

    def __init__(self, engine: PagedEngine, pool: PagedKVPool, *,
                 on_token=None, on_complete=None, seed: int = 0, obs=None):
        self.engine, self.pool = engine, pool
        self.pcfg = engine.pcfg
        self.on_token, self.on_complete = on_token, on_complete
        # repro.obs.Observability: request-lifecycle spans + the serving
        # latency histograms (TTFT / ITL / queue wait).  NOOP by default.
        self.obs = obs or NOOP
        if self.obs.enabled:
            self.obs.tracer.name_thread(0, "engine")
        # optional repro.obs.numerics.QualityMonitor: its on_step tap runs
        # the sampled shadow-divergence / KV dequant probes after each
        # decode step (host-side; never touches the compiled step)
        self.quality = None
        # optional repro.obs.profile.PhaseProfiler: same tap shape — the
        # sampled phase-attribution replays (gather/dequant/attention/...)
        self.profiler = None
        self._lanes: dict[int, deque[Request]] = {}
        self._requests: dict[int, Request] = {}
        self._slots: list[Request | None] = [None] * self.pcfg.max_slots
        self._pos = np.zeros((self.pcfg.max_slots,), np.int32)
        self._last_tok = np.zeros((self.pcfg.max_slots,), np.int32)
        self._next_rid = 0
        self._decode_steps = 0
        self._key_folds = 0
        self._key = jax.random.key(seed)

    # ------------------------------------------------------------- submit
    def submit(self, prompt, *, max_new_tokens: int = 16, priority: int = 0,
               on_token=None, tenant: str | None = None) -> int:
        """Validate-and-enqueue.  Every reason a request could never be
        admitted is rejected here with a ValueError (instead of live-locking
        the admit loop later): empty prompts, non-positive token budgets,
        contexts beyond the prefill bucket, and page demands the pool cannot
        satisfy even when completely empty."""
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        total = len(prompt) + max_new_tokens
        if total > self.pcfg.max_context:
            raise ValueError(f"prompt+max_new_tokens={total} exceeds "
                             f"max_context={self.pcfg.max_context}")
        need = -(-total // self.pcfg.page_size)
        if need > self.pool.n_allocatable:
            raise ValueError(
                f"request needs {need} pages at full length but the pool "
                f"holds only {self.pool.n_allocatable} allocatable pages "
                f"(n_pages={self.pool.n_pages} minus scratch); it could "
                f"never be admitted")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, max_new_tokens, priority=priority,
                      on_token=on_token, arrival=rid, tenant=tenant)
        if self.obs.enabled:
            req.t_submit = req.t_queued = self.obs.clock()
            label = f"{tenant}/r{rid}" if tenant else f"req-{rid}"
            req.trace_tid = self.obs.tracer.new_tid(label)
            self.obs.event("submit", tid=req.trace_tid, rid=rid,
                           prompt_len=len(prompt))
        self._requests[rid] = req
        self._lanes.setdefault(priority, deque()).append(req)
        return rid

    # -------------------------------------------------------------- state
    @property
    def has_work(self) -> bool:
        return any(self._lanes.values()) or any(
            r is not None for r in self._slots)

    def active_requests(self) -> list[Request]:
        return [r for r in self._slots if r is not None]

    def queued_requests(self) -> list[Request]:
        return [r for lane in self._lanes.values() for r in lane]

    def stats(self) -> dict:
        return {"active": len(self.active_requests()),
                "queued": len(self.queued_requests()),
                "pool_occupancy": self.pool.occupancy(),
                "steps": self._decode_steps,
                "preemptions": sum(r.n_preemptions
                                   for r in self._requests.values()),
                # speculative-rejection rollbacks are NOT preemptions: the
                # slot keeps running, only its cache tail is un-written —
                # they get their own counter (fleet/telemetry.py)
                "rejected_tokens": sum(r.rejected_tokens
                                       for r in self._requests.values())}

    def request(self, rid: int) -> Request:
        return self._requests[rid]

    def outputs(self) -> dict[int, list[int]]:
        """Generated tokens of every submitted request so far."""
        return {rid: list(r.generated) for rid, r in self._requests.items()}

    # ------------------------------------------------------------ helpers
    def _tenant_label(self, req: Request) -> str:
        return req.tenant if req.tenant is not None else "default"

    def _emit(self, req: Request, tok: int):
        req.generated.append(tok)
        if self.obs.enabled:
            now = self.obs.clock()
            tenant = self._tenant_label(req)
            if req.t_first is None:
                req.t_first = now
                if req.t_submit is not None:
                    self.obs.metrics.histogram(
                        "serve_ttft_ms", tenant=tenant).record(
                        (now - req.t_submit) * 1e3)
                self.obs.event("first_token", tid=req.trace_tid,
                               rid=req.rid)
            elif req.t_last is not None:
                self.obs.metrics.histogram(
                    "serve_itl_ms", tenant=tenant).record(
                    (now - req.t_last) * 1e3)
            req.t_last = now
            self.obs.metrics.counter("serve_tokens_total",
                                     tenant=tenant).inc()
        if req.on_token:
            req.on_token(req.rid, tok)
        if self.on_token:
            self.on_token(req.rid, tok)

    def _finish(self, req: Request, slot: int | None,
                events: list[Completion]):
        if slot is not None:
            self._slots[slot] = None
        self.pool.free(req.rid)
        req.state = COMPLETE
        if self.obs.enabled:
            now = self.obs.clock()
            tenant = self._tenant_label(req)
            if req.t_submit is not None:
                self.obs.tracer.complete(
                    "request", req.t_submit, now - req.t_submit,
                    tid=req.trace_tid, rid=req.rid, tenant=tenant,
                    n_tokens=len(req.generated),
                    preemptions=req.n_preemptions)
            self.obs.metrics.counter("serve_completions_total",
                                     tenant=tenant).inc()
        done = Completion(req.rid, tuple(req.generated), req.n_preemptions,
                          tenant=req.tenant,
                          rejected_tokens=req.rejected_tokens)
        events.append(done)
        if self.on_complete:
            self.on_complete(done)

    def _next_queued(self) -> Request | None:
        for prio in sorted(self._lanes, reverse=True):
            if self._lanes[prio]:
                return self._lanes[prio].popleft()
        return None

    def _requeue_front(self, req: Request):
        self._lanes.setdefault(req.priority, deque()).appendleft(req)

    def _fold_key(self):
        self._key_folds += 1
        return jax.random.fold_in(self._key, self._key_folds)

    # -------------------------------------------------------------- admit
    def _admit(self, events: list[Completion]):
        while None in self._slots:
            req = self._next_queued()
            if req is None:
                return
            resume = bool(req.generated)
            # resume re-prefills prompt + generated[:-1]; the last generated
            # token is re-fed through the decode step so the continuation
            # samples from the same (quantized-cache) attention as an
            # uninterrupted run.
            tokens = req.prompt + req.generated[:-1]
            need = -(-len(tokens) // self.pcfg.page_size)
            if not self.pool.alloc(req.rid, need):
                self._requeue_front(req)
                return
            if self.obs.enabled and req.t_queued is not None:
                now = self.obs.clock()
                wait = now - req.t_queued
                self.obs.metrics.histogram(
                    "serve_queue_wait_ms",
                    tenant=self._tenant_label(req)).record(wait * 1e3)
                self.obs.tracer.complete("queued", req.t_queued, wait,
                                         tid=req.trace_tid, rid=req.rid)
            first = self.engine.prefill_request(
                self.pool, tokens, self.pool.pages_of(req.rid),
                self._fold_key())
            slot = self._slots.index(None)
            req.state = RUNNING
            if resume:
                tok = req.generated[-1]
            else:
                tok = first
                self._emit(req, tok)
                if len(req.generated) >= req.max_new_tokens:
                    self._finish(req, None, events)
                    continue
            self._slots[slot] = req
            self._pos[slot] = len(tokens)
            self._last_tok[slot] = tok

    # ------------------------------------------------------------ preempt
    def _preempt_victim(self) -> bool:
        """Evict the lowest-priority, latest-arrived running request."""
        victims = [(r.priority, -r.arrival, i)
                   for i, r in enumerate(self._slots) if r is not None]
        if not victims:
            return False
        _, _, slot = min(victims)
        req = self._slots[slot]
        self._slots[slot] = None
        self.pool.free(req.rid)
        req.state = QUEUED
        req.n_preemptions += 1
        if self.obs.enabled:
            req.t_queued = self.obs.clock()
            self.obs.event("preempt", tid=req.trace_tid, rid=req.rid,
                           priority=req.priority)
            self.obs.metrics.counter(
                "serve_preemptions_total",
                tenant=self._tenant_label(req)).inc()
        self._requeue_front(req)
        return True

    def _ensure_pages(self):
        """Every active slot needs the pages covering every position the
        engine may write this step (``engine.lookahead_tokens`` rows for a
        speculative engine's candidate run); preempt on exhaustion."""
        look = getattr(self.engine, "lookahead_tokens", 1)
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            # lookahead rows past the request's own maximum length need no
            # pages: the slot's scratch-padded table routes those writes
            # to page 0, and tokens scored there are beyond the budget
            total = len(req.prompt) + req.max_new_tokens
            last = min(int(self._pos[slot]) + look - 1, total - 1,
                       self.pcfg.max_context - 1)
            need_idx = last // self.pcfg.page_size
            while need_idx >= len(self.pool.pages_of(req.rid)):
                if self.pool.alloc(req.rid, 1):
                    continue      # may need more than one page (lookahead)
                active = [r for r in self._slots if r is not None]
                if len(active) <= 1:
                    raise RuntimeError(
                        "page pool exhausted with a single request in "
                        "flight; increase n_pages")
                self._preempt_victim()
                if self._slots[slot] is None:   # the victim was this slot
                    break

    # ---------------------------------------------------------------- step
    def step(self) -> list[Completion]:
        """Admit what fits, then advance every in-flight request.

        A plain :class:`~repro.serve.engine.PagedEngine` emits exactly one
        token per slot; a speculative engine may emit several accepted
        tokens per slot per step (``engine.advance_slots`` returns
        per-slot emission lists plus rejected-draft counts).  Emission is
        capped at each request's remaining token budget — any cache rows
        the engine wrote past the cap die with the request's pages.
        """
        events: list[Completion] = []
        self._admit(events)
        self._ensure_pages()
        active = [i for i, r in enumerate(self._slots) if r is not None]
        if not active:
            return events

        table = np.zeros((self.pcfg.max_slots, self.pcfg.pages_per_slot),
                         np.int32)
        budget = [0] * self.pcfg.max_slots
        for i in active:
            table[i] = self.pool.table_array(self._slots[i].rid,
                                             self.pcfg.pages_per_slot)
            budget[i] = (self._slots[i].max_new_tokens
                         - len(self._slots[i].generated))
        pos = np.where([r is not None for r in self._slots], self._pos, 0)
        # the engine-lane decode span; a speculative engine opens its
        # draft/verify child spans inside it (noop tracer: a shared null
        # context, no recording)
        with self.obs.tracer.span("decode", step=self._decode_steps,
                                  n_slots=len(active)):
            emitted, rejected = self.engine.advance_slots(
                self.pool, self._last_tok, table, pos.astype(np.int32),
                self._fold_key(), budget=budget)
        self._decode_steps += 1

        look = getattr(self.engine, "lookahead_tokens", 1)
        for i in active:
            req = self._slots[i]
            req.rejected_tokens += int(rejected[i])
            for tok in emitted[i]:
                if len(req.generated) >= req.max_new_tokens:
                    break
                self._pos[i] += 1
                self._last_tok[i] = int(tok)
                self._emit(req, int(tok))
            if len(req.generated) >= req.max_new_tokens:
                self._finish(req, i, events)
            elif look > 1:
                # speculative rollback: un-write cache rows past the
                # accepted prefix and release surplus lookahead pages —
                # the slot keeps running (NOT a preemption)
                self.pool.truncate(req.rid, int(self._pos[i]))
        if self.quality is not None:
            self.quality.on_step(self)
        if self.profiler is not None:
            self.profiler.on_step(self)
        return events

    def drain(self, max_steps: int | None = None) -> dict[int, list[int]]:
        """Run until every submitted request completes."""
        steps = 0
        while self.has_work:
            self.step()
            steps += 1
            if max_steps is not None and steps > max_steps:
                raise RuntimeError("drain exceeded max_steps")
        return self.outputs()
