"""Batched serving engine: prefill + decode with quantized weights/cache.

The deployment path of the paper's scheme end-to-end:

  * weights:    offline ``transformer.quantize_params`` -> packed QWeight
                (local quantization regions; kernels/quant_matmul on TPU);
  * activations: per-projection runtime quantization via the policy's
                ``a_bits`` (paper section V.B "inputs ... converted into
                fixed point in runtime");
  * KV cache:   ``kv_bits`` stores K/V (or the SSM state) in the LQ wire
                format (core/kvwire.py).

``generate`` runs greedy or temperature sampling with a lax.scan'd decode
loop inside one jit — per-token Python overhead is zero; batching is the
(B, ...) leading dim end-to-end.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import kvwire, schemes
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import QuantPolicy, NO_QUANT


def greedy_sample(logits, key):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(temperature: float = 1.0, top_k: int | None = None):
    def fn(logits, key):
        lg = logits / max(temperature, 1e-6)
        if top_k is not None:
            kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
            lg = jnp.where(lg < kth, -1e9, lg)
        return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
    return fn


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_len: int = 2048
    kv_bits: int | None = None           # None = fp cache
    kv_group: int = 64
    weight_scheme: str | None = None     # e.g. "lq4w"; None = fp weights
    a_bits: int | None = None            # runtime activation quantization
    backend: str = "auto"
    temperature: float = 0.0             # 0 => greedy
    top_k: int | None = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        self.cfg, self.ecfg = cfg, ecfg
        if ecfg.weight_scheme is not None:
            qcfg = schemes.get(ecfg.weight_scheme)
            if ecfg.a_bits is not None:
                qcfg = dataclasses.replace(qcfg, a_bits=ecfg.a_bits)
            self.params = transformer.quantize_params(params, cfg, qcfg)
            self.policy = QuantPolicy.serve(qcfg, backend=ecfg.backend)
        else:
            self.params = params
            self.policy = NO_QUANT
        self._sample = (greedy_sample if ecfg.temperature == 0.0 else
                        temperature_sample(ecfg.temperature, ecfg.top_k))
        self._generate = jax.jit(self._generate_impl,
                                 static_argnames=("steps",))

    # ------------------------------------------------------------------
    def init_cache(self, batch: int):
        kvq = ((self.ecfg.kv_bits, self.ecfg.kv_group)
               if self.ecfg.kv_bits is not None else None)
        return transformer.init_cache(self.cfg, batch, self.ecfg.max_len,
                                      kv_quant=kvq)

    def _generate_impl(self, params, batch, cache, key, *, steps: int):
        logits, cache = transformer.prefill(params, self.cfg, batch, cache,
                                            policy=self.policy)
        first = self._sample(logits[:, -1], key)

        def step(carry, k):
            tok, cache = carry
            logits, cache = transformer.decode_step(
                params, self.cfg, tok[:, None], cache, policy=self.policy)
            nxt = self._sample(logits[:, -1], k)
            return (nxt, cache), nxt

        keys = jax.random.split(key, steps)
        (_, cache), toks = jax.lax.scan(step, (first, cache), keys)
        out = jnp.concatenate([first[:, None], jnp.moveaxis(toks, 0, 1)],
                              axis=1)
        return out, cache

    def generate(self, batch: dict, *, steps: int, seed: int = 0):
        """batch: {'tokens': (B, L)} (+ frontend inputs).  Returns
        (generated (B, steps+1) int32, final cache)."""
        b = batch["tokens"].shape[0]
        cache = self.init_cache(b)
        return self._generate(self.params, batch, cache,
                              jax.random.key(seed), steps=steps)

    # ------------------------------------------------------------------
    def cache_bytes(self, batch: int) -> int:
        """HBM bytes of the decode cache (the kv_bits win, measurable)."""
        return kvwire.cache_nbytes(jax.eval_shape(
            lambda: self.init_cache(batch)))
