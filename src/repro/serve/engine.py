"""Batched serving engine: prefill + decode with quantized weights/cache.

The deployment path of the paper's scheme end-to-end:

  * weights:    offline ``transformer.quantize_params`` -> packed QWeight
                (local quantization regions; kernels/quant_matmul on TPU);
  * activations: per-projection runtime quantization via the policy's
                ``a_bits`` (paper section V.B "inputs ... converted into
                fixed point in runtime");
  * KV cache:   ``kv_bits`` stores K/V (or the SSM state) in the LQ wire
                format (core/kvwire.py);
  * mixed precision: ``EngineConfig.plan`` (a ``repro.plan.QuantPlan``)
                assigns a per-layer scheme instead of one uniform
                ``weight_scheme`` — the planned model serves through the
                identical prefill/decode/paged paths.

``generate`` runs greedy or temperature sampling with a lax.scan'd decode
loop inside one jit — per-token Python overhead is zero; batching is the
(B, ...) leading dim end-to-end.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kvwire, schemes
from repro.kernels import paged_attention as paged_attn
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import QuantPolicy, NO_QUANT
from repro.obs import NOOP, Stopwatch
from repro.obs.profile import annotate
from repro.serve.pool import PagedKVPool


def greedy_sample(logits, key):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(temperature: float = 1.0, top_k: int | None = None):
    def fn(logits, key):
        lg = logits / max(temperature, 1e-6)
        if top_k is not None:
            kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
            lg = jnp.where(lg < kth, -1e9, lg)
        return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
    return fn


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_len: int = 2048
    kv_bits: int | None = None           # None = fp cache
    kv_group: int = 64
    weight_scheme: str | None = None     # e.g. "lq4w"; None = fp weights
    a_bits: int | None = None            # runtime activation quantization
    plan: object = None                  # QuantPlan: per-layer mixed precision
    backend: str = "auto"
    temperature: float = 0.0             # 0 => greedy
    top_k: int | None = None
    # paged decode through the fused flash-decode kernel
    # (kernels/paged_attention.py): wire pages stream through VMEM and
    # dequantize in-register instead of gather -> fp pool view -> attend.
    # Compiled on TPU, interpret-mode elsewhere; silently falls back to
    # the XLA gather path when Pallas is unavailable.
    fused_attention: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig, *,
                 obs=None):
        self.cfg, self.ecfg = cfg, ecfg
        # repro.obs.Observability; NOOP records nothing at ~zero cost.
        # Host-side only: instrumentation never enters a jitted function,
        # so enabling it cannot add a retrace.
        self.obs = obs or NOOP
        self.obs_metric_labels: dict = {}  # e.g. {"engine": "draft"}
        if ecfg.plan is not None:
            if ecfg.weight_scheme is not None:
                raise ValueError("pass either a uniform weight_scheme or a "
                                 "plan, not both")
            if ecfg.a_bits is not None:
                raise ValueError("a_bits is per-layer under a plan — set it "
                                 "in the plan's QuantConfigs instead")
            if transformer.is_quantized_params(params):
                # pre-packed by the caller (leaf-cache sharing across
                # engines: repro.spec draft/verifier, repro.fleet tenants)
                self.params = params
            else:
                self.params = transformer.quantize_params(params, cfg,
                                                          ecfg.plan)
            self.policy = ecfg.plan.policy(cfg, mode="serve",
                                           backend=ecfg.backend)
        elif ecfg.weight_scheme is not None:
            qcfg = schemes.get(ecfg.weight_scheme)
            if ecfg.a_bits is not None:
                qcfg = dataclasses.replace(qcfg, a_bits=ecfg.a_bits)
            self.params = transformer.quantize_params(params, cfg, qcfg)
            self.policy = QuantPolicy.serve(qcfg, backend=ecfg.backend)
        else:
            self.params = params
            self.policy = NO_QUANT
        self._kv_layout = self._resolve_kv_layout()
        self._sample = (greedy_sample if ecfg.temperature == 0.0 else
                        temperature_sample(ecfg.temperature, ecfg.top_k))
        self._generate = jax.jit(self._generate_impl,
                                 static_argnames=("steps",))

    # ------------------------------------------------------------------
    def _resolve_kv_layout(self):
        """The engine's cache wire spec: ``(bits, group)`` where ``bits``
        is None (fp), one int (uniform), or the plan's per-layer map."""
        plan = self.ecfg.plan
        if plan is not None and getattr(plan, "has_kv", False):
            if self.ecfg.kv_bits is not None:
                raise ValueError("kv_bits is per-layer under a plan with a "
                                 "kv map — set it in the plan instead")
            return plan.resolve_kv(self.cfg), plan.kv_group
        return self.ecfg.kv_bits, self.ecfg.kv_group

    def _kv_quant(self):
        bits, group = self._kv_layout
        return None if bits is None else (bits, group)

    def init_cache(self, batch: int):
        return transformer.init_cache(self.cfg, batch, self.ecfg.max_len,
                                      kv_quant=self._kv_quant())

    def _generate_impl(self, params, batch, cache, key, *, steps: int):
        logits, cache = transformer.prefill(params, self.cfg, batch, cache,
                                            policy=self.policy)
        first = self._sample(logits[:, -1], key)

        def step(carry, k):
            tok, cache = carry
            logits, cache = transformer.decode_step(
                params, self.cfg, tok[:, None], cache, policy=self.policy)
            nxt = self._sample(logits[:, -1], k)
            return (nxt, cache), nxt

        keys = jax.random.split(key, steps)
        (_, cache), toks = jax.lax.scan(step, (first, cache), keys)
        out = jnp.concatenate([first[:, None], jnp.moveaxis(toks, 0, 1)],
                              axis=1)
        return out, cache

    def generate(self, batch: dict, *, steps: int, seed: int = 0):
        """batch: {'tokens': (B, L)} (+ frontend inputs).  Returns
        (generated (B, steps+1) int32, final cache)."""
        b = batch["tokens"].shape[0]
        cache = self.init_cache(b)
        return self._generate(self.params, batch, cache,
                              jax.random.key(seed), steps=steps)

    # ------------------------------------------------------------------
    def cache_bytes(self, batch: int) -> int:
        """HBM bytes of the decode cache (the kv_bits win, measurable)."""
        return kvwire.cache_nbytes(jax.eval_shape(
            lambda: self.init_cache(batch)))


# ---------------------------------------------------------------------------
# paged engine: prefill/decode against a shared page pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PagedConfig:
    """Geometry of the continuous-batching serve cell.

    max_context bounds prompt + generation per request and fixes the static
    shapes: the prefill bucket is max_context tokens and every decode step
    gathers max_context // page_size pages per slot.  n_pages counts
    physical pages including the reserved scratch page 0.
    """
    max_slots: int = 4
    page_size: int = 16
    n_pages: int = 64
    max_context: int = 256

    def __post_init__(self):
        if self.max_context % self.page_size:
            raise ValueError("max_context must be a multiple of page_size")

    @property
    def pages_per_slot(self) -> int:
        return self.max_context // self.page_size


class PagedEngine(Engine):
    """Engine whose prefill/decode operate on gathered page views.

    Prefill runs one request (B=1) through the contiguous path on a
    fixed-size right-padded bucket, then scatters the bucket's wire cache
    into the request's pages — one jit for every prompt length.  Decode
    advances all max_slots slots in a single jit (static shapes; inactive
    slots are padded onto the scratch page and masked), with each layer
    gathering its slot page views from the shared pool.
    """

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 pcfg: PagedConfig, *, obs=None):
        super().__init__(cfg, params, ecfg, obs=obs)
        if pcfg.max_context > ecfg.max_len:
            raise ValueError("pcfg.max_context exceeds ecfg.max_len")
        self.pcfg = pcfg
        self._kvq = self._kv_quant()
        # None (XLA gather+dequant) | "pallas" | "interpret"; a static
        # closure value, so toggling it is a different engine, never a
        # retrace of a running one
        self.fused_mode = paged_attn.resolve_mode(ecfg.fused_attention)
        self.fused_fallback = (bool(ecfg.fused_attention)
                               and self.fused_mode is None)
        self._fused_fallback_reported = False
        self.report_attention_mode()
        self._prefill_paged = jax.jit(self._prefill_paged_impl)
        self._step_paged = jax.jit(self._step_paged_impl)
        self._multi_paged = jax.jit(self._multi_paged_impl)

    def new_pool(self) -> PagedKVPool:
        bits, group = self._kv_layout
        return PagedKVPool(self.cfg, n_pages=self.pcfg.n_pages,
                           page_size=self.pcfg.page_size,
                           kv_bits=bits, kv_group=group, obs=self.obs)

    @property
    def attention_mode(self) -> str:
        """The *resolved* paged-decode path this engine actually runs:
        ``fused-pallas`` / ``fused-interpret`` when the Pallas kernel is
        live, ``xla-fallback`` when fused was requested but unavailable,
        plain ``xla`` when never requested."""
        if self.fused_mode is not None:
            return f"fused-{self.fused_mode}"
        return "xla-fallback" if self.fused_fallback else "xla"

    def report_attention_mode(self, obs=None):
        """One-shot ``fused_fallback`` event + counter for a downgraded
        engine.  Engines are often built with NOOP obs and get the real
        one attached post-warmup (Server.set_obs / FleetRouter._wire), so
        this re-arms until an *enabled* obs actually records it."""
        if not self.fused_fallback or self._fused_fallback_reported:
            return
        self._fused_fallback_reported = paged_attn.report_fallback(
            obs if obs is not None else self.obs)

    # ------------------------------------------------------------- jitted
    def _scatter_bucket(self, pages, cache, page_ids):
        """Scatter a contiguous B=1 prefill cache into pool pages.

        The bucket cache and the pool share one decoder-stack layout
        (homogeneous ``"super"`` or heterogeneous ``"super_segments"`` —
        both built from the engine's kv spec), so the copy is structural:
        ``scatter_prefill`` tree-maps leaf-for-leaf at whatever wire
        format each layer carries.
        """
        sup_key = "super_segments" if "super_segments" in pages else "super"
        return {sup_key: kvwire.scatter_prefill(pages[sup_key],
                                                cache[sup_key], page_ids,
                                                stacked=True),
                "tail": kvwire.scatter_prefill(pages["tail"], cache["tail"],
                                               page_ids)}

    def _prefill_paged_impl(self, params, tokens, pages, page_ids,
                            logits_pos, key):
        cache = transformer.init_cache(self.cfg, 1, self.pcfg.max_context,
                                       kv_quant=self._kvq)
        logits, cache = transformer.prefill(
            params, self.cfg, {"tokens": tokens}, cache, policy=self.policy,
            logits_pos=logits_pos)
        pages = self._scatter_bucket(pages, cache, page_ids)
        return self._sample(logits[:, -1], key), pages

    def _step_paged_impl(self, params, pages, tokens, page_table, pos, key):
        logits, pages = transformer.paged_decode_step(
            params, self.cfg, tokens[:, None], pages, page_table, pos,
            policy=self.policy, fused=self.fused_mode)
        return self._sample(logits[:, -1], key), pages

    def _multi_paged_impl(self, params, pages, tokens, page_table, pos):
        logits, pages = transformer.paged_decode_multi(
            params, self.cfg, tokens, pages, page_table, pos,
            policy=self.policy, fused=self.fused_mode)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), pages

    # --------------------------------------------------------------- host
    def prefill_request(self, pool: PagedKVPool, tokens, page_ids,
                        key) -> int:
        """Prefill one request into its pages; returns the sampled first
        continuation token.  ``tokens`` is the (unpadded) int prompt."""
        bucket = self.pcfg.max_context
        if len(tokens) > bucket:
            raise ValueError(f"prompt len {len(tokens)} > bucket {bucket}")
        obs = self.obs
        if not obs.enabled:
            return self._prefill_host(pool, tokens, page_ids, key)
        # measured wall clock brackets the compiled step end to end:
        # block_until_ready on the scattered pages, not just the token
        sw = Stopwatch(obs.clock)
        with obs.tracer.span("prefill", n_tokens=len(tokens),
                             **self.obs_metric_labels):
            tok = self._prefill_host(pool, tokens, page_ids, key)
            jax.block_until_ready(pool.pages)
        obs.metrics.histogram("serve_prefill_ms",
                              **self.obs_metric_labels).record(
            sw.elapsed_ms())
        return tok

    def _prefill_host(self, pool: PagedKVPool, tokens, page_ids,
                      key) -> int:
        padded = np.zeros((1, self.pcfg.max_context), np.int32)
        padded[0, :len(tokens)] = tokens
        ids = np.zeros((self.pcfg.pages_per_slot,), np.int32)
        ids[:len(page_ids)] = page_ids
        with annotate("prefill"):       # xprof TraceMe; metadata only
            tok, pool.pages = self._prefill_paged(
                self.params, jnp.asarray(padded), pool.pages,
                jnp.asarray(ids), jnp.asarray(len(tokens) - 1, jnp.int32),
                key)
        return int(tok[0])

    def decode_step_batch(self, pool: PagedKVPool, tokens, page_table, pos,
                          key) -> np.ndarray:
        """Advance every slot one token.  tokens/pos (max_slots,),
        page_table (max_slots, pages_per_slot).  Returns sampled tokens."""
        obs = self.obs
        sw = Stopwatch(obs.clock) if obs.enabled else None
        with annotate("decode_step"):   # xprof TraceMe; metadata only
            toks, pool.pages = self._step_paged(
                self.params, pool.pages, jnp.asarray(tokens, jnp.int32),
                jnp.asarray(page_table, jnp.int32),
                jnp.asarray(pos, jnp.int32), key)
        out = np.asarray(toks)
        if sw is not None:
            jax.block_until_ready(pool.pages)
            obs.metrics.histogram("serve_decode_step_ms",
                                  **self.obs_metric_labels).record(
                sw.elapsed_ms())
        return out

    def decode_multi_batch(self, pool: PagedKVPool, tokens, page_table,
                           pos) -> np.ndarray:
        """Greedy-score a length-L candidate run per slot in ONE compiled
        batched forward (the speculative verify step).  tokens
        (max_slots, L); returns the greedy next token at every position
        (max_slots, L) — all L candidates' K/V are written to the pool, so
        rejected suffixes must be un-written via ``pool.truncate``."""
        toks, pool.pages = self._multi_paged(
            self.params, pool.pages, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(page_table, jnp.int32), jnp.asarray(pos, jnp.int32))
        return np.asarray(toks)

    # ------------------------------------------------------- scheduler API
    @property
    def lookahead_tokens(self) -> int:
        """Cache rows one scheduler step may write per slot at/past its
        position (speculative engines write their whole candidate run)."""
        return 1

    def advance_slots(self, pool: PagedKVPool, tokens, page_table, pos,
                      key, budget=None):
        """Scheduler step contract: advance every slot, returning
        ``(emitted, rejected)`` — per-slot lists of emitted tokens and
        per-slot rejected-draft counts.  The plain engine emits exactly
        one token per slot and never rejects; ``budget`` (per-slot max
        tokens to emit) is honored trivially."""
        toks = self.decode_step_batch(pool, tokens, page_table, pos, key)
        return [[int(t)] for t in toks], [0] * len(toks)

    @property
    def decode_compilations(self) -> int:
        """Distinct decode-step traces (1 == no per-step retrace)."""
        return self._step_paged._cache_size()
