"""Perf-attribution plane: where a decode step's device time actually goes.

The latency plane (PR 6) says *how long* a decode step takes; the cost
model (plan/costmodel.py) says how long it *should* take.  This module
closes the gap with three instruments, all host-side and NOOP-default —
nothing here ever enters the engine's compiled functions, so
``decode_compilations`` stays 1 and token streams are bit-identical with
profiling on:

* **annotations** — :func:`annotate` (a ``jax.profiler.TraceAnnotation``
  host TraceMe) labels prefill / decode / draft / verify host calls in
  xprof captures, and ``jax.named_scope`` markers inside the model code
  (transformer.py) label the HLO ops per phase / walker segment.  Both
  are metadata-only: numerics and trace caches are untouched.
* **phase profiler** — :class:`PhaseProfiler`, a scheduler tap (attach
  via ``Server.attach_profiler``).  Every ``every_n_steps`` decode steps
  it replays the step's sub-phases against the engine's *live* pool state
  in standalone jits (compiled once each, never shared with the engine's):
  page ``gather``, wire ``dequant``, ``attention`` over the gathered
  cache, and the ``lm_head`` (final norm + logits), each
  ``block_until_ready``-bounded, plus one full decode-step replay through
  the engine's own already-compiled jit (same shapes — no new trace).
  A fused engine (``EngineConfig.fused_attention``) replays ONE
  ``fused_attention`` phase per stack run instead of the gather/dequant/
  attention triplet — the decomposition no longer exists on device, and
  pretending it does would mis-attribute the step.
  Histograms ``serve_phase_ms{phase=...,layer_run=...}`` per stack run
  (``run0``/``run1``/.../``tail0``; ``all`` for stack-wide phases), with
  the unattributed remainder ``phase="other"`` defined as
  ``max(0, step_replay - sum(measured phases))`` so the phases sum to at
  least the replayed step by construction.
* **utilization gauges** — :func:`record_utilization` divides the cost
  model's per-step FLOPs and wire bytes by the measured
  ``serve_decode_step_ms`` p50: gauges ``serve_mfu`` and
  ``serve_hbm_util``.  Pass ``hw=repro.obs.calibrated_hw(...)`` to
  normalize against the measured host roof instead of the stock
  roofline (both gauges are clamped to (0, 1] — calibration folds batch
  efficiency into the roof, so the clamp guards the gauge contract).

``python -m repro.launch.serve --profile [--profile-every N]`` wires the
profiler + gauges into a serve run; ``--xprof-out DIR`` additionally
captures a programmatic ``jax.profiler`` trace (:func:`xprof_capture`)
viewable in TensorBoard/XProf.  ``python -m repro.obs.check trace.json
metrics.json --profile`` validates the artifacts.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kvwire
from repro.kernels import paged_attention as paged_attn
from repro.models import attention, transformer
from repro.obs.metrics import Stopwatch

# the two decompositions a decode step can attribute to: the XLA path
# splits into gather/dequant/attention; a fused engine
# (EngineConfig.fused_attention) runs all three as ONE kernel, so its
# honest attribution is a single fused_attention phase per stack run
PHASES = ("gather", "dequant", "attention", "lm_head", "other")
FUSED_PHASES = ("fused_attention", "lm_head", "other")


def annotate(name: str):
    """Host-side xprof annotation (``jax.profiler.TraceAnnotation``).

    Labels the enclosed host work — the dispatch of a prefill/decode/
    draft/verify call — in programmatic profiler captures.  Metadata
    only: a TraceMe never touches computation, and an unavailable
    profiler degrades to a null context.
    """
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()


@contextlib.contextmanager
def xprof_capture(out_dir: str):
    """Programmatic ``jax.profiler`` capture around a block.

    Writes a TensorBoard/XProf trace under ``out_dir`` (the
    ``--xprof-out`` flag of ``repro.launch.serve``).  Capture failures
    degrade to a warning — profiling must never take the serve run down.
    """
    started = False
    try:
        jax.profiler.start_trace(out_dir)
        started = True
    except Exception as e:                                # pragma: no cover
        print(f"xprof capture unavailable: {e}")
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception as e:                        # pragma: no cover
                print(f"xprof capture failed to stop: {e}")


# ---------------------------------------------------------------------------
# sampled phase profiler (scheduler tap)
# ---------------------------------------------------------------------------

def _pool_runs(pages) -> list:
    """``[(layer_run, block_tuple, stacked)]`` over a pool's stack runs.

    One entry per scan run of the pool (homogeneous ``super``, or one per
    heterogeneous ``super_segments`` run) plus one per tail block — the
    same granularity the planned-stack walker compiles at, so phase times
    attribute to the units that can actually be optimized separately.
    """
    runs = []
    if "super_segments" in pages:
        for r, seg in enumerate(pages["super_segments"]):
            runs.append((f"run{r}", seg, True))
    elif pages.get("super"):
        runs.append(("run0", pages["super"], True))
    for t, block in enumerate(pages["tail"]):
        runs.append((f"tail{t}", (block,), False))
    return runs


def _run_kv(block_tuple) -> list:
    """The attention K/V leaves of one run (skips mixers with no cache)."""
    out = []
    for block in block_tuple:
        self_kv = block.get("self") if isinstance(block, dict) else None
        if isinstance(self_kv, dict) and "k" in self_kv and "v" in self_kv:
            out.append({"k": self_kv["k"], "v": self_kv["v"]})
    return out


class PhaseProfiler:
    """Sampled per-phase decode-step attribution over one scheduler.

    Attach via ``Server.attach_profiler`` (or ``scheduler.profiler = p``);
    the scheduler calls :meth:`on_step` after each decode step.  Works
    with plain and speculative engines — a :class:`SpeculativeEngine`
    profiles through its verifier, whose step dominates the cycle.

    Every probe replays the current step's sub-phases against the live
    pool pages / page tables / positions in standalone jits, so the
    recorded milliseconds are the real gather/dequant/attention cost of
    the traffic being served — not a synthetic microbenchmark.  Probe
    keys are self-owned: the scheduler's sampling key stream is never
    advanced, which keeps token streams bit-identical with profiling on.
    """

    def __init__(self, obs, cfg, engine, *, every_n_steps: int = 8):
        self.obs = obs
        self.cfg = cfg
        self.engine = engine
        # the paged engine whose params/policy/pool the replays mirror
        self.core = getattr(engine, "verifier", engine)
        self.every_n_steps = every_n_steps
        self.steps = 0
        self._jits: dict = {}           # layer_run -> (gather, dequant, attend)
        self._lm_head = None
        pcfg = self.core.pcfg
        g = cfg.n_heads // cfg.n_kv_heads
        key = jax.random.key(0)
        # fixed synthetic query / pre-lm-head activation: phase cost
        # depends on shapes and cache contents, not these values
        self._q = jax.random.normal(
            key, (pcfg.max_slots, 1, cfg.n_kv_heads, g, cfg.head_dim),
            cfg.activation_dtype)
        self._x = jax.random.normal(
            jax.random.fold_in(key, 1), (pcfg.max_slots, 1, cfg.d_model),
            cfg.activation_dtype)

    # -------------------------------------------------------------- hook
    def on_step(self, sched):
        """Scheduler tap: runs after each decode step (host-side only)."""
        self.steps += 1
        every = self.every_n_steps
        if every <= 0 or self.steps % every:
            return None
        if not any(r is not None for r in sched._slots):
            return None
        return self.probe(sched)

    # -------------------------------------------------------------- jits
    def _phase_jits(self, label: str, kvs, stacked: bool):
        """Standalone gather/dequant/attention jits for one stack run,
        compiled once (fixed pool shapes) and never shared with the
        engine's functions — profiling cannot retrace the serving path."""
        if label in self._jits:
            return self._jits[label]
        d = self.cfg.head_dim
        dtype = self.cfg.activation_dtype
        quant = any(kvwire.is_quant_kv(kv["k"]) for kv in kvs)

        def gather(kv_list, table):
            fn = (jax.vmap(kvwire.gather_pages, in_axes=(0, None))
                  if stacked else kvwire.gather_pages)
            return [{k: fn(leaf, table) for k, leaf in kv.items()}
                    for kv in kv_list]

        def dequant(gathered):
            return [{k: (kvwire.dequantize_kv(v, d, dtype)
                         if kvwire.is_quant_kv(v) else v)
                     for k, v in kv.items()} for kv in gathered]

        def attend(dq, q, pos):
            attn = attention.decode_attention
            fn = (jax.vmap(lambda k, v: attn(q, k, v, pos))
                  if stacked else (lambda k, v: attn(q, k, v, pos)))
            return [fn(kv["k"], kv["v"]) for kv in dq]

        jits = (jax.jit(gather), jax.jit(dequant) if quant else None,
                jax.jit(attend))
        self._jits[label] = jits
        return jits

    def _fused_jit(self, label: str, stacked: bool):
        """Standalone fused-kernel replay for one stack run — the single
        phase a fused engine's step actually executes per layer."""
        key = ("fused", label)
        if key in self._jits:
            return self._jits[key]
        interpret = self.core.fused_mode == "interpret"

        def fused(kv_list, q, table, pos):
            outs = []
            for kv in kv_list:
                k, v = kv["k"], kv["v"]
                if stacked:
                    lead = (k["packed"] if kvwire.is_quant_kv(k)
                            else k).shape[0]
                    outs.extend(paged_attn.paged_attention(
                        q, jax.tree.map(lambda a, i=i: a[i], k),
                        jax.tree.map(lambda a, i=i: a[i], v),
                        table, pos, interpret=interpret)
                        for i in range(lead))
                else:
                    outs.append(paged_attn.paged_attention(
                        q, k, v, table, pos, interpret=interpret))
            return outs

        jit = jax.jit(fused)
        self._jits[key] = jit
        return jit

    def _lm_head_jit(self):
        if self._lm_head is None:
            cfg, policy = self.cfg, self.core.policy

            def lm_head(params, x):
                x = transformer._norm_apply(cfg, params["final_norm"], x)
                return transformer._logits(params, cfg, x, policy)

            self._lm_head = jax.jit(lm_head)
        return self._lm_head

    def _timed(self, fn, *args) -> tuple:
        sw = Stopwatch(self.obs.clock)
        out = fn(*args)
        jax.block_until_ready(out)
        return out, sw.elapsed_ms()

    # ------------------------------------------------------------- probe
    def probe(self, sched) -> dict:
        """Replay the current step's phases against the live pool state;
        record ``serve_phase_ms{phase,layer_run}`` histograms."""
        pool, pcfg = sched.pool, self.core.pcfg
        table = np.zeros((pcfg.max_slots, pcfg.pages_per_slot), np.int32)
        live = np.zeros((pcfg.max_slots,), bool)
        for i, r in enumerate(sched._slots):
            if r is not None:
                table[i] = pool.table_array(r.rid, pcfg.pages_per_slot)
                live[i] = True
        pos = np.where(live, sched._pos, 0).astype(np.int32)
        tokens = np.where(live, sched._last_tok, 0).astype(np.int32)
        jtable = jnp.asarray(table)
        jpos = jnp.asarray(pos)

        m = self.obs.metrics
        out: dict = {}

        def record(phase: str, layer_run: str, ms: float):
            m.histogram("serve_phase_ms", phase=phase,
                        layer_run=layer_run).record(ms)
            out[(phase, layer_run)] = out.get((phase, layer_run), 0.0) + ms

        with self.obs.tracer.span("profile", step=self.steps,
                                  n_slots=int(live.sum())):
            fused_mode = getattr(self.core, "fused_mode", None)
            for label, blocks, stacked in _pool_runs(pool.pages):
                kvs = _run_kv(blocks)
                if not kvs:
                    continue            # recurrent mixer: no paged cache
                if fused_mode is not None:
                    with self.obs.tracer.span("phase:fused_attention",
                                              layer_run=label):
                        _, ms = self._timed(self._fused_jit(label, stacked),
                                            kvs, self._q, jtable, jpos)
                    record("fused_attention", label, ms)
                    continue
                gather, dequant, attend = self._phase_jits(label, kvs,
                                                           stacked)
                with self.obs.tracer.span("phase:gather", layer_run=label):
                    gathered, ms = self._timed(gather, kvs, jtable)
                record("gather", label, ms)
                if dequant is None:
                    dq, ms = gathered, 0.0    # fp wire: no dequant op at all
                else:
                    with self.obs.tracer.span("phase:dequant",
                                              layer_run=label):
                        dq, ms = self._timed(dequant, gathered)
                record("dequant", label, ms)
                with self.obs.tracer.span("phase:attention",
                                          layer_run=label):
                    _, ms = self._timed(attend, dq, self._q, jpos)
                record("attention", label, ms)
            with self.obs.tracer.span("phase:lm_head", layer_run="all"):
                _, ms = self._timed(self._lm_head_jit(), self.core.params,
                                    self._x)
            record("lm_head", "all", ms)
            # full-step replay through the engine's own compiled jit: same
            # shapes as the serving calls, so no new trace is cut
            # (decode_compilations stays 1) and the probe's own key never
            # advances the scheduler's sampling stream
            look = getattr(self.engine, "lookahead_tokens", 1)
            with self.obs.tracer.span("phase:step_replay"):
                if look > 1:      # speculative: the verify step is the step
                    run = np.tile(tokens[:, None], (1, look))
                    _, replay_ms = self._timed(
                        self.core._multi_paged, self.core.params,
                        pool.pages, jnp.asarray(run), jtable, jpos)
                else:
                    _, replay_ms = self._timed(
                        self.core._step_paged, self.core.params, pool.pages,
                        jnp.asarray(tokens), jtable, jpos,
                        jax.random.fold_in(jax.random.key(0), self.steps))
            m.histogram("serve_step_replay_ms").record(replay_ms)
            # the device time the sub-phase replays do not account for
            # (embed, QKV/out/FFN matmuls, scatter, sampling)
            attributed = sum(out.values())
            record("other", "all", max(0.0, replay_ms - attributed))
        m.counter("profile_probes_total").inc()
        out[("step_replay", "all")] = replay_ms
        return {f"{p}/{r}": ms for (p, r), ms in out.items()}


# ---------------------------------------------------------------------------
# roofline-utilization gauges
# ---------------------------------------------------------------------------

def record_utilization(obs, cfg, engine, pool, *, hw=None,
                       labels: dict | None = None) -> dict | None:
    """MFU / HBM-bandwidth-utilization gauges for one serving cell.

    Per-step achieved FLOPs (cost-model MACs x 2 x active slots) and wire
    bytes (every live weight streamed once per step + each slot's cache
    context read back) over the measured ``serve_decode_step_ms`` p50,
    normalized by the roofline constants: gauges ``serve_mfu`` and
    ``serve_hbm_util`` (plus ``labels``, e.g. ``{"tenant": ...}`` in
    fleet mode), both clamped to (0, 1].

    ``hw`` defaults to the stock :class:`repro.roofline.HW`; pass
    ``repro.obs.calibrated_hw(...)`` to measure utilization of the
    *measured* host roof.  Returns the achieved numbers, or ``None``
    before the engine has recorded any decode step.
    """
    from repro.obs.residuals import engine_kv_list, engine_weight_configs
    from repro.plan.costmodel import plan_cost, plan_kv_cost
    from repro.roofline import HW

    labels = labels or {}
    core = getattr(engine, "verifier", engine)    # spec: the verifier's step
    hw = hw or HW()
    hist = obs.metrics.find("serve_decode_step_ms", **core.obs_metric_labels)
    look = 1
    if hist is None or not hist.count:
        # speculative serving records no plain decode-step histogram — the
        # verify step (a length-(k+1) batched forward) is the step there
        hist = obs.metrics.find("serve_verify_ms")
        look = getattr(engine, "lookahead_tokens", 1)
    if hist is None or not hist.count:
        return None
    step_s = hist.percentile(50) / 1e3
    cost = plan_cost(cfg, engine_weight_configs(cfg, core.ecfg))
    kv = plan_kv_cost(cfg, engine_kv_list(cfg, core),
                      kv_group=core._kv_layout[1], tokens=1)
    n_slots = core.pcfg.max_slots
    flops = 2.0 * sum(p["macs"] for p in cost["per_layer"]) * n_slots * look
    bytes_ = (cost["bytes"] + kv["bytes_per_token"]
              * core.pcfg.max_context * n_slots)
    mfu = min(1.0, (flops / step_s) / hw.peak_flops)
    hbm = min(1.0, (bytes_ / step_s) / hw.hbm_bw)
    obs.metrics.gauge("serve_mfu", **labels).set(mfu)
    obs.metrics.gauge("serve_hbm_util", **labels).set(hbm)
    return {"mfu": mfu, "hbm_util": hbm, "flops_per_step": flops,
            "bytes_per_step": bytes_, "step_ms": step_s * 1e3}


def attach_fleet_profilers(router, cfg, *, every_n_steps: int = 8) -> dict:
    """One :class:`PhaseProfiler` per fleet tenant, attached to each
    tenant's scheduler.  Returns ``{tenant_id: profiler}``."""
    out = {}
    for t in router.registry:
        p = PhaseProfiler(t.scheduler.obs, cfg, t.engine,
                          every_n_steps=every_n_steps)
        t.scheduler.profiler = p
        out[t.tenant_id] = p
    return out
