"""Benchmark regression gate: ``python -m repro.obs.regress``.

Compares a fresh benchmark result (``BENCH_serve.json`` or any nested
dict of floats) against the rolling baseline of the append-only history
``benchmarks/history.jsonl`` (see ``benchmarks/history.py``) and exits:

  0  no regression (or no comparable baseline yet — first run passes)
  1  at least one metric regressed past its tolerance band, or the
     inputs were unreadable
  2  usage error

The baseline per metric is the **median over the last ``--window``
comparable entries** (same backend — device kind varies across CI
hosts, backend does not), so one noisy run neither poisons the baseline
nor slips a real regression through.  Tolerance bands are per-metric
and direction-aware, keyed on the metric-name suffix:

  ``*tok_per_s``               higher is better   ratio 1.5 (CI timing
  ``*_ms``                     lower is better    ratio 1.5  is noisy)
  ``*acceptance_rate``         higher is better   ratio 1.05 (numerics-
  ``*verify_steps_per_token``  lower is better    ratio 1.05  stable)
  ``*_attainment``             higher is better   ratio 1.5 (SLO
                                                  compliance fraction)

Unknown suffixes are skipped, not failed: the gate guards the headline
metrics it understands and stays quiet about new ones until a band is
added here.

    python -m repro.obs.regress BENCH_serve.json
    python -m repro.obs.regress BENCH_serve.json --append   # pass, then
                                                            # become history
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys

def _history_module():
    """``benchmarks.history`` lives at the repo root (a namespace
    package outside ``src/``); put the root on the path when the caller
    didn't run from it."""
    try:
        from benchmarks import history
    except ImportError:                                    # pragma: no cover
        import pathlib
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[3]))
        from benchmarks import history
    return history


# (suffix, higher_is_better, tolerated ratio of regression)
_BANDS = (
    ("tok_per_s", True, 1.5),
    ("_ms", False, 1.5),
    ("acceptance_rate", True, 1.05),
    ("verify_steps_per_token", False, 1.05),
    ("_attainment", True, 1.5),
)


def flatten_metrics(tree: dict, prefix: str = "") -> dict:
    """Nested BENCH dict -> flat ``{"serve_throughput.kv8_...": 3.4}``."""
    out: dict = {}
    for k, v in tree.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten_metrics(v, f"{key}."))
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            out[key] = float(v)
    return out


def band_for(name: str):
    """(higher_is_better, ratio) for a metric name, or None (ungated)."""
    for suffix, higher, ratio in _BANDS:
        if name.endswith(suffix):
            return higher, ratio
    return None


def rolling_baseline(history: list[dict], *, backend: str | None = None,
                     window: int = 5) -> dict:
    """Per-metric median over the last ``window`` comparable entries."""
    if backend and backend != "unknown":
        comparable = [e for e in history
                      if e.get("meta", {}).get("backend") in (backend,
                                                              "unknown",
                                                              None)]
    else:
        comparable = list(history)
    values: dict[str, list[float]] = {}
    for entry in comparable[-window:]:
        for name, v in entry.get("metrics", {}).items():
            values.setdefault(name, []).append(float(v))
    return {name: statistics.median(vs) for name, vs in values.items()}


def compare(current: dict, baseline: dict) -> list[dict]:
    """Regressions of ``current`` (flat) vs ``baseline`` (flat).

    A metric regresses when it moved past its tolerance band in the bad
    direction; improvements and in-band noise pass.  Metrics missing
    from either side are skipped (history grows incrementally).
    """
    regressions = []
    for name, cur in sorted(current.items()):
        base = baseline.get(name)
        spec = band_for(name)
        if base is None or spec is None:
            continue
        higher, ratio = spec
        if base <= 0 or cur <= 0:
            continue                      # degenerate: nothing to gate on
        worse = (base / cur) if higher else (cur / base)
        if worse > ratio:
            regressions.append({
                "metric": name, "current": cur, "baseline": base,
                "ratio": worse, "tolerance": ratio,
                "direction": "higher_is_better" if higher
                             else "lower_is_better",
            })
    return regressions


def check(current_path: str, history_path=None, *, window: int = 5,
          append: bool = False) -> int:
    """The CLI body; returns the process exit code (0 ok / 1 fail)."""
    hist = _history_module()

    try:
        with open(current_path) as f:
            current = flatten_metrics(json.load(f))
    except (OSError, json.JSONDecodeError, AttributeError) as e:
        print(f"regress: cannot read {current_path}: {e}")
        return 1
    entries = hist.load_history(history_path)
    meta = hist.run_metadata()
    baseline = rolling_baseline(entries, backend=meta.get("backend"),
                                window=window)
    gated = [n for n in current if band_for(n) and n in baseline]
    if not gated:
        print(f"regress: no comparable baseline in "
              f"{history_path or hist.HISTORY_PATH} — passing "
              f"({len(current)} metrics, {len(entries)} history entries)")
        if append:
            hist.append_entry(current, history_path, meta=meta)
        return 0
    regressions = compare(current, baseline)
    print(f"regress: {len(gated)} gated metrics vs median of last "
          f"{window} runs (backend={meta.get('backend')})")
    for r in regressions:
        print(f"  REGRESSION {r['metric']}: {r['current']:.4g} vs "
              f"baseline {r['baseline']:.4g} "
              f"({r['ratio']:.2f}x worse, tolerance {r['tolerance']}x, "
              f"{r['direction']})")
    if regressions:
        return 1
    print("regress: OK — no metric outside its tolerance band")
    if append:
        hist.append_entry(current, history_path, meta=meta)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="benchmark regression gate over benchmarks/"
                    "history.jsonl")
    ap.add_argument("current", help="fresh benchmark JSON "
                                    "(e.g. BENCH_serve.json)")
    ap.add_argument("--history", default=None,
                    help="history JSONL path (default: "
                         "benchmarks/history.jsonl)")
    ap.add_argument("--window", type=int, default=5,
                    help="rolling-baseline window (default 5)")
    ap.add_argument("--append", action="store_true",
                    help="append the current run to the history when it "
                         "passes")
    args = ap.parse_args(argv)
    return check(args.current, args.history, window=args.window,
                 append=args.append)


if __name__ == "__main__":
    sys.exit(main())
