"""Live metrics endpoint: stdlib HTTP server over an Observability.

:class:`MetricsServer` runs a ``ThreadingHTTPServer`` on a daemon thread
and serves three read-only routes straight from the live registry:

* ``GET /metrics``        — Prometheus text format (``to_prometheus()``),
  ``Content-Type: text/plain; version=0.0.4``;
* ``GET /healthz``        — ``ok`` (liveness probe);
* ``GET /snapshot.json``  — the full counters/gauges/histograms snapshot
  as JSON (includes percentiles — richer than the Prometheus view);
* ``GET /slo.json``       — the attached :class:`repro.obs.slo.SLOTracker`
  report (per-tenant budgets, burn rates, breach episodes); 404 until an
  ``attach_slo`` call wires a tracker.

Handlers only *read* registry state (plain Python dicts mutated by the
single serving thread between requests); nothing here touches the engine
or its compiled functions.  Pass ``port=0`` to bind an ephemeral port —
``server.port`` reports the real one.  Wired by ``repro.launch.serve
--serve-metrics PORT``.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer


class _Handler(BaseHTTPRequestHandler):
    # the Observability to serve; set by MetricsServer on the handler class
    obs = None
    slo = None      # optional SLOTracker behind /slo.json

    def _send(self, code: int, body: bytes, ctype: str):
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):  # noqa: N802 (http.server API)
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            body = self.obs.metrics.to_prometheus().encode()
            self._send(200, body, "text/plain; version=0.0.4")
        elif path == "/healthz":
            self._send(200, b"ok\n", "text/plain")
        elif path == "/snapshot.json":
            body = json.dumps(self.obs.metrics.snapshot()).encode()
            self._send(200, body, "application/json")
        elif path == "/slo.json":
            if self.slo is None:
                self._send(404, b"no slo tracker attached\n", "text/plain")
            else:
                body = json.dumps(self.slo.report()).encode()
                self._send(200, body, "application/json")
        else:
            self._send(404, b"not found\n", "text/plain")

    def log_message(self, fmt, *args):
        pass                       # keep scrape noise out of serve stdout


class MetricsServer:
    """Serve ``/metrics``, ``/healthz``, ``/snapshot.json`` for ``obs``."""

    def __init__(self, obs, *, port: int = 0, host: str = "127.0.0.1",
                 slo=None):
        handler = type("BoundHandler", (_Handler,), {"obs": obs,
                                                     "slo": slo})
        self._handler = handler
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]   # real port when port=0
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-metrics",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def attach_slo(self, tracker):
        """Expose ``tracker.report()`` at ``/slo.json`` (``None``
        detaches — the route 404s again).  Returns the tracker."""
        self._handler.slo = tracker
        return tracker

    def close(self):
        self._httpd.shutdown()
        self._thread.join(timeout=5.0)
        self._httpd.server_close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
