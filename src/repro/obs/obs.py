"""The Observability bundle: one switch, one clock, tracer + metrics.

Every serving-layer component (``Scheduler``, ``Server``, ``PagedEngine``,
``SpeculativeEngine``, ``PagedKVPool``, ``FleetRouter``,
``FleetTelemetry``) accepts an optional :class:`Observability`; the
default is the module-level :data:`NOOP` singleton, whose tracer and
metrics discard everything — instrumented hot paths pay one
``obs.enabled`` attribute check when observability is off, and never
touch the clock.

When enabled, all timing flows from the single injectable ``clock``
(seconds; default ``time.perf_counter``), shared by the tracer's span
timestamps and the metric histograms, so traces and metrics line up and
tests can drive both deterministically.
"""
from __future__ import annotations

from repro.obs.metrics import (DEFAULT_CLOCK, NOOP_METRICS, MetricsRegistry)
from repro.obs.trace import NOOP_TRACER, Tracer


class Observability:
    """Tracer + metrics registry behind one enable switch."""

    def __init__(self, *, clock=DEFAULT_CLOCK, enabled: bool = True):
        self.enabled = enabled
        self.clock = clock
        self.tracer = Tracer(clock) if enabled else NOOP_TRACER
        self.metrics = MetricsRegistry() if enabled else NOOP_METRICS
        self.flight = None        # FlightRecorder once attach_flight() ran

    def attach_flight(self, recorder):
        """Feed every finished span/event into ``recorder`` (an
        :class:`repro.obs.flight.FlightRecorder`) so anomaly triggers can
        dump the recent timeline + a metrics snapshot.  No-op when
        disabled; returns the recorder either way."""
        self.flight = recorder
        if self.enabled:
            recorder.bind(self)
            self.tracer.listener = recorder.on_record
        return recorder

    # thin sugar so call sites read ``obs.span(...)`` / ``obs.event(...)``
    def span(self, name: str, *, tid: int = 0, **args):
        return self.tracer.span(name, tid=tid, **args)

    def event(self, name: str, *, tid: int = 0, **args):
        self.tracer.event(name, tid=tid, **args)

    def now(self) -> float:
        return self.clock()

    def save_trace(self, path: str):
        self.tracer.save(path)

    def save_metrics(self, path: str):
        self.metrics.save(path)


NOOP = Observability(enabled=False)
