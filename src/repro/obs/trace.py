"""Span-based request-lifecycle tracing with Chrome ``trace_event`` export.

A :class:`Tracer` records three kinds of events against an injectable
clock:

* **spans** — ``with tracer.span("decode", n_slots=3): ...`` records a
  Chrome *complete* event (``ph: "X"``) whose ``ts``/``dur`` bound the
  body.  Spans nest per thread lane (``tid``); nesting depth is tracked
  explicitly so span trees reconstruct deterministically even under a
  frozen fake clock (where ts/dur containment is ambiguous).
* **retro spans** — ``tracer.complete(name, t0, dur)`` records a span
  whose bounds the caller timed itself (e.g. a request's submit ->
  complete lifetime, only known at completion).
* **instant events** — ``tracer.event("preempt", rid=3)`` records a
  Chrome *instant* event (``ph: "i"``).

``to_chrome()`` renders the whole timeline as a ``chrome://tracing`` /
Perfetto-loadable JSON object; ``save(path)`` writes it.

The serving convention for lanes: ``tid 0`` is the engine lane (prefill /
decode / draft / verify spans, serialized host-side), and every request
gets its own lane from :meth:`Tracer.new_tid` carrying its lifecycle
spans (``queued``, ``request``) and events (``first_token``, ``preempt``,
``rewind``).

:class:`NoopTracer` is the disabled counterpart: every method is a
constant-time no-op and ``span()`` returns a shared null context
manager, so instrumented hot paths pay one attribute lookup when
tracing is off.
"""
from __future__ import annotations

import json
import time

PID = 0   # one serving cell == one trace process


class _NullContext:
    """Reusable do-nothing context manager (the disabled span)."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


NULL_CONTEXT = _NullContext()


class NoopTracer:
    """Tracing disabled: records nothing, costs (almost) nothing."""
    enabled = False
    events: tuple = ()

    def span(self, name, *, tid=0, **args):
        return NULL_CONTEXT

    def complete(self, name, start, duration, *, tid=0, **args):
        pass

    def event(self, name, *, tid=0, **args):
        pass

    def new_tid(self, name=None) -> int:
        return 0

    def name_thread(self, tid, name):
        pass


NOOP_TRACER = NoopTracer()


class _Span:
    """Context manager backing :meth:`Tracer.span`; fills ``dur`` on exit."""
    __slots__ = ("_tracer", "_ev")

    def __init__(self, tracer, ev):
        self._tracer, self._ev = tracer, ev

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        ev = self._ev
        tr = self._tracer
        ev["dur"] = tr._ts_now() - ev["ts"]
        tr._depth[ev["tid"]] -= 1
        if tr.listener is not None:
            tr.listener(ev)
        return False


class Tracer:
    """Event recorder.  Timestamps are microseconds relative to the
    tracer's construction instant (Chrome's ``ts`` unit), taken from the
    injectable ``clock`` (seconds, default ``time.perf_counter``)."""
    enabled = True

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._epoch = clock()
        self.events: list[dict] = []     # in span-ENTER order
        self._depth: dict[int, int] = {}
        self._threads: dict[int, str] = {}
        self._next_tid = 0
        # optional tap: called with each finished event dict (span on exit,
        # retro span, instant event) — the flight recorder's feed
        # (obs/flight.py).  None costs one attribute check per record.
        self.listener = None

    # ------------------------------------------------------------- clock
    def _ts_now(self) -> float:
        return (self._clock() - self._epoch) * 1e6

    def _ts_of(self, t: float) -> float:
        """Absolute clock reading (seconds) -> trace microseconds."""
        return (t - self._epoch) * 1e6

    # ------------------------------------------------------------- lanes
    def new_tid(self, name: str | None = None) -> int:
        """Allocate a fresh thread lane (e.g. one per request)."""
        self._next_tid += 1
        if name is not None:
            self._threads[self._next_tid] = name
        return self._next_tid

    def name_thread(self, tid: int, name: str):
        self._threads[tid] = name

    # ------------------------------------------------------------ record
    def span(self, name: str, *, tid: int = 0, **args):
        d = self._depth.get(tid, 0)
        ev = {"name": name, "ph": "X", "ts": self._ts_now(), "dur": 0.0,
              "pid": PID, "tid": tid, "depth": d}
        if args:
            ev["args"] = args
        self._depth[tid] = d + 1
        self.events.append(ev)
        return _Span(self, ev)

    def complete(self, name: str, start: float, duration: float, *,
                 tid: int = 0, **args):
        """Record a caller-timed span: ``start`` is an absolute clock
        reading (seconds), ``duration`` is seconds."""
        ev = {"name": name, "ph": "X", "ts": self._ts_of(start),
              "dur": duration * 1e6, "pid": PID, "tid": tid,
              "depth": self._depth.get(tid, 0)}
        if args:
            ev["args"] = args
        self.events.append(ev)
        if self.listener is not None:
            self.listener(ev)

    def event(self, name: str, *, tid: int = 0, **args):
        ev = {"name": name, "ph": "i", "ts": self._ts_now(), "pid": PID,
              "tid": tid, "s": "t", "depth": self._depth.get(tid, 0)}
        if args:
            ev["args"] = args
        self.events.append(ev)
        if self.listener is not None:
            self.listener(ev)

    # ----------------------------------------------------------- inspect
    def span_tree(self, tid: int = 0) -> list[dict]:
        """The lane's spans as a nested forest (children inside parents),
        reconstructed from recorded depths — deterministic under any
        clock.  Each node: ``{name, ts, dur, args, children}``."""
        roots: list[dict] = []
        stack: list[dict] = []
        for ev in self.events:
            if ev["tid"] != tid or ev["ph"] != "X":
                continue
            node = {"name": ev["name"], "ts": ev["ts"], "dur": ev["dur"],
                    "args": ev.get("args", {}), "children": []}
            del stack[ev["depth"]:]
            (stack[-1]["children"] if stack else roots).append(node)
            stack.append(node)
        return roots

    # ------------------------------------------------------------ export
    def to_chrome(self) -> dict:
        """The Chrome/Perfetto ``trace_event`` JSON object."""
        meta = [{"name": "process_name", "ph": "M", "pid": PID, "tid": 0,
                 "args": {"name": "repro.serve"}}]
        for tid, name in sorted(self._threads.items()):
            meta.append({"name": "thread_name", "ph": "M", "pid": PID,
                         "tid": tid, "args": {"name": name}})
        evs = []
        for ev in self.events:
            out = {k: v for k, v in ev.items() if k != "depth"}
            evs.append(out)
        return {"traceEvents": meta + evs, "displayTimeUnit": "ms"}

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_chrome(), indent=indent)

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())
