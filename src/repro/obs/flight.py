"""Flight recorder: a bounded ring of recent spans/events, dumped on
anomaly.

A :class:`FlightRecorder` taps the tracer's ``listener`` hook (wired by
``Observability.attach_flight``) and keeps the last ``capacity`` finished
events in a ring.  Three anomaly triggers watch the stream:

* **preempt storm** — ``storm_n`` or more ``preempt`` events inside a
  ``storm_window_s`` sliding window (the thrash signature of an
  under-provisioned pool);
* **pool alloc failure** — any ``alloc_fail`` event (the pool turned a
  request away; ``serve/pool.py`` emits it on exhaustion);
* **drift alarm** — any ``drift_alarm`` event (the spec-acceptance drift
  detector in ``obs/numerics.py`` fired);
* **SLO breach** — any ``slo_breach`` event (a tenant objective's burn
  rate crossed the breach threshold on both windows; ``obs/slo.py``
  fires it once per episode).

Each trigger snapshots the ring plus the live metrics registry into an
in-memory dump (and a JSON file next to ``out`` when set), rate-limited
by a per-reason ``cooldown_s`` and a global ``max_dumps`` cap so a storm
cannot flood the disk.  ``save(path)`` writes the final ring + every dump
— the ``--flight-out`` artifact of ``repro.launch.serve``.

All of this is host-side bookkeeping on already-recorded events: it never
touches the engine's compiled functions.
"""
from __future__ import annotations

import json
from collections import deque

from repro.obs.metrics import DEFAULT_CLOCK

TRIGGER_EVENTS = ("alloc_fail", "drift_alarm", "slo_breach")
#                                                ^ fire on first sight
STORM_EVENT = "preempt"


class FlightRecorder:
    """Ring buffer over the obs event stream + anomaly-triggered dumps."""

    def __init__(self, capacity: int = 256, *, storm_n: int = 5,
                 storm_window_s: float = 1.0, cooldown_s: float = 5.0,
                 max_dumps: int = 8, out: str | None = None,
                 clock=DEFAULT_CLOCK):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.ring: deque = deque(maxlen=capacity)
        self.dumps: list[dict] = []
        self.storm_n = storm_n
        self.storm_window_s = storm_window_s
        self.cooldown_s = cooldown_s
        self.max_dumps = max_dumps
        self.out = out
        self._clock = clock
        self._obs = None
        self._preempts: deque = deque()      # recent preempt clock readings
        self._last_dump: dict[str, float] = {}   # reason -> clock reading
        self.dropped_dumps = 0               # triggers suppressed by limits

    def bind(self, obs):
        """Adopt the Observability whose stream feeds this recorder (its
        clock times the trigger windows, its metrics enter the dumps)."""
        self._obs = obs
        self._clock = obs.clock

    # ------------------------------------------------------------- record
    def on_record(self, ev: dict):
        """Tracer listener: called with every finished event dict."""
        self.ring.append(dict(ev))           # the tracer mutates its dicts
        name = ev.get("name")
        if name == STORM_EVENT:
            now = self._clock()
            self._preempts.append(now)
            while self._preempts and \
                    now - self._preempts[0] > self.storm_window_s:
                self._preempts.popleft()
            if len(self._preempts) >= self.storm_n:
                self.trigger("preempt_storm",
                             preempts=len(self._preempts),
                             window_s=self.storm_window_s)
        elif name in TRIGGER_EVENTS:
            self.trigger(name, **ev.get("args", {}))

    # ------------------------------------------------------------ trigger
    def trigger(self, reason: str, **info) -> bool:
        """Snapshot the ring + metrics under ``reason``.  Returns whether
        a dump was actually taken (cooldown / max_dumps may suppress)."""
        now = self._clock()
        last = self._last_dump.get(reason)
        if len(self.dumps) >= self.max_dumps or \
                (last is not None and now - last < self.cooldown_s):
            self.dropped_dumps += 1
            return False
        self._last_dump[reason] = now
        metrics = (self._obs.metrics.snapshot()
                   if self._obs is not None else {})
        dump = {"reason": reason, "info": info, "clock": now,
                "events": list(self.ring), "metrics": metrics}
        self.dumps.append(dump)
        if self.out:
            path = f"{self.out}.{len(self.dumps)}.{reason}.json"
            with open(path, "w") as f:
                json.dump(dump, f, indent=1)
        return True

    # ------------------------------------------------------------- export
    def snapshot(self) -> dict:
        return {"ring": list(self.ring), "dumps": self.dumps,
                "dropped_dumps": self.dropped_dumps}

    def save(self, path: str):
        """Write the final ring + every anomaly dump (``--flight-out``)."""
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
