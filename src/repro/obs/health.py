"""Silent-degradation detection: per-tenant composite health gauges.

The serving stack has failure modes that degrade quality or latency
without tripping any existing alarm:

* **fused-attention fallback** — ``EngineConfig.fused_attention`` was
  requested but Pallas is unavailable, so the engine silently serves the
  XLA gather+dequant path (``kernels/paged_attention.resolve_mode``
  returning ``None``).  The engine reports it via ``fused_fallback`` /
  ``attention_mode`` (serve/engine.py); this monitor folds it into the
  health gauge so a fleet cannot *believe* it is running fused.
* **shadow-KL blowup** — the quality plane's ``quality_shadow_kl``
  histogram (obs/numerics.py) spiking past ``kl_max``: the quantized
  model has diverged from its fp shadow even though tokens keep flowing.
* **pool pressure** — occupancy trending up with less than
  ``headroom_requests`` worth of free pages (measured in full-request
  page demands, ``pages_per_slot``).  This fires a ``pool_pressure``
  event *before* the allocator's ``alloc_fail`` does, one per pressure
  episode, so operators get an early warning instead of a post-mortem.
* **SLO state** — when an :class:`repro.obs.slo.SLOTracker` is wired,
  its worst per-tenant objective state (warning/breach) caps health.

Exported metrics, refreshed by :meth:`HealthMonitor.on_step`:

* ``health{tenant}``                       — composite in [0, 1]
  (min over components: 1.0 healthy, 0.75 degraded-warning, 0.5
  degraded, 0.25 breaching)
* ``health_component{tenant,component}``   — per-component value
* ``pool_alloc_headroom{tenant}``          — free pages / pages one
  full-length request needs (admissions of headroom left)
* ``pool_occupancy_trend{tenant}``         — EWMA occupancy slope
* ``pool_pressure_total{tenant}``          — pressure episodes counter

Host-side reads over the pool's allocator state and already-recorded
metrics only: nothing enters a compiled function.
"""
from __future__ import annotations

COMPONENTS = ("fused", "quality", "pool", "slo")
_SLO_HEALTH = {"ok": 1.0, "warning": 0.75, "breach": 0.25}


def _pages_per_request(engine) -> int:
    """Worst-case page demand of one request: ``pages_per_slot`` of the
    engine's paged geometry (a speculative engine's verifier owns it)."""
    pcfg = getattr(engine, "pcfg", None)
    if pcfg is None:
        pcfg = getattr(getattr(engine, "verifier", None), "pcfg", None)
    return pcfg.pages_per_slot if pcfg is not None else 1


class HealthMonitor:
    """Composite per-tenant health over engines/pools + obs metrics.

    Register each tenant's engine/pool (``attach_fleet_health`` does it
    for a router; single-cell serves register ``"default"``), then call
    :meth:`on_step` once per decode step alongside the SLO tracker.
    """

    def __init__(self, obs, *, slo=None, kl_max: float = 1.0,
                 pressure_occupancy: float = 0.85,
                 headroom_requests: float = 1.0,
                 trend_alpha: float = 0.3):
        if not 0.0 < trend_alpha <= 1.0:
            raise ValueError(f"trend_alpha must be in (0, 1], "
                             f"got {trend_alpha}")
        self.obs = obs
        self.slo = slo                      # optional SLOTracker
        self.kl_max = kl_max
        self.pressure_occupancy = pressure_occupancy
        self.headroom_requests = headroom_requests
        self.trend_alpha = trend_alpha
        self._tenants: dict[str, dict] = {}

    def register(self, tenant_id: str, *, engine=None, pool=None):
        """Track a tenant's serving stack (either handle optional)."""
        self._tenants[tenant_id] = {"engine": engine, "pool": pool,
                                    "occ_ewma": None, "trend": 0.0,
                                    "pressure": False, "health": 1.0,
                                    "components": {}}

    # ------------------------------------------------------- components
    def _fused_component(self, st) -> float:
        engine = st["engine"]
        if engine is None or not getattr(engine, "fused_fallback", False):
            return 1.0
        return 0.5      # serving, but NOT on the path the config asked for

    def _quality_component(self, tid: str) -> float:
        h = (self.obs.metrics.find("quality_shadow_kl", tenant=tid)
             or self.obs.metrics.find("quality_shadow_kl"))
        if h is None or not getattr(h, "count", 0):
            return 1.0
        return 0.5 if h.percentile(95) > self.kl_max else 1.0

    def _pool_component(self, tid: str, st) -> float:
        pool = st["pool"]
        if pool is None:
            return 1.0
        occ = pool.occupancy()
        headroom = pool.n_free / max(_pages_per_request(st["engine"]), 1)
        prev = st["occ_ewma"]
        ewma = (occ if prev is None
                else self.trend_alpha * occ
                + (1.0 - self.trend_alpha) * prev)
        st["occ_ewma"] = ewma
        st["trend"] = 0.0 if prev is None else ewma - prev
        m = self.obs.metrics
        m.gauge("pool_alloc_headroom", tenant=tid).set(headroom)
        m.gauge("pool_occupancy_trend", tenant=tid).set(st["trend"])
        pressure = (ewma >= self.pressure_occupancy
                    and st["trend"] >= 0.0
                    and headroom < self.headroom_requests)
        if pressure and not st["pressure"]:     # one event per episode
            self.obs.event("pool_pressure", tenant=tid,
                           occupancy=round(occ, 4),
                           headroom=round(headroom, 4))
            m.counter("pool_pressure_total", tenant=tid).inc()
        st["pressure"] = pressure
        return 0.5 if pressure else 1.0

    def _slo_component(self, tid: str) -> float:
        if self.slo is None:
            return 1.0
        return _SLO_HEALTH[self.slo.worst_state(tid)]

    # -------------------------------------------------------------- step
    def on_step(self):
        """Refresh every tenant's component + composite health gauges."""
        if not getattr(self.obs, "enabled", False):
            return
        m = self.obs.metrics
        for tid, st in self._tenants.items():
            comps = {"fused": self._fused_component(st),
                     "quality": self._quality_component(tid),
                     "pool": self._pool_component(tid, st),
                     "slo": self._slo_component(tid)}
            for name, v in comps.items():
                m.gauge("health_component", tenant=tid,
                        component=name).set(v)
            h = min(comps.values())
            st["health"] = h
            st["components"] = comps
            m.gauge("health", tenant=tid).set(h)

    # ------------------------------------------------------------ export
    def tenant_summary(self, tenant_id: str) -> float | None:
        st = self._tenants.get(tenant_id)
        return None if st is None else st["health"]

    def snapshot(self) -> dict:
        out = {}
        for tid, st in sorted(self._tenants.items()):
            row = {"health": st["health"],
                   "components": dict(st["components"]),
                   "pool_pressure": st["pressure"]}
            engine = st["engine"]
            if engine is not None:
                mode = getattr(engine, "attention_mode", None)
                if mode is not None:
                    row["attention_mode"] = mode
            out[tid] = row
        return {"tenants": out}


def attach_fleet_health(router, *, slo=None, **kwargs) -> HealthMonitor:
    """One :class:`HealthMonitor` over every tenant of a
    :class:`repro.fleet.FleetRouter`; also threads it into the router's
    telemetry so ``snapshot()`` carries per-tenant health."""
    monitor = HealthMonitor(router.obs, slo=slo, **kwargs)
    for t in router.registry:
        monitor.register(t.tenant_id, engine=t.engine, pool=t.pool)
    router.telemetry.health = monitor
    return monitor
