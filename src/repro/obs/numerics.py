"""Online numerics probes: quantization error + divergence, measured live.

The paper's headline accuracy claim — low-bit local-quantization regions
retain model quality — is verified here *while traffic is flowing*, not
just in offline evals.  Four probes, all host-side (none ever enters the
engine's compiled decode step, so ``decode_compilations`` stays 1 and
token streams are bit-identical with probes on):

* **weight wire-error** (:func:`record_weight_wire_error`) — at quantize
  time, per decoder layer: MSE / max-abs of ``dequant(quant(w)) - w``
  over exactly the leaves ``transformer.quantize_params`` packs, under
  the layer's planned scheme.  Gauges ``quant_weight_{mse,maxabs}{layer=}``.
* **shadow divergence** (:class:`QualityMonitor`) — every
  ``every_n_steps`` decode steps, one sampled slot's context is replayed
  through (a) the fp reference and (b) the engine's quantized
  weights+policy in two standalone jits; the probe records the logit
  KL(fp‖quant) histogram ``quality_shadow_kl`` and whether the fp
  model's top-1 token agrees with the token the quantized *serving* path
  actually emitted (gauge ``quality_shadow_top1_agree``).
* **KV dequant error** — the same probe gathers the slot's pool pages
  per layer, dequantizes them at that layer's wire format, and compares
  against the fp replay's cache: the *accumulated* cache wire error a
  decode step actually reads (gauges ``kv_dequant_{mse,maxabs}{layer=}``
  — the measurement half of the ROADMAP's decode-time KV sensitivity).
* **spec-acceptance drift** (:class:`AcceptanceDrift`) — EWMA of the
  speculative acceptance rate vs a calibration baseline; crossing the
  threshold emits a ``drift_alarm`` event (a flight-recorder trigger)
  and bumps ``spec_drift_alarms_total``.

Probe cost is bounded by the sampling knobs on :class:`NumericsConfig`:
each shadow probe is two extra prefill-sized forwards (compiled once —
the replay jits are separate functions and never touch the engine's),
and the KV comparison is O(context · layers) host flops.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kvwire, schemes
from repro.kernels import ops as kops
from repro.models import transformer
from repro.models.layers import NO_QUANT

# KL of a shadow replay is tiny when quantization is faithful — the
# serving-latency bucket ladder would dump everything into the first
# bucket.  1-2-5 ladder over 1e-9 .. 500 nats instead.
KL_BUCKETS = tuple(c * 10.0 ** e for e in range(-9, 3) for c in (1, 2, 5))


@dataclasses.dataclass(frozen=True)
class NumericsConfig:
    """Sampling knobs of the online quality probes."""
    every_n_steps: int = 8          # shadow-replay every N decode steps
    kv_probe: bool = True           # per-layer KV dequant error per probe
    drift_alpha: float = 0.2        # acceptance EWMA smoothing
    drift_threshold: float = 0.15   # |ewma - baseline| alarm threshold
    drift_min_cycles: int = 8       # cycles before baseline/alarms engage
    drift_baseline: float | None = None   # None = auto-calibrate


# ---------------------------------------------------------------------------
# layer walkers (shared by the KV probe and the weight wire-error pass)
# ---------------------------------------------------------------------------

def layer_blocks(tree, cfg):
    """Yield ``(layer_idx, block)`` over a cache/pool/params decoder tree.

    Handles the homogeneous ``"super"`` layout (per-position trees whose
    leaves stack ``n_super`` first) and the heterogeneous
    ``"super_segments"`` layout (one such tuple per run of superblocks);
    blocks come out with the stack dim sliced away, in layer order
    ``superblock * p_len + position`` then the tail.
    """
    p_len = len(cfg.pattern)
    if "super_segments" in tree:
        start = 0
        for seg in tree["super_segments"]:
            size = jax.tree.leaves(seg[0])[0].shape[0]
            for s in range(size):
                for j, block in enumerate(seg):
                    yield ((start + s) * p_len + j,
                           jax.tree.map(lambda a, s=s: a[s], block))
            start += size
    else:
        for s in range(cfg.n_super):
            for j, block in enumerate(tree["super"]):
                yield (s * p_len + j,
                       jax.tree.map(lambda a, s=s: a[s], block))
    for t, block in enumerate(tree["tail"]):
        yield (cfg.n_super * p_len + t, block)


def _layer_label(i: int) -> str:
    return f"layer{i}"


# ---------------------------------------------------------------------------
# weight wire-error (recorded at quantize time)
# ---------------------------------------------------------------------------

def _wire_error_tree(block, qcfg) -> dict:
    """MSE / max-abs of the wire round-trip over exactly the leaves
    ``transformer._quantize_tree`` would pack under ``qcfg``."""
    if qcfg.w_bits is None:
        return {"mse": 0.0, "maxabs": 0.0, "n_weights": 0}
    bits, gs = qcfg.w_bits, qcfg.group_size
    sq, n, mx = 0.0, 0, 0.0

    def roundtrip(w):
        nonlocal sq, n, mx
        flat = np.asarray(w, np.float32).reshape((-1,) + w.shape[-2:])
        for w2 in flat:                       # MoE expert stacks: per expert
            qw = kops.quantize_weight(jnp.asarray(w2), bits, gs)
            err = (np.asarray(kops.dequantize_weight(qw), np.float64)
                   - w2.astype(np.float64))
            sq += float(np.sum(err * err))
            n += err.size
            mx = max(mx, float(np.max(np.abs(err))))

    def visit(t):
        if isinstance(t, dict):
            for k, v in t.items():
                if k in transformer._EXCLUDE_KEYS:
                    continue
                if k == "w" and hasattr(v, "ndim") and v.ndim >= 2 \
                        and v.shape[-2] % gs == 0:
                    roundtrip(v)
                elif k in ("wi_gate", "wi_up", "wo") \
                        and hasattr(v, "ndim") and not isinstance(v, dict) \
                        and v.ndim >= 3 and v.shape[-2] % gs == 0:
                    roundtrip(v)
                else:
                    visit(v)
        elif isinstance(t, (list, tuple)):
            for v in t:
                visit(v)

    visit(block)
    return {"mse": sq / n if n else 0.0, "maxabs": mx, "n_weights": n}


def record_weight_wire_error(obs, cfg, fp_params, qcfg_or_plan) -> dict:
    """Per-layer wire error of quantizing ``fp_params`` under a scheme
    name / :class:`~repro.core.schemes.QuantConfig` / QuantPlan.

    Records gauges ``quant_weight_mse{layer=...}`` and
    ``quant_weight_maxabs{layer=...}``; returns ``{layer_label: stats}``.
    Runs on the fp checkpoint, so call it where the engine quantizes —
    it is pure measurement and leaves ``fp_params`` untouched.
    """
    if hasattr(qcfg_or_plan, "resolve"):              # QuantPlan
        configs = qcfg_or_plan.resolve(cfg)
    else:
        qcfg = (schemes.get(qcfg_or_plan)
                if not isinstance(qcfg_or_plan, schemes.QuantConfig)
                else qcfg_or_plan)
        configs = (qcfg,) * cfg.n_layers
    out = {}
    for i, block in layer_blocks(fp_params["decoder"], cfg):
        stats = _wire_error_tree(block, configs[i])
        label = _layer_label(i)
        out[label] = stats
        if obs is not None and obs.enabled:
            obs.metrics.gauge("quant_weight_mse", layer=label).set(
                stats["mse"])
            obs.metrics.gauge("quant_weight_maxabs", layer=label).set(
                stats["maxabs"])
    return out


# ---------------------------------------------------------------------------
# spec-acceptance drift
# ---------------------------------------------------------------------------

class AcceptanceDrift:
    """EWMA drift detector over the speculative acceptance rate.

    Feed per-cycle acceptance rates via :meth:`update`; after
    ``min_cycles`` the baseline locks (to the given calibration value, or
    auto-calibrates to the first settled EWMA) and an excursion of more
    than ``threshold`` from it fires — once per breach episode (the alarm
    latches until the EWMA recovers, so a sustained regression does not
    spam one alarm per step).
    """

    def __init__(self, *, alpha: float = 0.2, threshold: float = 0.15,
                 min_cycles: int = 8, baseline: float | None = None):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha, self.threshold = alpha, threshold
        self.min_cycles, self.baseline = min_cycles, baseline
        self.ewma: float | None = None
        self.cycles = 0
        self.alarmed = False          # currently in a breach episode

    def update(self, rate: float) -> bool:
        """Observe one cycle's acceptance rate; True == alarm fires now."""
        rate = float(rate)
        self.cycles += 1
        self.ewma = (rate if self.ewma is None else
                     self.alpha * rate + (1.0 - self.alpha) * self.ewma)
        if self.cycles < self.min_cycles:
            return False
        if self.baseline is None:
            self.baseline = self.ewma     # calibration window just closed
            return False
        breach = abs(self.ewma - self.baseline) > self.threshold
        fired = breach and not self.alarmed
        self.alarmed = breach
        return fired


# ---------------------------------------------------------------------------
# shadow-divergence + KV dequant monitor
# ---------------------------------------------------------------------------

def _log_softmax(x: np.ndarray) -> np.ndarray:
    x = x - x.max()
    return x - np.log(np.exp(x).sum())


class QualityMonitor:
    """Sampled online divergence probes over one scheduler's traffic.

    Attach via ``Server.attach_quality`` (or ``scheduler.quality = m``);
    the scheduler calls :meth:`on_step` after each decode step.  Works
    with plain and speculative engines — a :class:`SpeculativeEngine`'s
    replays run through its verifier, and its ``drafted``/``accepted``
    counters feed the drift detector.
    """

    def __init__(self, obs, cfg, fp_params, engine, *,
                 ncfg: NumericsConfig | None = None):
        self.obs = obs
        self.cfg = cfg
        self.fp_params = fp_params
        self.engine = engine                      # drift counters live here
        # the paged engine whose params/policy/kv-layout the replays mirror
        self.core = getattr(engine, "verifier", engine)
        self.ncfg = ncfg or NumericsConfig()
        self.steps = 0
        self._probe_cursor = 0
        self._last_drafted = 0
        self._last_accepted = 0
        self.drift = AcceptanceDrift(
            alpha=self.ncfg.drift_alpha, threshold=self.ncfg.drift_threshold,
            min_cycles=self.ncfg.drift_min_cycles,
            baseline=self.ncfg.drift_baseline)

        cfg_, core = cfg, self.core
        bucket = core.pcfg.max_context
        kvq = core._kv_quant()

        # standalone replay jits: compiled once each (fixed bucket shape,
        # traced logits_pos), never shared with the engine's functions —
        # enabling probes cannot retrace the serving path.
        def fp_replay(params, tokens, logits_pos):
            cache = transformer.init_cache(cfg_, 1, bucket, kv_quant=None)
            return transformer.prefill(params, cfg_, {"tokens": tokens},
                                       cache, policy=NO_QUANT,
                                       logits_pos=logits_pos)

        def q_replay(params, tokens, logits_pos):
            # mirrors PagedEngine._prefill_paged_impl: same params, same
            # policy, same cache wire layout as the serving engine
            cache = transformer.init_cache(cfg_, 1, bucket, kv_quant=kvq)
            logits, _ = transformer.prefill(params, cfg_, {"tokens": tokens},
                                            cache, policy=core.policy,
                                            logits_pos=logits_pos)
            return logits

        self._fp_replay = jax.jit(fp_replay)
        self._q_replay = jax.jit(q_replay)

    # -------------------------------------------------------------- hook
    def on_step(self, sched):
        """Scheduler tap: runs after each decode step (host-side only)."""
        self.steps += 1
        self._check_drift()
        every = self.ncfg.every_n_steps
        if every <= 0 or self.steps % every:
            return None
        slot_req = self._pick_slot(sched)
        if slot_req is None:
            return None
        return self.probe(sched, *slot_req)

    def _pick_slot(self, sched):
        """Round-robin over slots that have emitted at least one token."""
        live = [(i, r) for i, r in enumerate(sched._slots)
                if r is not None and r.generated]
        if not live:
            return None
        self._probe_cursor += 1
        return live[self._probe_cursor % len(live)]

    # ------------------------------------------------------------- drift
    def _check_drift(self):
        drafted = getattr(self.engine, "drafted", None)
        if drafted is None:
            return                          # plain engine: nothing drafted
        accepted = self.engine.accepted
        dd = drafted - self._last_drafted
        da = accepted - self._last_accepted
        self._last_drafted, self._last_accepted = drafted, accepted
        if dd <= 0:
            return
        fired = self.drift.update(da / dd)
        m = self.obs.metrics
        m.gauge("spec_acceptance_ewma").set(self.drift.ewma)
        if self.drift.baseline is not None:
            m.gauge("spec_acceptance_baseline").set(self.drift.baseline)
        if fired:
            m.counter("spec_drift_alarms_total").inc()
            self.obs.event("drift_alarm",
                           ewma=round(self.drift.ewma, 4),
                           baseline=round(self.drift.baseline, 4),
                           threshold=self.ncfg.drift_threshold)

    # ------------------------------------------------------------- probe
    def probe(self, sched, slot: int, req) -> dict | None:
        """Shadow-replay ``req``'s context; record KL / agreement / KV
        error.  The context is ``prompt + generated[:-1]`` — exactly the
        tokens whose K/V rows the pool holds for this slot (the last
        generated token is the *input* to the next step, not yet cached).
        """
        context = req.prompt + req.generated[:-1]
        c = len(context)
        bucket = self.core.pcfg.max_context
        if not 0 < c <= bucket:
            return None
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :c] = context
        toks = jnp.asarray(padded)
        pos = jnp.asarray(c - 1, jnp.int32)
        fp_logits, fp_cache = self._fp_replay(self.fp_params, toks, pos)
        q_logits = self._q_replay(self.core.params, toks, pos)

        lp_fp = _log_softmax(np.asarray(fp_logits[0, 0], np.float64))
        lp_q = _log_softmax(np.asarray(q_logits[0, 0], np.float64))
        kl = float(np.sum(np.exp(lp_fp) * (lp_fp - lp_q)))
        kl = max(kl, 0.0)                    # guard fp rounding at ~0
        agree = int(np.argmax(lp_fp)) == int(req.generated[-1])

        m = self.obs.metrics
        m.histogram("quality_shadow_kl", buckets=KL_BUCKETS).record(kl)
        probes = m.counter("quality_shadow_probes_total")
        agrees = m.counter("quality_shadow_agree_total")
        probes.inc()
        if agree:
            agrees.inc()
        if probes.value:
            m.gauge("quality_shadow_top1_agree").set(
                agrees.value / probes.value)
        self.obs.event("shadow_probe", rid=req.rid, context=c,
                       kl=round(kl, 9), agree=bool(agree))
        kv = (self._kv_probe(sched.pool, req.rid, c, fp_cache)
              if self.ncfg.kv_probe else None)
        return {"kl": kl, "agree": agree, "context": c, "kv": kv}

    def _kv_probe(self, pool, rid: int, c: int, fp_cache) -> dict:
        """Per-layer accumulated cache wire error: gather the slot's pool
        pages, dequantize at each layer's own format, compare rows
        ``0..c-1`` against the fp replay's cache."""
        table = jnp.asarray(
            pool.table_array(rid, self.core.pcfg.pages_per_slot)[None])
        d = self.cfg.head_dim
        ref = dict(layer_blocks(fp_cache, self.cfg))
        m = self.obs.metrics
        out = {}
        for i, block in layer_blocks(pool.pages, self.cfg):
            errs = []
            for key in ("k", "v"):
                got = kvwire.gather_pages(block["self"][key], table)
                if kvwire.is_quant_kv(got):
                    got = kvwire.dequantize_kv(got, d)
                got = np.asarray(got[0, :c], np.float64)
                want = np.asarray(ref[i]["self"][key][0, :c], np.float64)
                errs.append((got - want).ravel())
            err = np.concatenate(errs)
            label = _layer_label(i)
            stats = (float(np.mean(err * err)),
                     float(np.max(np.abs(err))) if err.size else 0.0)
            m.gauge("kv_dequant_mse", layer=label).set(stats[0])
            m.gauge("kv_dequant_maxabs", layer=label).set(stats[1])
            # the deployed wire width alongside the measured error, so a
            # metrics snapshot alone is enough for launch/plan.py
            # --kv-sensitivity-from to map error -> format (0 = fp wire)
            kq = block["self"]["k"]
            bits = (kvwire.kv_bits_of(kq, d)
                    if kvwire.is_quant_kv(kq) else 0)
            m.gauge("kv_dequant_bits", layer=label).set(float(bits))
            out[label] = stats
        return out


def attach_fleet_quality(router, fp_params, *,
                         ncfg: NumericsConfig | None = None) -> dict:
    """One :class:`QualityMonitor` per fleet tenant, attached to each
    tenant's scheduler (each monitor replays through that tenant's own
    engine/plan).  Returns ``{tenant_id: monitor}``."""
    monitors = {}
    for t in router.registry:
        mon = QualityMonitor(t.scheduler.obs, router.registry.model_cfg,
                             fp_params, t.engine, ncfg=ncfg)
        t.scheduler.quality = mon
        monitors[t.tenant_id] = mon
    return monitors
