"""Request-lifecycle tracing + latency metrics for the serving stack.

    obs = Observability()                     # or the global NOOP default
    server = Server(cfg, params, ecfg, pcfg, obs=obs)
    ...serve...
    obs.save_trace("trace.json")              # chrome://tracing / Perfetto
    obs.save_metrics("metrics.json")          # p50/p95/p99 snapshots

See README.md in this directory for the span model, metric names, and
export formats; ``repro.launch.serve --trace-out/--metrics-out`` is the
CLI entry point and ``python -m repro.obs.check`` validates artifacts.
"""
from .export import MetricsServer
from .flight import FlightRecorder
from .metrics import (DEFAULT_CLOCK, DEFAULT_MS_BUCKETS, Counter, Gauge,
                      Histogram, MetricsRegistry, NoopMetrics, NOOP_METRICS,
                      Stopwatch, time_fn)
from .obs import NOOP, Observability
from .trace import NOOP_TRACER, NULL_CONTEXT, NoopTracer, Tracer

__all__ = [
    "DEFAULT_CLOCK", "DEFAULT_MS_BUCKETS", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "NoopMetrics", "NOOP_METRICS", "Stopwatch",
    "time_fn",
    "NOOP", "Observability",
    "NOOP_TRACER", "NULL_CONTEXT", "NoopTracer", "Tracer",
    "FlightRecorder", "MetricsServer",
    # quality plane (lazy: numerics/residuals pull in jax + the model
    # stack, which the lightweight consumers of this package never need)
    "AcceptanceDrift", "NumericsConfig", "QualityMonitor",
    "attach_fleet_quality", "record_weight_wire_error",
    "engine_weight_configs", "record_residuals", "fit_calibration",
    "save_calibration", "load_calibration", "calibrated_hw",
    "PhaseProfiler", "annotate", "attach_fleet_profilers",
    "record_utilization", "xprof_capture",
    "SLOSpec", "TenantSLO", "SLOTracker", "good_fraction",
    "validate_report", "HealthMonitor", "attach_fleet_health",
]

_LAZY = {
    "AcceptanceDrift": "numerics", "NumericsConfig": "numerics",
    "QualityMonitor": "numerics", "attach_fleet_quality": "numerics",
    "record_weight_wire_error": "numerics",
    "engine_weight_configs": "residuals", "record_residuals": "residuals",
    "fit_calibration": "residuals", "save_calibration": "residuals",
    "load_calibration": "residuals", "calibrated_hw": "residuals",
    "PhaseProfiler": "profile", "annotate": "profile",
    "attach_fleet_profilers": "profile", "record_utilization": "profile",
    "xprof_capture": "profile",
    "SLOSpec": "slo", "TenantSLO": "slo", "SLOTracker": "slo",
    "good_fraction": "slo", "validate_report": "slo",
    "HealthMonitor": "health", "attach_fleet_health": "health",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
