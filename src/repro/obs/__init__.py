"""Request-lifecycle tracing + latency metrics for the serving stack.

    obs = Observability()                     # or the global NOOP default
    server = Server(cfg, params, ecfg, pcfg, obs=obs)
    ...serve...
    obs.save_trace("trace.json")              # chrome://tracing / Perfetto
    obs.save_metrics("metrics.json")          # p50/p95/p99 snapshots

See README.md in this directory for the span model, metric names, and
export formats; ``repro.launch.serve --trace-out/--metrics-out`` is the
CLI entry point and ``python -m repro.obs.check`` validates artifacts.
"""
from .metrics import (DEFAULT_CLOCK, DEFAULT_MS_BUCKETS, Counter, Gauge,
                      Histogram, MetricsRegistry, NoopMetrics, NOOP_METRICS,
                      Stopwatch, time_fn)
from .obs import NOOP, Observability
from .trace import NOOP_TRACER, NULL_CONTEXT, NoopTracer, Tracer

__all__ = [
    "DEFAULT_CLOCK", "DEFAULT_MS_BUCKETS", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "NoopMetrics", "NOOP_METRICS", "Stopwatch",
    "time_fn",
    "NOOP", "Observability",
    "NOOP_TRACER", "NULL_CONTEXT", "NoopTracer", "Tracer",
]
