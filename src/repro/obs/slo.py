"""SLO plane: per-tenant error budgets, burn rates, breach alerting.

PRs 6-8 built the *measurement* planes (tracing, quality probes, perf
attribution); this module is the *judgment* plane — it holds those
measurements against explicit per-tenant targets, SRE-style:

* :class:`TenantSLO` — one tenant's targets: TTFT p95 / ITL p95 upper
  bounds (ms), a tok/s floor, an availability floor
  (``1 - rejected/submitted``), and an acceptance-rate floor for
  speculative tenants.  Every target is optional; unset objectives are
  simply not tracked.
* :class:`SLOSpec` — the serializable spec (JSON round-trip with
  validation, like ``repro.plan.QuantPlan``): per-tenant targets plus
  the shared window/alerting configuration.  Fleet manifests carry it
  as an ``"slo"`` section (``repro.fleet.load_manifest``).
* :class:`SLOTracker` — consumes the metrics the serving stack already
  records (``serve_ttft_ms{tenant=}`` / ``serve_itl_ms{tenant=}``
  histograms, ``serve_tokens_total`` counters, ``FleetTelemetry``
  submit/reject counters, the ``spec_acceptance_rate`` gauge) through
  sliding **step** windows and computes, per (tenant, objective):

    - multi-window burn rates: how fast the error budget is burning
      over the ``fast_steps`` window ("5m-equivalent" decode steps) and
      the ``slow_steps`` window ("1h-equivalent") — burn 1.0 == exactly
      consuming the budget, SRE-style;
    - error-budget consumption over the ``budget_steps`` window
      (``slo_budget_remaining`` in [0, 1]);
    - an ok -> warning -> breach state machine that fires one
      ``slo_breach`` trace event per breach episode (latching like
      ``AcceptanceDrift``), rate-limited by ``cooldown_s`` — the event
      is a ``FlightRecorder`` dump trigger, so a breach snapshots the
      recent timeline automatically.

Windows are measured in *tracker polls* (one ``on_step()`` per decode
step), not wall-clock, so the whole plane is injectable-clock testable;
the "5m/1h-equivalent" defaults assume roughly one poll per second and
shrink to a handful of steps in smoke specs.

Like the rest of ``repro.obs`` this is host-side bookkeeping over
already-recorded metrics: nothing enters a compiled function, tokens are
bit-identical with tracking on, and the decode step never retraces.

CLI gate (exit 0 ok / 1 breach or invalid / 2 usage, like
``repro.obs.regress``)::

    python -m repro.obs.slo report.json          # gate a saved report
    python -m repro.obs.slo --demo-breach out.json   # synthesize a
        # breached report through a real tracker (negative-test input)
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import math
import sys
from collections import deque

# objective name -> (metric direction) — latency targets are upper
# bounds on the p95, the rest are floors
LATENCY_OBJECTIVES = ("ttft_p95_ms", "itl_p95_ms")
FLOOR_OBJECTIVES = ("tok_per_s", "availability", "acceptance_rate")
OBJECTIVES = LATENCY_OBJECTIVES + FLOOR_OBJECTIVES
STATES = ("ok", "warning", "breach")
_STATE_LEVEL = {"ok": 0, "warning": 1, "breach": 2}
# a p95 latency target tolerates 5% bad samples by definition
_P95_FRACTION = 0.95
# burn-rate denominator floor: an availability floor of exactly 1.0
# leaves a zero error budget; clamp so burn rates stay finite
_MIN_EPS = 1e-6


def good_fraction(hist, target: float) -> float:
    """Fraction of a fixed-bucket histogram's samples <= ``target``.

    Buckets whose upper bound exceeds ``target`` count as bad even when
    the target falls inside them (conservative: never over-reports
    compliance).  Empty histograms are fully compliant.
    """
    if not getattr(hist, "count", 0):
        return 1.0
    return good_count(hist, target) / hist.count


def good_count(hist, target: float) -> int:
    """Number of samples recorded at or under ``target`` (see
    :func:`good_fraction` for the in-bucket convention)."""
    idx = bisect.bisect_right(hist.buckets, float(target))
    return sum(hist.counts[:idx])


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantSLO:
    """One tenant's objective targets.  Unset (None) == not tracked."""
    ttft_p95_ms: float | None = None     # TTFT p95 upper bound, ms
    itl_p95_ms: float | None = None      # inter-token-latency p95, ms
    tok_per_s: float | None = None       # decode-throughput floor
    availability: float | None = None    # floor on 1 - rejected/submitted
    acceptance_rate: float | None = None  # spec-decode acceptance floor

    def __post_init__(self):
        for name in LATENCY_OBJECTIVES + ("tok_per_s",):
            v = getattr(self, name)
            if v is not None and not (isinstance(v, (int, float))
                                      and math.isfinite(v) and v > 0):
                raise ValueError(f"{name} must be a finite positive "
                                 f"number, got {v!r}")
        for name in ("availability", "acceptance_rate"):
            v = getattr(self, name)
            if v is not None and not (isinstance(v, (int, float))
                                      and 0.0 < v <= 1.0):
                raise ValueError(f"{name} must be in (0, 1], got {v!r}")

    def objectives(self) -> dict:
        """The set targets: ``{objective_name: target}``."""
        return {n: getattr(self, n) for n in OBJECTIVES
                if getattr(self, n) is not None}

    def to_obj(self) -> dict:
        return self.objectives()

    @staticmethod
    def from_obj(obj: dict) -> "TenantSLO":
        if not isinstance(obj, dict):
            raise ValueError(f"tenant SLO entry must be an object, "
                             f"got {obj!r}")
        unknown = sorted(set(obj) - set(OBJECTIVES))
        if unknown:
            raise ValueError(f"unknown SLO objectives {unknown}; "
                             f"known: {list(OBJECTIVES)}")
        return TenantSLO(**obj)


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Serializable per-tenant SLO targets + shared window/alert config.

    ``tenants`` maps tenant ids to their :class:`TenantSLO`; ``default``
    (optional) applies to tenants that carry traffic but have no
    explicit row.  Windows are in tracker steps; ``target`` is the
    good-event fraction objective for the floor objectives (latency p95
    targets imply 0.95, an availability floor is its own fraction).
    """
    tenants: tuple = ()                  # ((tenant_id, TenantSLO), ...)
    default: TenantSLO | None = None
    target: float = 0.95                 # good-event fraction (floors)
    fast_steps: int = 300                # "5m-equivalent" burn window
    slow_steps: int = 3600               # "1h-equivalent" burn window
    budget_steps: int = 3600             # error-budget accounting window
    warn_burn: float = 2.0               # fast burn >= this -> warning
    breach_burn: float = 6.0             # fast AND slow >= this -> breach
    cooldown_s: float = 5.0              # min clock between breach events

    def __post_init__(self):
        seen = set()
        for entry in self.tenants:
            tid, tslo = entry
            if not tid or not isinstance(tid, str):
                raise ValueError(f"tenant id must be a non-empty string, "
                                 f"got {tid!r}")
            if tid in seen:
                raise ValueError(f"duplicate tenant {tid!r} in SLOSpec")
            seen.add(tid)
            if not isinstance(tslo, TenantSLO):
                raise ValueError(f"tenant {tid!r}: expected a TenantSLO, "
                                 f"got {type(tslo).__name__}")
        if not 0.0 < self.target < 1.0:
            raise ValueError(f"target must be in (0, 1), got {self.target}")
        for name in ("fast_steps", "slow_steps", "budget_steps"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{name} must be an int >= 1, got {v!r}")
        if self.fast_steps > self.slow_steps:
            raise ValueError(f"fast_steps ({self.fast_steps}) must not "
                             f"exceed slow_steps ({self.slow_steps})")
        for name in ("warn_burn", "breach_burn"):
            v = getattr(self, name)
            if not (isinstance(v, (int, float)) and v > 0):
                raise ValueError(f"{name} must be > 0, got {v!r}")
        if self.warn_burn > self.breach_burn:
            raise ValueError(f"warn_burn ({self.warn_burn}) must not "
                             f"exceed breach_burn ({self.breach_burn})")
        if self.cooldown_s < 0:
            raise ValueError(f"cooldown_s must be >= 0, "
                             f"got {self.cooldown_s}")

    def tenant_slo(self, tenant_id: str) -> TenantSLO | None:
        for tid, tslo in self.tenants:
            if tid == tenant_id:
                return tslo
        return self.default

    # ------------------------------------------------------------- JSON
    def to_obj(self) -> dict:
        return {
            "version": 1,
            "target": self.target,
            "windows": {"fast_steps": self.fast_steps,
                        "slow_steps": self.slow_steps,
                        "budget_steps": self.budget_steps},
            "alerting": {"warn_burn": self.warn_burn,
                         "breach_burn": self.breach_burn,
                         "cooldown_s": self.cooldown_s},
            "default": (self.default.to_obj()
                        if self.default is not None else None),
            "tenants": {tid: tslo.to_obj() for tid, tslo in self.tenants},
        }

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_obj(), indent=indent, sort_keys=True)

    @staticmethod
    def from_obj(obj: dict, *, extra_tenants=()) -> "SLOSpec":
        """Parse the JSON object form.  ``extra_tenants`` (an iterable of
        ``(tenant_id, TenantSLO)``) merges per-tenant rows from outside
        the spec object — fleet manifests carry targets inline on tenant
        entries; an inline row overrides the spec object's row."""
        if not isinstance(obj, dict):
            raise ValueError(f"SLO spec must be a JSON object, got {obj!r}")
        version = obj.get("version", 1)
        if version != 1:
            raise ValueError(f"unsupported SLO spec version {version!r}")
        known = {"version", "target", "windows", "alerting", "default",
                 "tenants"}
        unknown = sorted(set(obj) - known)
        if unknown:
            raise ValueError(f"unknown SLO spec keys {unknown}; "
                             f"known: {sorted(known)}")
        windows = obj.get("windows") or {}
        alerting = obj.get("alerting") or {}
        for section, allowed in ((windows, ("fast_steps", "slow_steps",
                                            "budget_steps")),
                                 (alerting, ("warn_burn", "breach_burn",
                                             "cooldown_s"))):
            bad = sorted(set(section) - set(allowed))
            if bad:
                raise ValueError(f"unknown SLO spec keys {bad}; "
                                 f"known: {list(allowed)}")
        tenants = {tid: TenantSLO.from_obj(t)
                   for tid, t in (obj.get("tenants") or {}).items()}
        tenants.update(extra_tenants)
        default = obj.get("default")
        kw = {}
        if "target" in obj:
            kw["target"] = obj["target"]
        kw.update(windows)
        kw.update(alerting)
        return SLOSpec(
            tenants=tuple(sorted(tenants.items())),
            default=(TenantSLO.from_obj(default)
                     if default is not None else None),
            **kw)

    @staticmethod
    def from_json(text: str) -> "SLOSpec":
        return SLOSpec.from_obj(json.loads(text))

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())
            f.write("\n")

    @staticmethod
    def load(path: str) -> "SLOSpec":
        with open(path) as f:
            return SLOSpec.from_json(f.read())


# ---------------------------------------------------------------------------
# sliding windows + per-objective series
# ---------------------------------------------------------------------------

class _Window:
    """Running (good, total) sums over the last ``steps`` pushes."""
    __slots__ = ("steps", "_deq", "good", "total")

    def __init__(self, steps: int):
        self.steps = steps
        self._deq: deque = deque()
        self.good = 0
        self.total = 0

    def push(self, good: int, total: int):
        self._deq.append((good, total))
        self.good += good
        self.total += total
        while len(self._deq) > self.steps:
            g, t = self._deq.popleft()
            self.good -= g
            self.total -= t

    @property
    def bad(self) -> int:
        return self.total - self.good

    def bad_fraction(self) -> float:
        return self.bad / self.total if self.total else 0.0


class _Series:
    """One (tenant, objective) event stream and its alert state."""
    __slots__ = ("tenant", "objective", "target", "fraction", "eps",
                 "fast", "slow", "budget", "state", "episodes",
                 "good_total", "total", "_cursor", "_rate_cursor")

    def __init__(self, tenant: str, objective: str, target: float,
                 spec: SLOSpec):
        self.tenant, self.objective, self.target = tenant, objective, target
        if objective in LATENCY_OBJECTIVES:
            self.fraction = _P95_FRACTION
        elif objective == "availability":
            self.fraction = target
        else:
            self.fraction = spec.target
        self.eps = max(1.0 - self.fraction, _MIN_EPS)
        self.fast = _Window(spec.fast_steps)
        self.slow = _Window(spec.slow_steps)
        self.budget = _Window(spec.budget_steps)
        self.state = "ok"
        self.episodes: list[dict] = []
        self.good_total = 0
        self.total = 0
        self._cursor = (0, 0)       # cumulative (good, total) last seen
        self._rate_cursor = None    # (clock, tokens) for the tok/s floor

    def push_cumulative(self, good: int, total: int):
        """Feed new cumulative counts; deltas enter every window."""
        pg, pt = self._cursor
        dg, dt = good - pg, total - pt
        if dt < 0 or dg < 0:        # counter reset (fresh telemetry)
            self._cursor = (good, total)
            dg, dt = good, total
        else:
            self._cursor = (good, total)
        self.push_delta(dg, dt)

    def push_delta(self, good: int, total: int):
        self.good_total += good
        self.total += total
        for w in (self.fast, self.slow, self.budget):
            w.push(good, total)

    # ------------------------------------------------------------ derived
    def burn(self, window: _Window) -> float:
        """Burn rate: bad-event fraction over the window, in units of
        the allowed bad fraction (1.0 == exactly consuming budget)."""
        return window.bad_fraction() / self.eps

    def budget_remaining(self) -> float:
        if not self.budget.total:
            return 1.0
        allowed = self.eps * self.budget.total
        return min(max(1.0 - self.budget.bad / allowed, 0.0), 1.0)

    def evaluate(self, spec: SLOSpec) -> tuple[str, bool]:
        """Advance the state machine; returns (state, entered_breach)."""
        bf, bs = self.burn(self.fast), self.burn(self.slow)
        if bf >= spec.breach_burn and bs >= spec.breach_burn:
            new = "breach"
        elif bf >= spec.warn_burn:
            new = "warning"
        else:
            new = "ok"
        entered = new == "breach" and self.state != "breach"
        self.state = new
        return new, entered

    def summary(self) -> dict:
        return {"objective": self.objective, "target": self.target,
                "slo_fraction": self.fraction, "state": self.state,
                "budget_remaining": round(self.budget_remaining(), 6),
                "burn_fast": round(self.burn(self.fast), 6),
                "burn_slow": round(self.burn(self.slow), 6),
                "events_total": self.total,
                "bad_total": self.total - self.good_total,
                "episodes": [dict(e) for e in self.episodes]}


# ---------------------------------------------------------------------------
# tracker
# ---------------------------------------------------------------------------

class SLOTracker:
    """Error-budget accounting over the live metrics registry.

    Call :meth:`on_step` once per decode step (the launch loops do; the
    fleet path also threads summaries into ``FleetTelemetry.snapshot``).
    All reads go through ``obs.metrics.find`` — nothing is created, and
    a disabled obs turns the tracker into a no-op.

    ``telemetry`` (a :class:`repro.fleet.FleetTelemetry`) supplies the
    submitted/rejected counters behind the availability objective and
    the set of tenants the ``default`` targets apply to; without it the
    single-cell serve path tracks the ``"default"`` tenant.
    """

    def __init__(self, spec: SLOSpec, obs, *, telemetry=None, clock=None):
        self.spec = spec
        self.obs = obs
        self.telemetry = telemetry
        self.clock = clock or obs.clock
        self.steps = 0
        self._series: dict[tuple, _Series] = {}
        self._last_fire: dict[tuple, float] = {}
        self.suppressed_events = 0

    # ---------------------------------------------------------- resolve
    def _resolved(self) -> dict:
        """{tenant_id: TenantSLO} — explicit rows plus the default for
        every tenant currently known to telemetry (or "default")."""
        out = dict(self.spec.tenants)
        if self.spec.default is not None:
            ids = (self.telemetry.per_tenant.keys()
                   if self.telemetry is not None else ("default",))
            for tid in ids:
                out.setdefault(tid, self.spec.default)
        return out

    def _get_series(self, tenant: str, objective: str,
                    target: float) -> _Series:
        key = (tenant, objective)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _Series(tenant, objective, target,
                                            self.spec)
        return s

    # ----------------------------------------------------------- observe
    def _observe(self, s: _Series):
        """Pull the objective's current good/total counts into windows."""
        m = self.obs.metrics
        if s.objective in ("ttft_p95_ms", "itl_p95_ms"):
            name = ("serve_ttft_ms" if s.objective == "ttft_p95_ms"
                    else "serve_itl_ms")
            h = m.find(name, tenant=s.tenant)
            if h is None or not getattr(h, "count", 0):
                s.push_delta(0, 0)
                return
            s.push_cumulative(good_count(h, s.target), h.count)
        elif s.objective == "availability":
            st = (self.telemetry.per_tenant.get(s.tenant)
                  if self.telemetry is not None else None)
            if st is None:
                s.push_delta(0, 0)
                return
            s.push_cumulative(st.submitted - st.rejected, st.submitted)
        elif s.objective == "tok_per_s":
            # one event per poll: did the tenant sustain its floor over
            # the interval since the last poll?
            c = m.find("serve_tokens_total", tenant=s.tenant)
            now = self.clock()
            prev = getattr(s, "_rate_cursor", None)
            tokens = c.value if c is not None else 0
            s._rate_cursor = (now, tokens)
            if prev is None:
                s.push_delta(0, 0)
                return
            t0, tok0 = prev
            dt = now - t0
            if dt <= 0:
                s.push_delta(0, 0)
                return
            rate = (tokens - tok0) / dt
            good = 1 if rate >= s.target else 0
            s.push_delta(good, 1)
        elif s.objective == "acceptance_rate":
            g = m.find("spec_acceptance_rate")
            if g is None:
                s.push_delta(0, 0)
                return
            s.push_delta(1 if g.value >= s.target else 0, 1)

    # -------------------------------------------------------------- step
    def on_step(self):
        """One tracker poll: windows advance, gauges refresh, breaches
        fire.  Host-side reads only — safe to call every decode step."""
        if not getattr(self.obs, "enabled", False):
            return
        self.steps += 1
        m = self.obs.metrics
        for tenant, tslo in sorted(self._resolved().items()):
            for objective, target in tslo.objectives().items():
                s = self._get_series(tenant, objective, target)
                self._observe(s)
                state, entered = s.evaluate(self.spec)
                m.gauge("slo_budget_remaining", tenant=tenant,
                        objective=objective).set(s.budget_remaining())
                m.gauge("slo_burn_rate", tenant=tenant,
                        objective=objective,
                        window="fast").set(s.burn(s.fast))
                m.gauge("slo_burn_rate", tenant=tenant,
                        objective=objective,
                        window="slow").set(s.burn(s.slow))
                m.gauge("slo_state", tenant=tenant,
                        objective=objective).set(_STATE_LEVEL[state])
                if entered:
                    self._fire(s)
                elif state == "ok" and s.episodes \
                        and "end_step" not in s.episodes[-1]:
                    ep = s.episodes[-1]
                    ep["end_step"] = self.steps
                    ep["end_clock"] = self.clock()

    def _fire(self, s: _Series):
        """Open a breach episode; emit one ``slo_breach`` event unless a
        recent one for this series is still inside ``cooldown_s``."""
        now = self.clock()
        ep = {"tenant": s.tenant, "objective": s.objective,
              "start_step": self.steps, "start_clock": now,
              "burn_fast": round(s.burn(s.fast), 6),
              "burn_slow": round(s.burn(s.slow), 6),
              "budget_remaining": round(s.budget_remaining(), 6)}
        s.episodes.append(ep)
        key = (s.tenant, s.objective)
        last = self._last_fire.get(key)
        if last is not None and now - last < self.spec.cooldown_s:
            self.suppressed_events += 1
            ep["event_suppressed"] = True
            return
        self._last_fire[key] = now
        self.obs.event("slo_breach", tenant=s.tenant,
                       objective=s.objective,
                       burn_fast=ep["burn_fast"],
                       burn_slow=ep["burn_slow"],
                       budget_remaining=ep["budget_remaining"])
        self.obs.metrics.counter("slo_breach_total", tenant=s.tenant,
                                 objective=s.objective).inc()

    # ------------------------------------------------------------ report
    def worst_state(self, tenant_id: str) -> str:
        level = 0
        for (tid, _), s in self._series.items():
            if tid == tenant_id:
                level = max(level, _STATE_LEVEL[s.state])
        return STATES[level]

    def tenant_summary(self, tenant_id: str) -> dict:
        """Compact per-tenant view for ``FleetTelemetry.snapshot()``."""
        out = {}
        for (tid, objective), s in sorted(self._series.items()):
            if tid != tenant_id:
                continue
            out[objective] = {
                "state": s.state,
                "budget_remaining": round(s.budget_remaining(), 6),
                "burn_fast": round(s.burn(s.fast), 6),
                "burn_slow": round(s.burn(s.slow), 6)}
        return out

    def report(self) -> dict:
        tenants: dict = {}
        worst = 0
        breached = False
        for (tid, objective), s in sorted(self._series.items()):
            tenants.setdefault(tid, {})[objective] = s.summary()
            worst = max(worst, _STATE_LEVEL[s.state])
            breached = breached or bool(s.episodes)
        return {"version": 1, "steps": self.steps,
                "worst_state": STATES[worst], "breached": breached,
                "suppressed_events": self.suppressed_events,
                "spec": self.spec.to_obj(), "tenants": tenants}

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.report(), f, indent=1, sort_keys=True)
            f.write("\n")


# ---------------------------------------------------------------------------
# report validation (shared with ``repro.obs.check --slo``)
# ---------------------------------------------------------------------------

def validate_report(report: dict) -> list[str]:
    """Assert a saved SLO report is structurally sound; returns the
    ``tenant/objective`` keys found.  Raises AssertionError on the first
    problem (``repro.obs.check`` turns that into exit 1).
    """
    assert isinstance(report, dict), "SLO report must be a JSON object"
    assert report.get("version") == 1, \
        f"unsupported SLO report version {report.get('version')!r}"
    assert report.get("worst_state") in STATES, \
        f"bad worst_state {report.get('worst_state')!r}"
    tenants = report.get("tenants")
    assert isinstance(tenants, dict), "report lacks a tenants object"
    spec = report.get("spec")
    assert isinstance(spec, dict), "report lacks its spec"
    spec_tenants = spec.get("tenants") or {}
    found = []
    for tid, objectives in spec_tenants.items():
        assert tid in tenants, f"spec tenant {tid!r} missing from report"
        for objective in objectives:
            assert objective in tenants[tid], \
                f"tenant {tid!r} objective {objective!r} missing from report"
    for tid, objectives in tenants.items():
        assert isinstance(objectives, dict) and objectives, \
            f"tenant {tid!r} carries no objectives"
        for objective, row in objectives.items():
            where = f"{tid}/{objective}"
            assert objective in OBJECTIVES, \
                f"{where}: unknown objective"
            assert row.get("state") in STATES, \
                f"{where}: bad state {row.get('state')!r}"
            b = row.get("budget_remaining")
            assert isinstance(b, (int, float)) and 0.0 <= b <= 1.0, \
                f"{where}: budget_remaining {b!r} outside [0, 1]"
            for burn in ("burn_fast", "burn_slow"):
                v = row.get(burn)
                assert isinstance(v, (int, float)) and \
                    math.isfinite(v) and v >= 0.0, \
                    f"{where}: {burn} {v!r} not a finite non-negative number"
            eps = row.get("episodes")
            assert isinstance(eps, list), f"{where}: episodes not a list"
            for ep in eps:
                assert isinstance(ep.get("start_step"), int), \
                    f"{where}: episode lacks start_step"
                end = ep.get("end_step")
                assert end is None or (isinstance(end, int)
                                       and end >= ep["start_step"]), \
                    f"{where}: episode ends before it starts"
            found.append(where)
    return found


# ---------------------------------------------------------------------------
# CLI gate
# ---------------------------------------------------------------------------

def _demo_breach(path: str) -> int:
    """Write a synthetic breached report: a real tracker over a fake
    clock with an injected ITL regression on one of two tenants — the
    ``make slo-smoke`` negative test (and a worked example of the
    plane's mechanics)."""
    from repro.obs import Observability

    t = [0.0]
    obs = Observability(clock=lambda: t[0])
    spec = SLOSpec(
        tenants=(("bronze", TenantSLO(itl_p95_ms=50.0)),
                 ("gold", TenantSLO(itl_p95_ms=50.0))),
        fast_steps=8, slow_steps=16, budget_steps=16,
        warn_burn=2.0, breach_burn=4.0, cooldown_s=1.0)
    tracker = SLOTracker(spec, obs)
    gold = obs.metrics.histogram("serve_itl_ms", tenant="gold")
    bronze = obs.metrics.histogram("serve_itl_ms", tenant="bronze")
    for step in range(24):
        t[0] += 1.0
        gold.record(5.0)                     # healthy tenant stays healthy
        bronze.record(5.0 if step < 8 else 500.0)   # injected regression
        tracker.on_step()
    tracker.save(path)
    rep = tracker.report()
    print(f"wrote {path} (worst_state={rep['worst_state']}, "
          f"breached={rep['breached']})")
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if len(argv) == 2 and argv[0] == "--demo-breach":
        return _demo_breach(argv[1])
    if len(argv) != 1 or argv[0].startswith("-"):
        print("usage: python -m repro.obs.slo report.json\n"
              "       python -m repro.obs.slo --demo-breach out.json",
              file=sys.stderr)
        return 2
    try:
        with open(argv[0]) as f:
            report = json.load(f)
        found = validate_report(report)
    except (AssertionError, json.JSONDecodeError, OSError) as e:
        print(f"slo: invalid report: {e}", file=sys.stderr)
        return 1
    episodes = sum(len(row["episodes"])
                   for objectives in report["tenants"].values()
                   for row in objectives.values())
    print(f"slo: {len(found)} objectives over {report.get('steps', 0)} "
          f"steps, worst state {report['worst_state']}, "
          f"{episodes} breach episodes")
    for tid, objectives in sorted(report["tenants"].items()):
        for objective, row in sorted(objectives.items()):
            print(f"  {tid}/{objective}: {row['state']}, budget "
                  f"{row['budget_remaining']:.3f}, burn "
                  f"fast {row['burn_fast']:.2f} / "
                  f"slow {row['burn_slow']:.2f}, "
                  f"{len(row['episodes'])} episodes")
    if report.get("breached") or report["worst_state"] == "breach":
        print("slo: FAIL — at least one objective breached",
              file=sys.stderr)
        return 1
    print("slo: OK — every objective within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
