"""Counters, gauges, fixed-bucket histograms, and THE wall-clock helpers.

Every wall-clock measurement in the repo goes through this module —
:class:`Stopwatch` for elapsed-time blocks, :func:`time_fn` for
per-call microbenchmarks (``jax.block_until_ready``-bounded) — so
"how we time things" is defined in exactly one place.

:class:`MetricsRegistry` keys metrics by name + label set (Prometheus
style, e.g. ``serve_ttft_ms{tenant="gold"}``) and snapshots to JSON or
Prometheus text exposition format.  Histograms are fixed-bucket:
``record`` is O(log buckets) and percentiles (p50/p95/p99) are read by
cumulative-count walk with linear interpolation inside the straddling
bucket, clamped to the observed [min, max] (the overflow bucket reports
the observed max).
"""
from __future__ import annotations

import bisect
import json
import math
import time

DEFAULT_CLOCK = time.perf_counter

# 1-2-5 series, 1 µs .. 50 s, in milliseconds: wide enough for TTFT on a
# cold CPU host and fine enough for sub-ms compiled decode steps.
DEFAULT_MS_BUCKETS = tuple(c * 10.0 ** e
                           for e in range(-3, 5) for c in (1, 2, 5))


class Stopwatch:
    """The shared elapsed-wall-clock primitive.

        sw = Stopwatch()
        ...work...
        dt = sw.elapsed()        # seconds; sw.elapsed_ms() for ms

    ``clock`` is injectable (seconds, monotonic) for deterministic tests.
    """

    def __init__(self, clock=DEFAULT_CLOCK):
        self._clock = clock
        self._t0 = clock()

    def reset(self) -> "Stopwatch":
        self._t0 = self._clock()
        return self

    @property
    def start(self) -> float:
        """The raw clock reading at (re)start."""
        return self._t0

    def elapsed(self) -> float:
        return self._clock() - self._t0

    def elapsed_ms(self) -> float:
        return self.elapsed() * 1e3


def time_fn(fn, *args, reps: int = 1, warmup: int = 1,
            clock=DEFAULT_CLOCK) -> float:
    """Seconds per call of ``fn(*args)``, device-synchronized.

    Runs ``warmup`` untimed calls (compile/jit warm), then ``reps`` timed
    calls bounded by ``jax.block_until_ready`` on the last result — the
    one microbenchmark loop every ``benchmarks/`` table shares.
    """
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    sw = Stopwatch(clock)
    out = None
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return sw.elapsed() / max(reps, 1)


# ---------------------------------------------------------------------------
# metric primitives
# ---------------------------------------------------------------------------

class Counter:
    """Monotonic event count."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1):
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``buckets`` are ascending upper bounds; values above the last bound
    land in an implicit overflow bucket.  ``percentile(p)`` finds the
    bucket holding rank ``p/100 * count`` in the cumulative counts and
    interpolates linearly between the bucket's bounds (lower bound 0 for
    the first bucket), clamped to the observed [min, max]; the overflow
    bucket reports the observed max.
    """
    __slots__ = ("buckets", "counts", "count", "sum", "min", "max")

    def __init__(self, buckets=DEFAULT_MS_BUCKETS):
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        self.counts = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, v: float):
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def percentile(self, p: float) -> float:
        if not self.count:
            return 0.0
        rank = (p / 100.0) * self.count
        if rank <= 0:
            return self.min
        cum = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            prev, cum = cum, cum + c
            if cum >= rank:
                if i == len(self.buckets):          # overflow bucket
                    return self.max
                lo = self.buckets[i - 1] if i else 0.0
                hi = self.buckets[i]
                est = lo + (hi - lo) * (rank - prev) / c
                return min(max(est, self.min), self.max)
        return self.max

    def snapshot(self) -> dict:
        if not self.count:
            return {"count": 0, "sum": 0.0}
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class _NoopMetric:
    """Counter/gauge/histogram stand-in when metrics are disabled."""
    __slots__ = ()
    value = 0
    count = 0
    sum = 0.0

    def inc(self, n: int = 1):
        pass

    def set(self, v: float):
        pass

    def record(self, v: float):
        pass

    def percentile(self, p: float) -> float:
        return 0.0

    def snapshot(self) -> dict:
        return {}


NOOP_METRIC = _NoopMetric()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Name+labels keyed metric store with JSON / Prometheus export."""
    enabled = True

    def __init__(self):
        self._metrics: dict[str, tuple[str, object]] = {}

    def _get(self, kind: str, name: str, labels: dict, factory):
        key = _key(name, labels)
        hit = self._metrics.get(key)
        if hit is None:
            hit = (kind, factory())
            self._metrics[key] = hit
        elif hit[0] != kind:
            raise TypeError(f"metric {key!r} already registered as "
                            f"{hit[0]}, requested {kind}")
        return hit[1]

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, buckets=DEFAULT_MS_BUCKETS,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(buckets))

    def find(self, name: str, **labels):
        """The metric at this key, or None — without creating it."""
        hit = self._metrics.get(_key(name, labels))
        return hit[1] if hit else None

    @property
    def histograms(self) -> dict:
        """All histograms by full key (``name{labels}``), insertion-safe
        read-only view for reporting loops."""
        return {k: m for k, (kind, m) in self._metrics.items()
                if kind == "histogram"}

    # ------------------------------------------------------------ export
    def snapshot(self) -> dict:
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for key, (kind, m) in sorted(self._metrics.items()):
            if kind == "histogram":
                out["histograms"][key] = m.snapshot()
            else:
                out[kind + "s"][key] = m.value
        return out

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        by_name: dict[str, list] = {}
        types: dict[str, str] = {}
        for key, (kind, m) in sorted(self._metrics.items()):
            name = key.split("{", 1)[0]
            labels = key[len(name):].strip("{}")
            by_name.setdefault(name, []).append((labels, kind, m))
            types[name] = kind
        lines = []
        for name, rows in by_name.items():
            lines.append(f"# TYPE {name} {types[name]}")
            for labels, kind, m in rows:
                if kind != "histogram":
                    lines.append(f"{name}{{{labels}}} {m.value}"
                                 if labels else f"{name} {m.value}")
                    continue
                cum = 0
                for bound, c in zip(m.buckets, m.counts):
                    cum += c
                    le = f'le="{bound:g}"'
                    lb = f"{labels},{le}" if labels else le
                    lines.append(f"{name}_bucket{{{lb}}} {cum}")
                lb = f'{labels},le="+Inf"' if labels else 'le="+Inf"'
                lines.append(f"{name}_bucket{{{lb}}} {m.count}")
                suffix = f"{{{labels}}}" if labels else ""
                lines.append(f"{name}_sum{suffix} {m.sum}")
                lines.append(f"{name}_count{suffix} {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def save(self, path: str):
        """Write the JSON snapshot (``.prom`` suffix: Prometheus text)."""
        text = (self.to_prometheus() if path.endswith(".prom")
                else self.to_json())
        with open(path, "w") as f:
            f.write(text)


class NoopMetrics:
    """Metrics disabled: every lookup returns the shared no-op metric."""
    enabled = False

    def counter(self, name: str, **labels):
        return NOOP_METRIC

    def gauge(self, name: str, **labels):
        return NOOP_METRIC

    def histogram(self, name: str, buckets=DEFAULT_MS_BUCKETS, **labels):
        return NOOP_METRIC

    def find(self, name: str, **labels):
        return None

    @property
    def histograms(self) -> dict:
        return {}

    def snapshot(self) -> dict:
        return {}

    def to_json(self, indent: int | None = 2) -> str:
        return "{}"

    def to_prometheus(self) -> str:
        return ""

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())


NOOP_METRICS = NoopMetrics()
