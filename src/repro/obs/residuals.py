"""Cost-model calibration: roofline predictions vs measured serving.

``plan/costmodel.py`` prices every candidate plan with an analytic
roofline (weight wire bytes / HBM bandwidth vs MACs / peak FLOPs).
Predictions drift from real hardware unless continuously calibrated —
this module closes the loop against the live serving metrics:

* **predicted** — ``plan_cost`` decode-ms + weight bytes for exactly the
  per-layer configs the engine deployed, and ``plan_kv_cost`` cache
  bytes at the pool's real token capacity;
* **measured** — the wire bytes actually resident (``QWeight.nbytes``
  walked over the engine's packed params; ``pool.nbytes()``) and the p50
  of the ``serve_decode_step_ms`` histogram the engine recorded;
* **residual** — ``costmodel_residual{quantity=...,stat=...}`` gauges
  (stat in predicted / measured / ratio), where ratio = measured /
  predicted.

Byte quantities are exact by construction (both sides count the same
wire format), so their ratios are ~1.0 and act as self-checks; the
decode-ms ratio is the genuine hardware-calibration signal.
:func:`fit_calibration` persists it as a correction factor and
:func:`calibrated_hw` folds it back into the roofline constants, which
``python -m repro.launch.plan --calibration`` feeds into the next
search — predicted ms then track the measured host.
"""
from __future__ import annotations

import dataclasses
import json

from repro.core import schemes
from repro.kernels.ops import QWeight
from repro.plan.costmodel import plan_cost, plan_kv_cost
from repro.roofline import HW


def engine_weight_configs(cfg, ecfg) -> tuple:
    """The per-layer :class:`QuantConfig` tuple an engine deployed —
    the exact configs ``plan_cost`` must price to match its params."""
    if ecfg.plan is not None:
        return tuple(ecfg.plan.resolve(cfg))
    if ecfg.weight_scheme is not None:
        qcfg = schemes.get(ecfg.weight_scheme)
        if ecfg.a_bits is not None:
            qcfg = dataclasses.replace(qcfg, a_bits=ecfg.a_bits)
        return (qcfg,) * cfg.n_layers
    return (schemes.FP32,) * cfg.n_layers


def engine_kv_list(cfg, engine) -> tuple:
    """Per-layer cache bits tuple of the engine's kv wire layout."""
    bits, _ = engine._kv_layout
    if isinstance(bits, (tuple, list)):
        return tuple(bits)
    return (bits,) * cfg.n_layers


def measured_weight_bytes(params) -> int:
    """Resident decoder weight bytes of a (possibly packed) param tree:
    ``QWeight.nbytes`` for packed leaves, fp itemsize for the dense
    leaves ``transformer.quantize_params`` would have packed (norms /
    router / conv leaves are excluded on both sides)."""
    from repro.models.transformer import _EXCLUDE_KEYS
    total = 0

    def visit(t):
        nonlocal total
        if isinstance(t, QWeight):
            total += t.nbytes()
        elif isinstance(t, dict):
            for k, v in t.items():
                if k in _EXCLUDE_KEYS:
                    continue
                if k in ("w", "wi_gate", "wi_up", "wo") \
                        and hasattr(v, "ndim") and not isinstance(v, dict) \
                        and v.ndim >= 2:
                    total += v.size * v.dtype.itemsize
                else:
                    visit(v)
        elif isinstance(t, (list, tuple)):
            for v in t:
                visit(v)

    visit(params["decoder"])
    return total


def record_residuals(obs, cfg, engine, pool, *, hw: HW | None = None,
                     labels: dict | None = None) -> dict:
    """Compare roofline predictions against this serving cell's measured
    bytes/latency; export ``costmodel_residual{quantity,stat}`` gauges
    (plus ``labels``, e.g. ``{"tenant": ...}`` in fleet mode).

    Returns ``{quantity: {"predicted", "measured", "ratio"}}`` for
    quantities ``decode_ms`` (per decode step, p50 measured),
    ``weight_bytes`` and ``kv_bytes``.  ``decode_ms`` is present only
    once the engine has recorded ``serve_decode_step_ms``.
    """
    labels = labels or {}
    core = getattr(engine, "verifier", engine)    # spec: price the verifier
    configs = engine_weight_configs(cfg, core.ecfg)
    predicted = plan_cost(cfg, configs, hw)
    kv_tokens = pool.n_pages * pool.page_size
    kv_pred = plan_kv_cost(cfg, engine_kv_list(cfg, core),
                           kv_group=core._kv_layout[1], tokens=kv_tokens)

    out = {
        "weight_bytes": {"predicted": float(predicted["bytes"]),
                         "measured": float(measured_weight_bytes(
                             core.params))},
        "kv_bytes": {"predicted": float(kv_pred["bytes"]),
                     "measured": float(pool.nbytes())},
    }
    hist = obs.metrics.find("serve_decode_step_ms",
                            **core.obs_metric_labels)
    if hist is not None and hist.count:
        out["decode_ms"] = {"predicted": float(predicted["ms"]),
                            "measured": float(hist.percentile(50))}
    for quantity, row in out.items():
        row["ratio"] = (row["measured"] / row["predicted"]
                        if row["predicted"] else 0.0)
        for stat, v in row.items():
            obs.metrics.gauge("costmodel_residual", quantity=quantity,
                              stat=stat, **labels).set(v)
    return out


# ---------------------------------------------------------------------------
# persisted correction factor -> calibrated roofline constants
# ---------------------------------------------------------------------------

def fit_calibration(residuals: dict, *, model: str | None = None) -> dict:
    """Collapse a residual report into a persisted correction record.

    ``ms_factor`` is the measured/predicted decode-ms ratio (1.0 when the
    run recorded no decode steps): the single scalar the roofline is off
    by on this host, which :func:`calibrated_hw` folds back in.
    """
    ms = residuals.get("decode_ms", {})
    return {"ms_factor": float(ms.get("ratio", 1.0)) or 1.0,
            "predicted_ms": ms.get("predicted"),
            "measured_ms": ms.get("measured"),
            "weight_bytes_ratio": residuals.get(
                "weight_bytes", {}).get("ratio"),
            "kv_bytes_ratio": residuals.get("kv_bytes", {}).get("ratio"),
            "model": model}


def save_calibration(path: str, calib: dict):
    with open(path, "w") as f:
        json.dump(calib, f, indent=1)


def load_calibration(path: str) -> dict:
    with open(path) as f:
        calib = json.load(f)
    if "ms_factor" not in calib:
        raise ValueError(f"{path}: not a calibration file (no ms_factor)")
    return calib


def calibrated_hw(calib, base: HW | None = None) -> HW:
    """Roofline constants corrected by a fitted ``ms_factor``.

    Scaling both peak FLOPs and HBM bandwidth by ``1/f`` scales every
    predicted ms by exactly ``f`` whichever side of the roofline a layer
    sits on, so re-planning under ``--budget-ms`` constrains against the
    *measured* host speed.
    """
    f = calib["ms_factor"] if isinstance(calib, dict) else float(calib)
    if f <= 0:
        raise ValueError(f"ms_factor must be positive, got {f}")
    base = base or HW()
    return dataclasses.replace(base, peak_flops=base.peak_flops / f,
                               hbm_bw=base.hbm_bw / f)
