"""Validate ``--trace-out`` / ``--metrics-out`` artifacts.

    python -m repro.obs.check trace.json metrics.json \
        [--spec] [--numerics] [--profile] [--slo report.json]

Asserts the trace is Chrome-trace-valid (``traceEvents`` list; every
event carries ``name``/``ph``/``ts``/``pid``/``tid``; complete events
carry a non-negative ``dur``; per-lane spans nest properly) and contains
the serving lifecycle spans, and that the metrics snapshot carries the
standard serving histograms with non-zero counts.  ``--spec`` also
requires the speculative ``draft``/``verify`` spans; ``--numerics``
requires the quality-plane metrics (shadow-divergence KL histogram +
agreement gauge, per-layer KV dequant-error gauges, cost-model residual
gauges — obs/numerics.py, obs/residuals.py); ``--profile`` requires the
perf-attribution plane (every ``serve_phase_ms`` phase recorded, the
``serve_mfu``/``serve_hbm_util`` gauges in ``(0, 1]``, the ``profile``/
``phase:*`` spans, and a plausible phase-sum vs decode-step p50 —
obs/profile.py); ``--slo report.json`` additionally validates a saved
SLO report's structure (every spec objective present, budgets in
[0, 1], burn rates finite, breach episodes well-formed — delegating to
``repro.obs.slo.validate_report``; unlike ``python -m repro.obs.slo``
this does NOT fail on a breach, only on malformed reports).  Exit code
0 on success, 1 with a diagnostic on
invalid/malformed artifacts, 2 on usage errors.  This is the ``make
obs-smoke`` / ``make numerics-smoke`` / ``make perf-smoke`` gate, and a
quick sanity check for any saved run.
"""
from __future__ import annotations

import json
import sys

REQUIRED_SPANS = ("prefill", "decode", "queued", "request")
SPEC_SPANS = ("draft", "verify")
REQUIRED_HISTOGRAMS = ("serve_ttft_ms", "serve_itl_ms",
                       "serve_queue_wait_ms", "serve_prefill_ms",
                       "serve_decode_step_ms")
NUMERICS_HISTOGRAMS = ("quality_shadow_kl",)
NUMERICS_GAUGE_PREFIXES = ("quality_shadow_top1_agree", "kv_dequant_mse",
                           "kv_dequant_maxabs", "costmodel_residual")
PROFILE_PHASES = ("gather", "dequant", "attention", "lm_head", "other")
# a fused-attention engine (EngineConfig.fused_attention) runs gather+
# dequant+attention as ONE kernel, so its honest decomposition is a
# single fused_attention phase — check.py accepts either breakdown,
# keyed on which phases the profiler actually recorded
FUSED_PROFILE_PHASES = ("fused_attention", "lm_head", "other")
PROFILE_GAUGES = ("serve_mfu", "serve_hbm_util")
# phase replays run in standalone jits with per-call dispatch overhead;
# on a tiny smoke model that overhead dwarfs the compute, so the phase
# sum is only required to land within a loose ratio band of the engine's
# fused decode-step p50 (attribution sanity, not a timing identity)
PHASE_SUM_BAND = (0.02, 50.0)


def check_trace(trace: dict, *, spec: bool = False) -> dict:
    """Validate a Chrome trace object; returns {span name: count}."""
    events = trace.get("traceEvents")
    assert isinstance(events, list) and events, "traceEvents missing/empty"
    names: dict[str, int] = {}
    lanes: dict[int, list] = {}
    for ev in events:
        for field in ("name", "ph", "pid", "tid"):
            assert field in ev, f"event missing {field!r}: {ev}"
        if ev["ph"] == "M":
            continue
        assert "ts" in ev, f"event missing ts: {ev}"
        if ev["ph"] == "X":
            assert "dur" in ev and ev["dur"] >= 0, f"bad dur: {ev}"
            lanes.setdefault(ev["tid"], []).append(ev)
        names[ev["name"]] = names.get(ev["name"], 0) + 1
    for tid, evs in lanes.items():
        # spans on one lane must nest: sorted by ts, each span either
        # starts after the previous open span ends or sits inside it
        open_spans: list = []
        for ev in sorted(evs, key=lambda e: (e["ts"], -e["dur"])):
            while open_spans and \
                    ev["ts"] >= open_spans[-1]["ts"] + open_spans[-1]["dur"]:
                open_spans.pop()
            if open_spans:
                parent = open_spans[-1]
                assert (ev["ts"] + ev["dur"]
                        <= parent["ts"] + parent["dur"] + 1e-6), \
                    f"span {ev['name']!r} overlaps {parent['name']!r} " \
                    f"without nesting (tid {tid})"
            open_spans.append(ev)
    want = REQUIRED_SPANS + (SPEC_SPANS if spec else ())
    missing = [n for n in want if not names.get(n)]
    assert not missing, f"trace lacks spans {missing}; has {sorted(names)}"
    return names


def check_metrics(snap: dict, *, spec: bool = False) -> list[str]:
    """Validate a metrics snapshot; returns the histogram keys found."""
    hists = snap.get("histograms")
    assert isinstance(hists, dict) and hists, "histograms missing/empty"
    found = []
    want = REQUIRED_HISTOGRAMS + (("serve_draft_ms", "serve_verify_ms")
                                  if spec else ())
    for name in want:
        keys = [k for k in hists if k == name or k.startswith(name + "{")]
        assert keys, f"metrics lack histogram {name!r}; " \
                     f"has {sorted(hists)}"
        for k in keys:
            assert hists[k].get("count", 0) > 0, f"{k} recorded nothing"
            assert "p50" in hists[k] and "p95" in hists[k], \
                f"{k} lacks percentiles"
        found.extend(keys)
    return found


def check_numerics(snap: dict) -> list[str]:
    """Validate the quality-plane metrics (``--numerics``); returns the
    metric keys found."""
    hists = snap.get("histograms", {})
    gauges = snap.get("gauges", {})
    found = []
    for name in NUMERICS_HISTOGRAMS:
        keys = [k for k in hists if k == name or k.startswith(name + "{")]
        assert keys, f"metrics lack histogram {name!r}; has {sorted(hists)}"
        for k in keys:
            assert hists[k].get("count", 0) > 0, f"{k} recorded nothing"
        found.extend(keys)
    for name in NUMERICS_GAUGE_PREFIXES:
        keys = [k for k in gauges if k == name or k.startswith(name + "{")]
        assert keys, f"metrics lack gauge {name!r}*; has {sorted(gauges)}"
        found.extend(keys)
    return found


def check_profile(trace: dict, snap: dict, *, spec: bool = False
                  ) -> list[str]:
    """Validate the perf-attribution plane (``--profile``); returns the
    metric keys found.

    Requires every phase of the recorded decomposition (the XLA
    gather/dequant/attention triplet, or :data:`FUSED_PROFILE_PHASES`
    when the profiler recorded a ``fused_attention`` phase) in the
    ``serve_phase_ms`` histograms with non-zero counts, the utilization
    gauges in ``(0, 1]``, the ``profile`` + ``phase:*`` spans in the
    trace, and the phase-time sum within :data:`PHASE_SUM_BAND` of the
    engine's decode-step p50 (verify p50 under ``--spec``).
    """
    hists = snap.get("histograms", {})
    gauges = snap.get("gauges", {})
    found = []
    phase_sum = 0.0
    fused = any(k.startswith("serve_phase_ms{")
                and 'phase="fused_attention"' in k for k in hists)
    for phase in FUSED_PROFILE_PHASES if fused else PROFILE_PHASES:
        frag = f'phase="{phase}"'
        keys = [k for k in hists
                if k.startswith("serve_phase_ms{") and frag in k]
        assert keys, f"metrics lack serve_phase_ms phase {phase!r}; " \
                     f"has {sorted(hists)}"
        for k in keys:
            assert hists[k].get("count", 0) > 0, f"{k} recorded nothing"
            phase_sum += hists[k]["p50"]
        found.extend(keys)
    for name in PROFILE_GAUGES:
        keys = [k for k in gauges if k == name or k.startswith(name + "{")]
        assert keys, f"metrics lack gauge {name!r}*; has {sorted(gauges)}"
        for k in keys:
            assert 0.0 < gauges[k] <= 1.0, \
                f"{k} = {gauges[k]} outside (0, 1]"
        found.extend(keys)
    names = {ev.get("name") for ev in trace.get("traceEvents", ())}
    assert "profile" in names, f"trace lacks 'profile' span; has " \
                               f"{sorted(n for n in names if n)}"
    assert any(isinstance(n, str) and n.startswith("phase:")
               for n in names), "trace lacks phase:* spans"
    step = "serve_verify_ms" if spec else "serve_decode_step_ms"
    step_keys = [k for k in hists
                 if (k == step or k.startswith(step + "{"))
                 and hists[k].get("count", 0)]
    assert step_keys, f"metrics lack {step!r} to compare phases against"
    step_p50 = max(hists[k]["p50"] for k in step_keys)
    lo, hi = PHASE_SUM_BAND
    assert lo * step_p50 <= phase_sum <= hi * step_p50, \
        f"phase p50 sum {phase_sum:.3f} ms outside [{lo}, {hi}]x of " \
        f"{step} p50 {step_p50:.3f} ms — attribution is implausible"
    return found


def check_slo(report: dict) -> list[str]:
    """Validate a saved SLO report's structure (``--slo``); returns the
    ``tenant/objective`` keys found.  Structure only — gating on breach
    state is ``python -m repro.obs.slo``'s job."""
    from repro.obs.slo import validate_report
    return validate_report(report)


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    usage = ("usage: python -m repro.obs.check trace.json metrics.json "
             "[--spec] [--numerics] [--profile] [--slo report.json]")
    spec = "--spec" in argv
    numerics = "--numerics" in argv
    profile = "--profile" in argv
    argv = [a for a in argv if a not in ("--spec", "--numerics",
                                         "--profile")]
    slo_path = None
    if "--slo" in argv:
        i = argv.index("--slo")
        if i + 1 >= len(argv):
            print(usage, file=sys.stderr)
            return 2
        slo_path = argv[i + 1]
        del argv[i:i + 2]
    if len(argv) != 2:
        print(usage, file=sys.stderr)
        return 2
    trace_path, metrics_path = argv
    try:
        with open(trace_path) as f:
            trace = json.load(f)
        with open(metrics_path) as f:
            snap = json.load(f)
        names = check_trace(trace, spec=spec)
        hists = check_metrics(snap, spec=spec)
        quality = check_numerics(snap) if numerics else []
        perf = check_profile(trace, snap, spec=spec) if profile else []
        slo = []
        if slo_path is not None:
            with open(slo_path) as f:
                slo = check_slo(json.load(f))
    except (AssertionError, json.JSONDecodeError, OSError) as e:
        print(f"check failed: {e}", file=sys.stderr)
        return 1
    print(f"{trace_path}: {sum(names.values())} events, spans "
          f"{ {n: names[n] for n in sorted(names)} }")
    print(f"{metrics_path}: {len(hists)} serving histograms ok")
    if numerics:
        print(f"{metrics_path}: {len(quality)} quality-plane metrics ok")
    if profile:
        print(f"{metrics_path}: {len(perf)} perf-plane metrics ok")
    if slo_path is not None:
        print(f"{slo_path}: {len(slo)} SLO objectives structurally ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
