"""Mixed-precision planner CLI: profile -> search -> plan.json.

    python -m repro.launch.plan --arch llama3.2-1b \
        --schemes lq8w,lq4w,lq2w --budget-mb 0.25 --out plan.json

Profiles per-layer sensitivity of the smoke config on the synthetic LM
stream, prices every (layer, scheme) cell with the roofline cost model,
runs the greedy Pareto search under the byte (``--budget-mb``) or modeled
latency (``--budget-ms``) budget, and emits a serializable QuantPlan that
``repro.launch.serve --plan plan.json`` deploys directly.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import transformer
from repro.plan import (candidate_costs, greedy_search, plan_cost,
                        profile_sensitivity, uniform_result)
from repro.plan.plan import candidates_for


def make_calib_stream(cfg, *, n_batches: int, batch: int, seq_len: int,
                      seed: int = 0) -> list:
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=seq_len, global_batch=batch,
                                  seed=seed))
    return [{"tokens": data.batch(i)["tokens"]} for i in range(n_batches)]


def build_plan(cfg, params, scheme_names, *, budget_mb=None, budget_ms=None,
               metric: str = "kl", batches=None, verbose: bool = True):
    """profile -> price -> search.  Returns (plan, search_result, profile)."""
    if (budget_mb is None) == (budget_ms is None):
        raise ValueError("pass exactly one of budget_mb / budget_ms")
    cands = candidates_for(cfg, scheme_names)
    prof = profile_sensitivity(params, cfg, batches, cands)
    costs = {l: {s: c.to_dict() for s, c in row.items()}
             for l, row in candidate_costs(cfg, cands).items()}
    cost_key = "bytes" if budget_ms is None else "ms"
    budget = budget_mb * 2**20 if budget_ms is None else budget_ms
    result = greedy_search(prof.losses, costs, budget=budget,
                           cost_key=cost_key, loss_key=metric)
    meta = {"arch": cfg.name, "budget": budget, "budget_key": cost_key,
            "metric": metric, "schemes": ",".join(scheme_names),
            "feasible": result.feasible}
    plan = result.plan(cands, meta=meta)

    if verbose:
        print(f"== planned {cfg.name}: budget {budget:.4g} {cost_key}, "
              f"metric {metric} ==")
        for layer in costs:
            s = result.assignment[layer]
            print(f"  {layer:>10} -> {s:>6}  "
                  f"bytes={costs[layer][s]['bytes']:>12,.0f}  "
                  f"{metric}={prof.losses[layer][s][metric]:.3e}")
        print(f"  total: cost={result.cost:.4g} {cost_key} "
              f"loss={result.loss:.3e} feasible={result.feasible}")
        for s in scheme_names:
            u = uniform_result(s, prof.losses, costs,
                               cost_key=cost_key, loss_key=metric)
            print(f"  uniform {s:>6}: cost={u.cost:.4g} loss={u.loss:.3e}")
    return plan, result, prof


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.names()))
    ap.add_argument("--schemes", default="lq8w,lq4w,lq2w",
                    help="comma-separated candidate schemes")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="weight-byte budget (wire-format MiB)")
    ap.add_argument("--budget-ms", type=float, default=None,
                    help="modeled per-token decode latency budget")
    ap.add_argument("--metric", default="kl", choices=("kl", "mse"))
    ap.add_argument("--batches", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--out", default="plan.json")
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch)
    if cfg.n_enc_layers:
        raise SystemExit(f"{args.arch}: planning covers decoder-only models")
    params = transformer.init_params(cfg, jax.random.key(0))
    stream = make_calib_stream(cfg, n_batches=args.batches,
                               batch=args.batch_size, seq_len=args.seq_len)
    plan, result, _ = build_plan(
        cfg, params, [s.strip() for s in args.schemes.split(",")],
        budget_mb=args.budget_mb, budget_ms=args.budget_ms,
        metric=args.metric, batches=stream)
    print(f"plan totals: {plan_cost(cfg, plan.resolve(cfg))['mb']:.4f} MiB")
    plan.save(args.out)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
