"""Mixed-precision planner CLI: profile -> search -> plan.json.

    python -m repro.launch.plan --arch llama3.2-1b \
        --schemes lq8w,lq4w,lq2w --budget-mb 0.25 --out plan.json

Profiles per-layer sensitivity of the smoke config on the synthetic LM
stream, prices every (layer, scheme) cell with the roofline cost model,
runs the greedy Pareto search under the byte (``--budget-mb``) or modeled
latency (``--budget-ms``) budget, and emits a serializable QuantPlan that
``repro.launch.serve --plan plan.json`` deploys directly.

``--kv 8,4,2`` (optionally with ``fp``) extends the search to the joint
weight x KV-cache space: each layer's cache bitwidth is profiled
(fake-quant of its K/V stream), priced at ``--kv-tokens`` of context in
the exact wire format, and folded into the same byte budget, so the
emitted plan carries a per-layer ``kv_bits`` map the paged serve pool
deploys as heterogeneous page geometry.  Pass the serve cell's geometry
(``--n-pages``/``--page-size``) instead of ``--kv-tokens`` to price the
cache at the pool's real capacity — the plan's kv bytes then equal
``pool_nbytes`` exactly, one currency for plan and pool budgets.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import transformer
from repro.plan import (candidate_costs, fit_kv_group, greedy_search,
                        joint_space, kv_candidate_costs, plan_cost,
                        plan_kv_cost, profile_kv_sensitivity,
                        profile_sensitivity, uniform_result)
from repro.plan.plan import candidates_for


def make_calib_stream(cfg, *, n_batches: int, batch: int, seq_len: int,
                      seed: int = 0) -> list:
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=seq_len, global_batch=batch,
                                  seed=seed))
    return [{"tokens": data.batch(i)["tokens"]} for i in range(n_batches)]


def load_kv_measurements(path: str) -> dict:
    """``{layer_index: (measured_mse, deployed_bits)}`` from a metrics
    snapshot (``repro.launch.serve --numerics --metrics-out``).

    Reads the ``kv_dequant_mse{layer="layerN"}`` gauges the quality
    plane's KV probe records from the *live pool during decode* — the
    accumulated wire error of real traffic, not the one-shot forward
    fake-quant proxy — plus ``kv_dequant_bits`` saying which wire format
    produced each number.
    """
    import json
    import re

    with open(path) as f:
        gauges = json.load(f)["gauges"]
    pat = re.compile(r'^kv_dequant_(mse|bits)\{layer="layer(\d+)"\}$')
    mse: dict = {}
    bits: dict = {}
    for key, value in gauges.items():
        m = pat.match(key)
        if m is None:
            continue
        (mse if m.group(1) == "mse" else bits)[int(m.group(2))] = value
    return {i: (mse[i], int(bits.get(i, 0))) for i in sorted(mse)}


def apply_kv_measurements(kv_sens: dict, measured: dict,
                          *, verbose: bool = True) -> dict:
    """Re-anchor the forward-proxy KV sensitivities on decode-time error.

    The proxy ranks layers by one-shot fake-quant damage; the serve-time
    probe measures the error each layer's cache actually accumulates
    over decode (scatter round trips, rope'd keys, real occupancy).  For
    each measured layer the whole candidate row (all non-fp cells, both
    ``kl`` and ``mse``) is scaled by ``measured / proxy`` at the
    *deployed* format, preserving the proxy's relative bitwidth curve
    while moving its absolute level to where decode traffic says it is.
    Layers that served an fp wire (bits 0), have no searchable cache, or
    a zero proxy cell are left on the proxy.
    """
    from repro.plan.costmodel import kv_label
    from repro.plan.plan import layer_name

    out = {layer: {lab: dict(cell) for lab, cell in row.items()}
           for layer, row in kv_sens.items()}
    for i, (ms, bits) in measured.items():
        layer = layer_name(i)
        row = out.get(layer)
        if row is None or not bits:
            continue
        proxy = row.get(kv_label(bits), {}).get("mse", 0.0)
        if proxy <= 0.0 or ms <= 0.0:
            continue
        factor = ms / proxy
        for lab, cell in row.items():
            for k in ("kl", "mse"):
                if cell.get(k):
                    cell[k] *= factor
        if verbose:
            print(f"  kv sensitivity {layer}: measured mse {ms:.3e} at "
                  f"{kv_label(bits)} vs proxy {proxy:.3e} -> x{factor:.3f}")
    return out


def build_plan(cfg, params, scheme_names, *, budget_mb=None, budget_ms=None,
               metric: str = "kl", batches=None, verbose: bool = True,
               kv_bits=None, kv_group: int = 64, kv_tokens: int = 256,
               hw=None, kv_measured: dict | None = None):
    """profile -> price -> search.  Returns (plan, search_result, profile).

    ``kv_bits`` (e.g. ``[8, 4, 2]``, ``None`` entries meaning fp) switches
    to the joint weight x cache search: sensitivities and byte costs of
    both axes merge into one per-layer grid (``plan.search.joint_space``)
    and the plan comes back with a per-layer kv map.  Joint search prices
    the cache at ``kv_tokens`` tokens of context, and needs the byte
    budget (``budget_mb``).

    ``hw`` overrides the roofline constants every candidate is priced
    with — pass ``repro.obs.calibrated_hw(load_calibration(path))`` to
    search against *measured* host speed (``--budget-ms`` then constrains
    calibrated milliseconds, not the stock roofline's).

    ``kv_measured`` (:func:`load_kv_measurements` output) re-anchors the
    kv sensitivities on serve-time dequant error before the joint search
    — see :func:`apply_kv_measurements`.
    """
    if (budget_mb is None) == (budget_ms is None):
        raise ValueError("pass exactly one of budget_mb / budget_ms")
    cands = candidates_for(cfg, scheme_names)
    prof = profile_sensitivity(params, cfg, batches, cands)
    costs = {l: {s: c.to_dict() for s, c in row.items()}
             for l, row in candidate_costs(cfg, cands, hw).items()}
    cost_key = "bytes" if budget_ms is None else "ms"
    budget = budget_mb * 2**20 if budget_ms is None else budget_ms
    if kv_bits is not None:
        if budget_mb is None:
            raise ValueError("joint kv search prices cache bytes — use "
                             "budget_mb, not budget_ms")
        kvg = fit_kv_group(kv_group, cfg.head_dim)
        kv_sens = profile_kv_sensitivity(params, cfg, batches, kv_bits,
                                         kv_group=kvg)
        if kv_measured:
            kv_sens = apply_kv_measurements(kv_sens, kv_measured,
                                            verbose=verbose)
        kv_costs = kv_candidate_costs(cfg, kv_bits, kv_group=kvg,
                                      tokens=kv_tokens)
        sens = joint_space(prof.losses, kv_sens)
        costs = joint_space(costs, kv_costs)
        result = greedy_search(sens, costs, budget=budget,
                               cost_key=cost_key, loss_key=metric)
        meta = {"arch": cfg.name, "budget": budget, "budget_key": cost_key,
                "metric": metric, "schemes": ",".join(scheme_names),
                "kv_bits": ",".join("fp" if b is None else str(b)
                                    for b in kv_bits),
                "kv_tokens": kv_tokens, "feasible": result.feasible}
        plan = result.joint_plan(cands, kv_group=kvg, meta=meta)
        if verbose:
            print(f"== planned {cfg.name} (joint weight x kv): budget "
                  f"{budget:.4g} {cost_key}, metric {metric} ==")
            for layer in costs:
                s = result.assignment[layer]
                print(f"  {layer:>10} -> {s:>12}  "
                      f"bytes={costs[layer][s]['bytes']:>12,.0f}  "
                      f"{metric}={sens[layer][s][metric]:.3e}")
            kv_resolved = plan.resolve_kv(cfg)
            kvcost = plan_kv_cost(cfg, kv_resolved, kv_group=kvg,
                                  tokens=kv_tokens)
            print(f"  total: cost={result.cost:.4g} {cost_key} "
                  f"loss={result.loss:.3e} feasible={result.feasible}; "
                  f"cache {kvcost['bytes_per_token']:.0f} B/token")
        return plan, result, prof
    result = greedy_search(prof.losses, costs, budget=budget,
                           cost_key=cost_key, loss_key=metric)
    meta = {"arch": cfg.name, "budget": budget, "budget_key": cost_key,
            "metric": metric, "schemes": ",".join(scheme_names),
            "feasible": result.feasible}
    plan = result.plan(cands, meta=meta)

    if verbose:
        print(f"== planned {cfg.name}: budget {budget:.4g} {cost_key}, "
              f"metric {metric} ==")
        for layer in costs:
            s = result.assignment[layer]
            print(f"  {layer:>10} -> {s:>6}  "
                  f"bytes={costs[layer][s]['bytes']:>12,.0f}  "
                  f"{metric}={prof.losses[layer][s][metric]:.3e}")
        print(f"  total: cost={result.cost:.4g} {cost_key} "
              f"loss={result.loss:.3e} feasible={result.feasible}")
        for s in scheme_names:
            u = uniform_result(s, prof.losses, costs,
                               cost_key=cost_key, loss_key=metric)
            print(f"  uniform {s:>6}: cost={u.cost:.4g} loss={u.loss:.3e}")
    return plan, result, prof


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.names()))
    ap.add_argument("--schemes", default="lq8w,lq4w,lq2w",
                    help="comma-separated candidate schemes")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="weight-byte budget (wire-format MiB)")
    ap.add_argument("--budget-ms", type=float, default=None,
                    help="modeled per-token decode latency budget")
    ap.add_argument("--metric", default="kl", choices=("kl", "mse"))
    ap.add_argument("--batches", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--kv", default=None, metavar="BITS",
                    help="comma-separated cache bitwidth candidates "
                         "(e.g. '8,4,2' or 'fp,8,2'): joint weight x kv "
                         "search; the plan gains a per-layer kv_bits map")
    ap.add_argument("--kv-group", type=int, default=64,
                    help="cache local-region size (clamped to head_dim)")
    ap.add_argument("--kv-tokens", type=int, default=None,
                    help="context tokens the cache budget is priced at "
                         "(default: the serve cell's real capacity "
                         "n_pages * page_size when --n-pages is given, "
                         "else 256)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="serve-cell page count (incl. scratch): prices "
                         "the kv budget at the pool's exact geometry, so "
                         "plan and pool budgets share one currency")
    ap.add_argument("--page-size", type=int, default=16,
                    help="serve-cell page size (with --n-pages)")
    ap.add_argument("--kv-sensitivity-from", default=None,
                    metavar="METRICS.json",
                    help="metrics snapshot from a --numerics serve run: "
                         "re-anchors the forward-proxy kv sensitivities "
                         "on the measured decode-time kv_dequant_mse "
                         "gauges (with --kv)")
    ap.add_argument("--calibration", default=None, metavar="CALIB.json",
                    help="cost-model correction from a measured run "
                         "(repro.launch.serve --calibration-out): prices "
                         "every candidate with the calibrated roofline")
    ap.add_argument("--out", default="plan.json")
    args = ap.parse_args(argv)

    hw = None
    if args.calibration is not None:
        from repro.obs import calibrated_hw, load_calibration
        calib = load_calibration(args.calibration)
        hw = calibrated_hw(calib)
        print(f"calibrated roofline: ms_factor={calib['ms_factor']:.3f} "
              f"({args.calibration})")

    kv_tokens = args.kv_tokens
    if kv_tokens is None:
        # context-aware kv budget: price the cache at the serve cell's
        # real capacity so the plan's kv bytes equal pool_nbytes exactly
        kv_tokens = (args.n_pages * args.page_size
                     if args.n_pages is not None else 256)

    cfg = configs.smoke(args.arch)
    if cfg.n_enc_layers:
        raise SystemExit(f"{args.arch}: planning covers decoder-only models")
    params = transformer.init_params(cfg, jax.random.key(0))
    stream = make_calib_stream(cfg, n_batches=args.batches,
                               batch=args.batch_size, seq_len=args.seq_len)
    kv_bits = None
    if args.kv is not None:
        kv_bits = [None if s.strip() in ("fp", "none") else int(s)
                   for s in args.kv.split(",")]
    kv_measured = None
    if args.kv_sensitivity_from is not None:
        if kv_bits is None:
            ap.error("--kv-sensitivity-from re-anchors the joint kv "
                     "search; use it with --kv")
        kv_measured = load_kv_measurements(args.kv_sensitivity_from)
        if not kv_measured:
            print(f"warning: no kv_dequant_mse gauges in "
                  f"{args.kv_sensitivity_from} (run serve with "
                  f"--numerics --kv-bits/--plan); keeping the proxy")
        else:
            print(f"kv sensitivity re-anchored on {len(kv_measured)} "
                  f"measured layers ({args.kv_sensitivity_from})")
    plan, result, _ = build_plan(
        cfg, params, [s.strip() for s in args.schemes.split(",")],
        budget_mb=args.budget_mb, budget_ms=args.budget_ms,
        metric=args.metric, batches=stream,
        kv_bits=kv_bits, kv_group=args.kv_group, kv_tokens=kv_tokens,
        hw=hw, kv_measured=kv_measured)
    print(f"plan totals: {plan_cost(cfg, plan.resolve(cfg), hw)['mb']:.4f} "
          f"MiB")
    plan.save(args.out)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
