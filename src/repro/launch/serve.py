"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the batched engine on the reduced config, optionally with the
paper's quantization applied to weights (--scheme lq4w), activations
(--a-bits) and the KV cache (--kv-bits), and reports tokens/s plus the
cache-bytes saving.

``--continuous N`` switches to the continuous-batching serve layer
(serve/server.py): N requests with staggered arrivals are scheduled over
the paged quantized KV pool, reporting throughput and pool occupancy.

``--fleet fleet.json`` hosts a multi-tenant fleet (repro.fleet): every
manifest tenant gets its own per-plan engine + pool behind one router and
one ``--budget-mb`` host budget; a staggered workload is routed across
tenants and per-tenant telemetry (tok/s, occupancy, rejections) is
reported.  The manifest carries the arch, so ``--arch`` is optional.

``--trace-out trace.json`` / ``--metrics-out metrics.json`` attach a
:class:`repro.obs.Observability` *after* jit warmup and write a
Chrome/Perfetto trace (open at ``ui.perfetto.dev``) and a metrics
snapshot (TTFT/ITL/queue-wait p50/p95, counters).  A ``.prom`` metrics
path emits Prometheus text format instead of JSON.

The quality plane (``repro.obs`` numerics/residuals/flight/export) rides
the same switch: ``--numerics`` samples shadow-divergence + KV
dequant-error probes every ``--numerics-every`` decode steps and prints
cost-model residuals at the end (``--calibration-out`` persists the
fitted roofline correction ``repro.launch.plan --calibration`` consumes);
``--serve-metrics PORT`` serves live ``/metrics`` (Prometheus text),
``/healthz`` and ``/snapshot.json`` over stdlib HTTP (port 0 picks an
ephemeral port); ``--flight-out`` arms a flight recorder that dumps the
recent span/event ring on anomalies (preemption storm, pool alloc
failure, drift alarm, SLO breach) and saves it at exit.

The SLO plane (``repro.obs.slo`` / ``repro.obs.health``) judges the
measurements against targets: ``--slo slo.json`` loads an
:class:`repro.obs.SLOSpec` (``--fleet`` manifests may carry an ``slo:``
section instead), polls an :class:`repro.obs.SLOTracker` plus a
:class:`repro.obs.HealthMonitor` every decode step, exposes
``/slo.json`` on the live endpoint, and ``--slo-report out.json``
persists the final per-tenant budget/burn/episode report —
``python -m repro.obs.slo out.json`` gates on it (exit 1 on breach).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer
from repro.obs import Observability, Stopwatch
from repro.serve import (Engine, EngineConfig, PagedConfig, RequestParams,
                         Server)


def _make_obs(args) -> Observability | None:
    """One Observability per run when any instrumentation was requested."""
    if (args.trace_out or args.metrics_out or args.numerics
            or args.flight_out or args.calibration_out or args.profile
            or args.slo or args.slo_report
            or args.serve_metrics is not None):
        return Observability()
    return None


def _report_utilization(obs, cfg, engine, pool, args, *, labels=None):
    """MFU / HBM-utilization gauges against the *measured* roof.

    Residuals are recorded first so the roofline constants can be
    calibrated to this host before the utilization division — a stock
    roof on a laptop would report a meaninglessly small MFU.
    """
    from repro.obs.profile import record_utilization
    from repro.obs.residuals import (calibrated_hw, fit_calibration,
                                     record_residuals)
    res = record_residuals(obs, cfg, engine, pool, labels=labels)
    hw = calibrated_hw(fit_calibration(res, model=cfg.name))
    u = record_utilization(obs, cfg, engine, pool, hw=hw, labels=labels)
    tag = f" [{labels}]" if labels else ""
    if u is None:
        print(f"utilization{tag}: no decode-step latency recorded")
        return None
    print(f"utilization{tag}: mfu {u['mfu']:.4f}, hbm {u['hbm_util']:.4f} "
          f"of the calibrated roof ({u['flops_per_step']:,.0f} FLOPs, "
          f"{u['bytes_per_step']:,.0f} B per {u['step_ms']:.3f} ms step)")
    return u


def _attach_extras(obs, args):
    """Flight recorder + live /metrics endpoint (both obs-taps; neither
    touches the engines).  Returns (flight, metrics_server)."""
    flight = msrv = None
    if args.flight_out:
        from repro.obs import FlightRecorder
        flight = obs.attach_flight(FlightRecorder(out=args.flight_out))
    if args.serve_metrics is not None:
        from repro.obs import MetricsServer
        msrv = MetricsServer(obs, port=args.serve_metrics)
        print(f"metrics endpoint: {msrv.url}/metrics (+ /healthz, "
              f"/snapshot.json)")
    return flight, msrv


def _finish_extras(flight, msrv, args):
    """Scrape the live endpoint once (proves it serves during the run),
    then save the flight ring."""
    if msrv is not None:
        import urllib.request
        with urllib.request.urlopen(f"{msrv.url}/metrics") as r:
            text = r.read().decode()
        print(f"/metrics live scrape: {len(text.splitlines())} lines of "
              f"Prometheus text")
        msrv.close()
    if flight is not None:
        flight.save(args.flight_out)
        print(f"wrote {args.flight_out} ({len(flight.ring)} ring events, "
              f"{len(flight.dumps)} anomaly dumps)")


def _load_slo_spec(args, manifest=None):
    """The run's SLOSpec: ``--slo`` file, else the manifest's ``slo:``
    section (fleet mode).  None when neither declares objectives."""
    if args.slo:
        from repro.obs.slo import SLOSpec
        return SLOSpec.load(args.slo)
    return manifest.slo if manifest is not None else None


def _report_slo(tracker, health, args):
    """Print the judgment summary; persist ``--slo-report`` (with the
    health snapshot riding along under ``"health"``)."""
    import json

    rep = tracker.report()
    if health is not None:
        rep["health"] = health.snapshot()
    for tid, objectives in sorted(rep["tenants"].items()):
        for objective, row in sorted(objectives.items()):
            print(f"slo [{tid}] {objective}: {row['state']}, budget "
                  f"{row['budget_remaining']:.3f}, burn fast "
                  f"{row['burn_fast']:.2f} / slow {row['burn_slow']:.2f}")
    print(f"slo: worst state {rep['worst_state']} over {rep['steps']} "
          f"steps ({tracker.suppressed_events} suppressed events)")
    if health is not None:
        for tid, row in sorted(health.snapshot()["tenants"].items()):
            print(f"health [{tid}]: {row['health']:.2f} "
                  f"({row.get('attention_mode', '?')})")
    if args.slo_report:
        with open(args.slo_report, "w") as f:
            json.dump(rep, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.slo_report} (gate with "
              f"python -m repro.obs.slo {args.slo_report})")


def _report_residuals(obs, cfg, engine, pool, args, *, labels=None):
    """Cost-model residuals (+ optional persisted calibration factor)."""
    from repro.obs.residuals import (fit_calibration, record_residuals,
                                     save_calibration)
    res = record_residuals(obs, cfg, engine, pool, labels=labels)
    tag = f" [{labels}]" if labels else ""
    for q, row in res.items():
        print(f"costmodel residual{tag} {q}: predicted "
              f"{row['predicted']:.5g} measured {row['measured']:.5g} "
              f"ratio {row['ratio']:.3f}")
    if args.calibration_out:
        save_calibration(args.calibration_out,
                         fit_calibration(res, model=cfg.name))
        print(f"wrote {args.calibration_out}")
    return res


def _save_obs(obs, args):
    """Write the requested trace/metrics artifacts + a latency summary."""
    if obs is None:
        return
    for name in ("serve_ttft_ms", "serve_itl_ms"):
        parts = []
        for key, h in sorted(obs.metrics.histograms.items()):
            if h.count and (key == name or key.startswith(name + "{")):
                parts.append(f"{key} p50={h.percentile(50):.1f} "
                             f"p95={h.percentile(95):.1f} (n={h.count})")
        if parts:
            print("latency:", "; ".join(parts))
    if args.trace_out:
        obs.save_trace(args.trace_out)
        print(f"wrote {args.trace_out} ({len(obs.tracer.events)} events; "
              f"open at ui.perfetto.dev)")
    if args.metrics_out:
        obs.save_metrics(args.metrics_out)
        print(f"wrote {args.metrics_out}")


def _continuous(cfg, params, ecfg, args):
    """Staggered-arrival continuous batching over the paged pool."""
    import dataclasses
    want = args.prompt_len + args.steps + 8
    mc = -(-want // args.page_size) * args.page_size
    ecfg = dataclasses.replace(ecfg, max_len=max(ecfg.max_len, mc))
    pcfg = PagedConfig(max_slots=args.max_slots, page_size=args.page_size,
                       n_pages=args.n_pages, max_context=mc)
    engine = None
    if args.spec_plan is not None:
        from repro.plan import QuantPlan
        from repro.spec import SpeculativeEngine
        draft = QuantPlan.load(args.spec_plan)
        engine = SpeculativeEngine(cfg, params, ecfg, pcfg,
                                   draft_plan=draft, spec_k=args.spec_k)
        print(f"speculative: k={args.spec_k} draft={args.spec_plan} "
              f"shared {engine.shared_weight_bytes():,.0f} B of packed "
              f"leaves with the verifier")
    server = Server(cfg, params, ecfg, pcfg, engine=engine)
    rng = jax.random.key(2)
    warm = jax.random.randint(jax.random.fold_in(rng, args.continuous),
                              (args.prompt_len,), 0, cfg.vocab_size)
    server.submit(warm.tolist(), RequestParams(max_new_tokens=2))
    server.drain()                          # warm both jits off the clock
    obs = _make_obs(args)
    flight = msrv = quality = profiler = tracker = health = None
    if obs is not None:
        server.set_obs(obs)                 # compile time stays off the books
        flight, msrv = _attach_extras(obs, args)
        spec = _load_slo_spec(args)
        if args.slo_report and spec is None:
            raise SystemExit("--slo-report needs --slo in --continuous "
                             "mode (no manifest to carry targets)")
        if spec is not None:
            from repro.obs.health import HealthMonitor
            from repro.obs.slo import SLOTracker
            tracker = SLOTracker(spec, obs)
            health = HealthMonitor(obs, slo=tracker)
            # single-cell serves record under the "default" tenant label
            health.register("default", engine=server.engine,
                            pool=server.pool)
            if msrv is not None:
                msrv.attach_slo(tracker)
        if args.profile:
            from repro.obs.profile import PhaseProfiler
            profiler = server.attach_profiler(PhaseProfiler(
                obs, cfg, server.engine,
                every_n_steps=args.profile_every))
        if args.numerics:
            from repro.core import schemes
            from repro.obs.numerics import (NumericsConfig, QualityMonitor,
                                            record_weight_wire_error)
            record_weight_wire_error(
                obs, cfg, params,
                ecfg.plan if ecfg.plan is not None
                else schemes.get(args.scheme))
            quality = server.attach_quality(QualityMonitor(
                obs, cfg, params, server.engine,
                ncfg=NumericsConfig(every_n_steps=args.numerics_every)))
    import contextlib

    from repro.obs.profile import xprof_capture
    capture = (xprof_capture(args.xprof_out) if args.xprof_out
               else contextlib.nullcontext())
    occ, sw = [], Stopwatch()
    rids = []

    def tick():                 # one judgment poll per decode step
        if tracker is not None:
            tracker.on_step()
            health.on_step()

    with capture:
        for i in range(args.continuous):
            prompt = jax.random.randint(jax.random.fold_in(rng, i),
                                        (args.prompt_len,), 0,
                                        cfg.vocab_size)
            rids.append(server.submit(prompt.tolist(), RequestParams(
                max_new_tokens=args.steps + 1)))
            for _ in range(args.arrival_every):  # staggered arrivals
                server.step()
                occ.append(server.pool.occupancy())
                tick()
        while server.has_work:
            server.step()
            occ.append(server.pool.occupancy())
            tick()
    dt = sw.elapsed()
    if args.xprof_out:
        print(f"wrote xprof capture under {args.xprof_out} (open in "
              f"TensorBoard / XProf)")
    toks = sum(len(server.output(r)) for r in rids)
    s = server.stats()
    print(f"continuous: {len(rids)} requests, {toks} tokens in {dt:.2f}s "
          f"-> {toks / dt:.1f} tok/s")
    print(f"pool: {server.pool.n_pages} pages x "
          f"{server.pool.page_nbytes():,} B, peak occupancy "
          f"{max(occ):.2f}, mean {sum(occ) / len(occ):.2f}")
    print(f"decode compilations: {s['decode_compilations']} "
          f"(1 == no per-step retrace)")
    if args.spec_plan is not None:
        sp = server.engine.spec_stats()
        print(f"speculative: acceptance {sp['acceptance_rate']:.3f}, "
              f"verifier steps/token {sp['verify_steps_per_token']:.3f} "
              f"(< 1.0 == decode speedup), rejected "
              f"{server.scheduler.stats()['rejected_tokens']} drafts")
    if obs is not None and (args.numerics or args.calibration_out):
        _report_residuals(obs, cfg, server.engine, server.pool, args)
    if profiler is not None:
        probes = obs.metrics.counter("profile_probes_total").value
        print(f"profile: {probes} phase probes "
              f"(every {args.profile_every} steps)")
        _report_utilization(obs, cfg, server.engine, server.pool, args)
    if quality is not None:
        probes = obs.metrics.counter("quality_shadow_probes_total").value
        agree = obs.metrics.gauge("quality_shadow_top1_agree").value
        print(f"quality: {probes} shadow probes, top-1 agreement "
              f"{agree:.3f}")
    if tracker is not None:
        _report_slo(tracker, health, args)
    _save_obs(obs, args)
    _finish_extras(flight, msrv, args)
    print("sample:", server.output(rids[0])[:16])


def _fleet(args):
    """Multi-tenant fleet from a manifest: route, drain, report."""
    import json

    from repro.fleet import FleetAdmissionError, build_fleet, load_manifest

    manifest = load_manifest(args.fleet)
    cfg = configs.smoke(manifest.arch)
    params = transformer.init_params(cfg, jax.random.key(0))
    router = build_fleet(manifest, cfg, params, budget_mb=args.budget_mb,
                         backend="ref",
                         fused_attention=args.fused_attention)
    print(router.registry.describe())

    rng = jax.random.key(3)
    tenants = [t.tenant_id for t in router.registry]
    for i, tid in enumerate(tenants):          # warm both jits off the clock
        warm = jax.random.randint(jax.random.fold_in(rng, 1000 + i),
                                  (args.prompt_len,), 0, cfg.vocab_size)
        router.submit(tid, warm.tolist(), max_new_tokens=2)
    router.drain(max_steps=10_000)
    obs = _make_obs(args)
    flight = msrv = tracker = health = None
    if obs is not None:                        # attach after warmup so jit
        router.obs = obs                       # compiles stay off the books
    router.reset_telemetry()                   # drop warmup counters; re-wire
    if obs is not None:
        flight, msrv = _attach_extras(obs, args)
        spec = _load_slo_spec(args, manifest)
        if args.slo_report and spec is None:
            raise SystemExit("--slo-report needs --slo or a manifest "
                             "'slo:' section")
        if spec is not None:
            from repro.obs.health import attach_fleet_health
            from repro.obs.slo import SLOTracker
            tracker = SLOTracker(spec, obs, telemetry=router.telemetry)
            router.telemetry.slo = tracker
            health = attach_fleet_health(router, slo=tracker)
            if msrv is not None:
                msrv.attach_slo(tracker)
        if args.profile:
            from repro.obs.profile import attach_fleet_profilers
            attach_fleet_profilers(router, cfg,
                                   every_n_steps=args.profile_every)
        if args.numerics:
            from repro.obs.numerics import (NumericsConfig,
                                            attach_fleet_quality)
            attach_fleet_quality(router, params, ncfg=NumericsConfig(
                every_n_steps=args.numerics_every))

    def tick():                 # one judgment poll per decode step
        if tracker is not None:
            tracker.on_step()
            health.on_step()

    sw = Stopwatch()
    for i in range(args.fleet_requests):
        for j, tid in enumerate(tenants):
            prompt = jax.random.randint(jax.random.fold_in(rng, i * 64 + j),
                                        (args.prompt_len,), 0,
                                        cfg.vocab_size)
            try:
                router.submit(tid, prompt.tolist(),
                              max_new_tokens=args.steps + 1)
            except FleetAdmissionError as e:     # quota full: shed + go on
                print(f"[fleet] rejected: {e}")
            for _ in range(args.arrival_every):  # staggered arrivals
                router.step()
                tick()
    steps = 0
    while router.has_work:                     # drain, polling per step
        router.step()
        tick()
        steps += 1
        if steps > 100_000:
            raise RuntimeError("fleet drain exceeded max_steps")
    dt = sw.elapsed()

    stats = router.stats()
    toks = stats["aggregate"]["tokens"]
    print(f"fleet: {len(tenants)} tenants x {args.fleet_requests} requests, "
          f"{toks} tokens in {dt:.2f}s -> {toks / dt:.1f} tok/s aggregate")
    print(json.dumps(stats, indent=1))
    if args.stats_out:
        with open(args.stats_out, "w") as f:
            json.dump(stats, f, indent=1)
        print(f"wrote {args.stats_out}")
    if obs is not None and args.numerics:
        from repro.obs.residuals import record_residuals
        for t in router.registry:              # per-tenant residual gauges
            res = record_residuals(obs, cfg, t.engine, t.pool,
                                   labels={"tenant": t.tenant_id})
            row = res["weight_bytes"]
            print(f"costmodel residual [{t.tenant_id}] weight_bytes: "
                  f"ratio {row['ratio']:.3f}")
    if obs is not None and args.profile:
        probes = obs.metrics.counter("profile_probes_total").value
        print(f"profile: {probes} phase probes across "
              f"{len(tenants)} tenants")
        for t in router.registry:              # per-tenant MFU / HBM gauges
            _report_utilization(obs, cfg, t.engine, t.pool, args,
                                labels={"tenant": t.tenant_id})
    if tracker is not None:
        _report_slo(tracker, health, args)
    _save_obs(obs, args)
    _finish_extras(flight, msrv, args)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(configs.names()),
                    help="required unless --fleet supplies the arch")
    ap.add_argument("--scheme", default=None, help="weight scheme, e.g. lq4w")
    ap.add_argument("--plan", default=None, metavar="PLAN.json",
                    help="mixed-precision QuantPlan (repro.launch.plan "
                         "output); mutually exclusive with --scheme")
    ap.add_argument("--a-bits", type=int, default=None)
    ap.add_argument("--kv-bits", type=int, default=None)
    ap.add_argument("--kv-group", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", type=int, default=0, metavar="N",
                    help="serve N staggered requests via the paged "
                         "continuous-batching layer")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="decode steps between request arrivals")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=128)
    ap.add_argument("--fused-attention", action="store_true",
                    help="paged decode through the fused flash-decode "
                         "kernel (kernels/paged_attention.py): wire pages "
                         "stream through VMEM and dequantize in-register "
                         "(LUT path at kv bits <= 4) instead of gather -> "
                         "fp pool view -> attend; compiled on TPU, "
                         "interpret-mode elsewhere, with automatic "
                         "fallback to the XLA gather path when Pallas is "
                         "unavailable; --continuous and --fleet")
    ap.add_argument("--spec-plan", default=None, metavar="DRAFT.json",
                    help="speculative decoding (with --continuous): a "
                         "low-bit draft QuantPlan of the same checkpoint "
                         "proposes tokens the main engine verifies")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per verify cycle")
    ap.add_argument("--fleet", default=None, metavar="FLEET.json",
                    help="multi-tenant manifest (repro.fleet); per-plan "
                         "engines behind one host budget")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="shared host byte budget for --fleet (overrides "
                         "the manifest's budget_mb)")
    ap.add_argument("--fleet-requests", type=int, default=4,
                    help="requests submitted per tenant in --fleet mode")
    ap.add_argument("--stats-out", default=None,
                    help="write the fleet stats snapshot to this JSON file")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="write a Chrome/Perfetto trace of the run "
                         "(--continuous / --fleet); view at ui.perfetto.dev")
    ap.add_argument("--metrics-out", default=None, metavar="METRICS.json",
                    help="write the metrics snapshot (TTFT/ITL/queue-wait "
                         "histograms, counters); a .prom suffix selects "
                         "Prometheus text format")
    ap.add_argument("--numerics", action="store_true",
                    help="online quality probes: shadow-divergence KL / "
                         "top-1 agreement, per-layer KV dequant error, "
                         "weight wire error, spec-acceptance drift, plus "
                         "cost-model residuals at exit")
    ap.add_argument("--numerics-every", type=int, default=4, metavar="N",
                    help="decode steps between shadow probes (--numerics)")
    ap.add_argument("--serve-metrics", type=int, default=None,
                    metavar="PORT",
                    help="serve live /metrics (Prometheus text), /healthz "
                         "and /snapshot.json on 127.0.0.1:PORT during the "
                         "run (0 = ephemeral port)")
    ap.add_argument("--flight-out", default=None, metavar="FLIGHT.json",
                    help="arm the flight recorder: ring of recent "
                         "spans/events, auto-dumped on anomalies "
                         "(preemption storm / pool alloc failure / drift "
                         "alarm / SLO breach) and saved here at exit")
    ap.add_argument("--slo", default=None, metavar="SLO.json",
                    help="judge the run against an SLOSpec (repro.obs.slo):"
                         " per-tenant TTFT/ITL p95, tok/s, availability "
                         "and acceptance targets through error budgets + "
                         "multi-window burn rates; breaches fire slo_breach"
                         " events (a flight-recorder dump trigger) and "
                         "per-tenant health gauges track silent "
                         "degradation; --fleet manifests may carry an "
                         "'slo:' section instead")
    ap.add_argument("--slo-report", default=None, metavar="OUT.json",
                    help="write the final SLO report (budgets, burn rates, "
                         "breach episodes, health) for the python -m "
                         "repro.obs.slo gate")
    ap.add_argument("--profile", action="store_true",
                    help="perf-attribution plane: sampled per-phase "
                         "decode-step breakdown (serve_phase_ms{phase,"
                         "layer_run} histograms) plus MFU / HBM-"
                         "utilization gauges against the calibrated "
                         "roofline at exit; host-side only — tokens and "
                         "compile counts are unchanged")
    ap.add_argument("--profile-every", type=int, default=4, metavar="N",
                    help="decode steps between phase probes (--profile)")
    ap.add_argument("--xprof-out", default=None, metavar="DIR",
                    help="capture a programmatic jax.profiler trace of "
                         "the serve loop under DIR (TensorBoard/XProf); "
                         "--continuous only")
    ap.add_argument("--calibration-out", default=None, metavar="CALIB.json",
                    help="persist the measured/predicted decode-ms "
                         "correction factor for repro.launch.plan "
                         "--calibration")
    args = ap.parse_args()

    obs_flags = (args.trace_out or args.metrics_out or args.numerics
                 or args.flight_out or args.calibration_out or args.profile
                 or args.slo or args.slo_report
                 or args.serve_metrics is not None)
    if obs_flags and not (args.continuous or args.fleet):
        ap.error("--trace-out/--metrics-out/--numerics/--serve-metrics/"
                 "--flight-out/--calibration-out/--profile/--slo/"
                 "--slo-report instrument the serve layer; use them with "
                 "--continuous or --fleet")
    if args.xprof_out and not args.continuous:
        ap.error("--xprof-out captures the --continuous serve loop")
    if args.calibration_out and args.fleet:
        ap.error("--calibration-out fits one engine's roofline correction; "
                 "use it with --continuous (fleet runs report per-tenant "
                 "residual gauges instead)")

    if args.spec_plan is not None and (args.fleet is not None
                                       or not args.continuous):
        ap.error("--spec-plan needs --continuous (speculation runs on the "
                 "paged serve layer; per-tenant speculative fleets are not "
                 "wired yet)")
    if args.fleet is not None:
        _fleet(args)
        return
    if args.arch is None:
        ap.error("--arch is required without --fleet")

    cfg = configs.smoke(args.arch)
    params = transformer.init_params(cfg, jax.random.key(0))
    plan = None
    if args.plan is not None:
        from repro.plan import QuantPlan
        plan = QuantPlan.load(args.plan)
        print(plan.describe(cfg))
    if args.fused_attention and not args.continuous:
        ap.error("--fused-attention fuses the *paged* decode path; use it "
                 "with --continuous or --fleet")
    ecfg = EngineConfig(max_len=args.prompt_len + args.steps + 8,
                        kv_bits=args.kv_bits, kv_group=args.kv_group,
                        weight_scheme=args.scheme, a_bits=args.a_bits,
                        plan=plan, backend="ref",
                        temperature=args.temperature,
                        fused_attention=args.fused_attention)
    if args.continuous:
        print(f"arch={args.arch} scheme={args.scheme} plan={args.plan} "
              f"a_bits={args.a_bits} kv_bits={args.kv_bits}")
        _continuous(cfg, params, ecfg, args)
        return
    engine = Engine(cfg, params, ecfg)

    key = jax.random.key(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_len, cfg.frontend_dim))
    elif cfg.frontend == "patch_stub":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.frontend_dim))

    out, _ = engine.generate(batch, steps=args.steps)          # warm up
    jax.block_until_ready(out)
    sw = Stopwatch()
    out, _ = engine.generate(batch, steps=args.steps)
    jax.block_until_ready(out)
    dt = sw.elapsed()
    toks = args.batch * (args.steps + 1)
    print(f"arch={args.arch} scheme={args.scheme} a_bits={args.a_bits} "
          f"kv_bits={args.kv_bits}")
    print(f"generated {toks} tokens in {dt:.2f}s -> {toks / dt:.1f} tok/s")
    print(f"decode-cache bytes: {engine.cache_bytes(args.batch):,}")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
