"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the batched engine on the reduced config, optionally with the
paper's quantization applied to weights (--scheme lq4w), activations
(--a-bits) and the KV cache (--kv-bits), and reports tokens/s plus the
cache-bytes saving.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer
from repro.serve import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.names()))
    ap.add_argument("--scheme", default=None, help="weight scheme, e.g. lq4w")
    ap.add_argument("--a-bits", type=int, default=None)
    ap.add_argument("--kv-bits", type=int, default=None)
    ap.add_argument("--kv-group", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.smoke(args.arch)
    params = transformer.init_params(cfg, jax.random.key(0))
    ecfg = EngineConfig(max_len=args.prompt_len + args.steps + 8,
                        kv_bits=args.kv_bits, kv_group=args.kv_group,
                        weight_scheme=args.scheme, a_bits=args.a_bits,
                        backend="ref", temperature=args.temperature)
    engine = Engine(cfg, params, ecfg)

    key = jax.random.key(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_len, cfg.frontend_dim))
    elif cfg.frontend == "patch_stub":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.frontend_dim))

    out, _ = engine.generate(batch, steps=args.steps)          # warm up
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out, _ = engine.generate(batch, steps=args.steps)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    toks = args.batch * (args.steps + 1)
    print(f"arch={args.arch} scheme={args.scheme} a_bits={args.a_bits} "
          f"kv_bits={args.kv_bits}")
    print(f"generated {toks} tokens in {dt:.2f}s -> {toks / dt:.1f} tok/s")
    print(f"decode-cache bytes: {engine.cache_bytes(args.batch):,}")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
