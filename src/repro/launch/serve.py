"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the batched engine on the reduced config, optionally with the
paper's quantization applied to weights (--scheme lq4w), activations
(--a-bits) and the KV cache (--kv-bits), and reports tokens/s plus the
cache-bytes saving.

``--continuous N`` switches to the continuous-batching serve layer
(serve/server.py): N requests with staggered arrivals are scheduled over
the paged quantized KV pool, reporting throughput and pool occupancy.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer
from repro.serve import (Engine, EngineConfig, PagedConfig, RequestParams,
                         Server)


def _continuous(cfg, params, ecfg, args):
    """Staggered-arrival continuous batching over the paged pool."""
    import dataclasses
    want = args.prompt_len + args.steps + 8
    mc = -(-want // args.page_size) * args.page_size
    ecfg = dataclasses.replace(ecfg, max_len=max(ecfg.max_len, mc))
    pcfg = PagedConfig(max_slots=args.max_slots, page_size=args.page_size,
                       n_pages=args.n_pages, max_context=mc)
    server = Server(cfg, params, ecfg, pcfg)
    rng = jax.random.key(2)
    warm = jax.random.randint(jax.random.fold_in(rng, args.continuous),
                              (args.prompt_len,), 0, cfg.vocab_size)
    server.submit(warm.tolist(), RequestParams(max_new_tokens=2))
    server.drain()                          # warm both jits off the clock
    occ, t0 = [], time.perf_counter()
    rids = []
    for i in range(args.continuous):
        prompt = jax.random.randint(jax.random.fold_in(rng, i),
                                    (args.prompt_len,), 0, cfg.vocab_size)
        rids.append(server.submit(prompt.tolist(), RequestParams(
            max_new_tokens=args.steps + 1)))
        for _ in range(args.arrival_every):      # staggered arrivals
            server.step()
            occ.append(server.pool.occupancy())
    while server.has_work:
        server.step()
        occ.append(server.pool.occupancy())
    dt = time.perf_counter() - t0
    toks = sum(len(server.output(r)) for r in rids)
    s = server.stats()
    print(f"continuous: {len(rids)} requests, {toks} tokens in {dt:.2f}s "
          f"-> {toks / dt:.1f} tok/s")
    print(f"pool: {server.pool.n_pages} pages x "
          f"{server.pool.page_nbytes():,} B, peak occupancy "
          f"{max(occ):.2f}, mean {sum(occ) / len(occ):.2f}")
    print(f"decode compilations: {s['decode_compilations']} "
          f"(1 == no per-step retrace)")
    print("sample:", server.output(rids[0])[:16])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.names()))
    ap.add_argument("--scheme", default=None, help="weight scheme, e.g. lq4w")
    ap.add_argument("--plan", default=None, metavar="PLAN.json",
                    help="mixed-precision QuantPlan (repro.launch.plan "
                         "output); mutually exclusive with --scheme")
    ap.add_argument("--a-bits", type=int, default=None)
    ap.add_argument("--kv-bits", type=int, default=None)
    ap.add_argument("--kv-group", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", type=int, default=0, metavar="N",
                    help="serve N staggered requests via the paged "
                         "continuous-batching layer")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="decode steps between request arrivals")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=128)
    args = ap.parse_args()

    cfg = configs.smoke(args.arch)
    params = transformer.init_params(cfg, jax.random.key(0))
    plan = None
    if args.plan is not None:
        from repro.plan import QuantPlan
        plan = QuantPlan.load(args.plan)
        print(plan.describe(cfg))
    ecfg = EngineConfig(max_len=args.prompt_len + args.steps + 8,
                        kv_bits=args.kv_bits, kv_group=args.kv_group,
                        weight_scheme=args.scheme, a_bits=args.a_bits,
                        plan=plan, backend="ref",
                        temperature=args.temperature)
    if args.continuous:
        print(f"arch={args.arch} scheme={args.scheme} plan={args.plan} "
              f"a_bits={args.a_bits} kv_bits={args.kv_bits}")
        _continuous(cfg, params, ecfg, args)
        return
    engine = Engine(cfg, params, ecfg)

    key = jax.random.key(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_len, cfg.frontend_dim))
    elif cfg.frontend == "patch_stub":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.frontend_dim))

    out, _ = engine.generate(batch, steps=args.steps)          # warm up
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out, _ = engine.generate(batch, steps=args.steps)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    toks = args.batch * (args.steps + 1)
    print(f"arch={args.arch} scheme={args.scheme} a_bits={args.a_bits} "
          f"kv_bits={args.kv_bits}")
    print(f"generated {toks} tokens in {dt:.2f}s -> {toks / dt:.1f} tok/s")
    print(f"decode-cache bytes: {engine.cache_bytes(args.batch):,}")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
