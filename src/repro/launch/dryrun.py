import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.  Do not
import this module from tests/benches (they must see 1 device); it is a
__main__ driver and is exercised in CI via a subprocess.

Per cell:
    with mesh:
        lowered  = jax.jit(step_fn, in_shardings=..., out_shardings=...) \
                       .lower(*abstract_inputs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())     # proves it fits
        print(compiled.cost_analysis())       # FLOPs/bytes for roofline

Outputs one JSON per cell under experiments/dryrun/ with the roofline
terms (repro.roofline), memory stats and the collective schedule summary
— EXPERIMENTS.md §Dry-run / §Roofline are generated from these artifacts.

Usage:
    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    python -m repro.launch.dryrun --all [--mesh single|multi|both]
    python -m repro.launch.dryrun --all --quant lq4w   # packed-weight serve
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import shapes as shp
from repro.distributed import sharding
from repro.distributed.actshard import activation_rules, default_rules
from repro.launch import mesh as meshlib
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.layers import QuantPolicy, NO_QUANT
from repro.roofline import roofline_from_compiled
from repro.train import TrainHParams, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# grad-accumulation microsteps for the train cells whose activations would
# otherwise exceed HBM (the 235B/109B MoE giants) — §Perf iterates these.
TRAIN_MICROSTEPS = {
    "qwen3-moe-235b-a22b": 8,
    "llama4-scout-17b-a16e": 4,
    "qwen3-14b": 2,
    "qwen3-8b": 2,
}

# (arch, kind) cells that additionally shard the residual-stream sequence
# dim over "model" (sequence parallelism) — §Perf iterations fill this.
SEQ_SHARD: dict = {}

# Named perf variants (§Perf hillclimb): "hp" overrides the train
# hyperparameters; "act" overrides the logical activation-sharding rules.
VARIANTS = {
    "": {},
    "mp": {"hp": {"param_dtype": "bfloat16"}},  # bf16 params, fp32 master
    "mp_gc8": {"hp": {"param_dtype": "bfloat16",
                      "grad_compress_bits": 8}},
    # 2-D sharded MoE dispatch buffers: experts over EP, capacity over dp
    "moe2d": {"act": {"experts": "model", "flat_tokens": "__dp__"}},
    "mp_moe2d": {"hp": {"param_dtype": "bfloat16"},
                 "act": {"experts": "model", "flat_tokens": "__dp__"}},
    # sequence-parallel residual stream (94-layer activation-memory lever)
    "seqp": {"act": {"seq": "model"}},
    # shard_map EP dispatch: tokens stay dp-local; one psum combines
    "moesm": {"act": {"moe_shard_map": True}},
    "mp_moesm": {"hp": {"param_dtype": "bfloat16"},
                 "act": {"moe_shard_map": True}},
}


def model_flops(cfg: ModelConfig, cell: shp.ShapeCell) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed globally.

    Train counts fwd+bwd (6x); prefill counts forward only (2x); decode
    processes global_batch tokens (one step) at 2x.
    """
    n = cfg.active_param_count()
    if cell.kind == "train":
        return 6.0 * n * cell.seq_len * cell.global_batch
    if cell.kind == "prefill":
        return 2.0 * n * cell.seq_len * cell.global_batch
    return 2.0 * n * cell.global_batch


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def build_cell(cfg: ModelConfig, cell: shp.ShapeCell, mesh, rules,
               policy: QuantPolicy, quant_scheme: str | None,
               hp_overrides: dict | None = None, kv_bits: int | None = None):
    """Returns (step_fn, in_shardings, out_shardings, abstract_args,
    donate)."""
    dp = rules.dp

    def abstract_params():
        p = _abstract(lambda: transformer.init_params(cfg, jax.random.key(0)))
        if quant_scheme is not None:
            from repro.core import schemes
            p = _abstract(lambda pp: transformer.quantize_params(
                pp, cfg, schemes.get(quant_scheme)), p)
        return p

    if cell.kind == "train":
        hp = TrainHParams(microsteps=TRAIN_MICROSTEPS.get(cfg.name, 1),
                          **((hp_overrides or {}).get("hp", {})))
        init_state, train_step = make_train_step(cfg, hp, policy)
        state = _abstract(init_state, jax.random.key(0))
        batch = shp.train_specs(cfg, cell.seq_len, cell.global_batch)
        state_sh = rules.shardings(state, mesh)
        batch_sh = sharding.batch_sharding(batch, mesh, dp)
        return (train_step, (state_sh, batch_sh), (state_sh, None),
                (state, batch), (0,))

    params = abstract_params()
    params_sh = rules.shardings(params, mesh)

    if cell.kind == "prefill":
        batch = shp.prefill_specs(cfg, cell.seq_len, cell.global_batch)
        cache = shp.cache_specs(cfg, cell.global_batch, cell.seq_len)
        batch_sh = sharding.batch_sharding(batch, mesh, dp)
        cache_sh = sharding.cache_sharding(cache, mesh, dp,
                                           batch_size=cell.global_batch)

        def prefill_step(p, b, c):
            return transformer.prefill(p, cfg, b, c, policy=policy)

        return (prefill_step, (params_sh, batch_sh, cache_sh),
                (None, cache_sh), (params, batch, cache), (2,))

    # decode
    if kv_bits is not None:
        cache = jax.eval_shape(lambda: transformer.init_cache(
            cfg, cell.global_batch, cell.seq_len, kv_quant=(kv_bits, 64)))
        tokens = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
    else:
        specs = shp.decode_specs(cfg, cell.seq_len, cell.global_batch)
        tokens, cache = specs["tokens"], specs["cache"]
    tok_sh = sharding.batch_sharding(
        tokens, mesh, dp) if cell.global_batch > 1 else \
        jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    cache_sh = sharding.cache_sharding(cache, mesh, dp,
                                       batch_size=cell.global_batch)

    def serve_step(p, t, c):
        return transformer.decode_step(p, cfg, t, c, policy=policy)

    return (serve_step, (params_sh, tok_sh, cache_sh), (None, cache_sh),
            (params, tokens, cache), (2,))


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             quant_scheme: str | None = None, save: bool = True,
             verbose: bool = True, variant: str = "",
             kv_bits: int | None = None) -> dict:
    cfg = configs.get(arch)
    cell = shp.SHAPES[shape]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}_{shape}_{mesh_name}" + \
        (f"_{quant_scheme}" if quant_scheme else "") + \
        (f"_kv{kv_bits}" if kv_bits else "") + \
        (f"_{variant}" if variant else "")

    ok, why = shp.cell_supported(arch, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "skipped", "reason": why}
        if save:
            _save(tag, rec)
        return rec

    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    rules = sharding.rules_for(meshlib.dp_axes(mesh), family=cfg.family)
    policy = (QuantPolicy.serve(quant_scheme, backend="ref")
              if quant_scheme else NO_QUANT)

    t0 = time.time()
    step_fn, in_sh, out_sh, args, donate = build_cell(
        cfg, cell, mesh, rules, policy, quant_scheme,
        hp_overrides=VARIANTS[variant], kv_bits=kv_bits)
    act_rules = default_rules(meshlib.dp_axes(mesh),
                              shard_seq=SEQ_SHARD.get((cfg.name, cell.kind),
                                                      False),
                              kv_heads=cfg.n_kv_heads)
    for k, v in VARIANTS[variant].get("act", {}).items():
        act_rules[k] = (tuple(meshlib.dp_axes(mesh)) if v == "__dp__"
                        else v)
    if act_rules.get("moe_shard_map"):
        act_rules["__mesh__"] = mesh
    with mesh, activation_rules(act_rules):
        jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    rep = roofline_from_compiled(
        compiled, arch=arch, shape=shape, mesh_name=mesh_name,
        chips=mesh.devices.size, model_flops=model_flops(cfg, cell))
    rec = rep.to_dict()
    rec.update(
        status="ok", quant=quant_scheme, variant=variant, kv_bits=kv_bits,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory={
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "per_chip_total": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
        })
    if verbose:
        m = rec["memory"]
        print(f"[dryrun] {tag}: OK  "
              f"args {m['argument_bytes'] / 2 ** 30:.2f} GiB  "
              f"temp {m['temp_bytes'] / 2 ** 30:.2f} GiB  "
              f"compute {rec['compute_s'] * 1e3:.1f} ms  "
              f"memory {rec['memory_s'] * 1e3:.1f} ms  "
              f"collective {rec['collective_s'] * 1e3:.1f} ms  "
              f"-> {rec['dominant']}-bound  "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
              flush=True)
    if save:
        _save(tag, rec)
    return rec


def _save(tag: str, rec: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(configs.names()))
    ap.add_argument("--shape", choices=list(shp.SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quant", default=None,
                    help="weight scheme for serve cells (e.g. lq4w)")
    ap.add_argument("--kv-bits", type=int, default=None,
                    help="LQ-quantized KV cache for decode cells")
    ap.add_argument("--variant", default="", choices=list(VARIANTS),
                    help="perf variant for train cells (e.g. mp)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = ([(args.arch, args.shape)] if args.arch and args.shape else
             [(a, s) for a in configs.names() for s in shp.SHAPES])
    if not args.all and not (args.arch and args.shape):
        ap.error("need --arch/--shape or --all")

    failures = []
    for arch, shape in cells:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            tag = f"{arch}_{shape}_{mesh_name}" + \
                (f"_{args.quant}" if args.quant else "")
            if args.skip_existing and \
                    os.path.exists(os.path.join(OUT_DIR, tag + ".json")):
                print(f"[dryrun] {tag}: exists, skipping", flush=True)
                continue
            quant = args.quant if shp.SHAPES[shape].kind != "train" else None
            kvb = args.kv_bits if shp.SHAPES[shape].kind == "decode" else None
            try:
                run_cell(arch, shape, multi_pod=multi, quant_scheme=quant,
                         variant=args.variant, kv_bits=kvb)
            except Exception as e:
                traceback.print_exc()
                failures.append((tag, str(e)))
                _save(tag, {"arch": arch, "shape": shape, "mesh": mesh_name,
                            "status": "failed", "error": str(e)})
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("\n[dryrun] all cells passed")


if __name__ == "__main__":
    main()
