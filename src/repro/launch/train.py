"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it drives the REDUCED (smoke) configs end-to-end —
synthetic data, AdamW, checkpoints, auto-resume; on a real pod the same
flow runs the full config across the production mesh (pass --full and a
populated jax.distributed environment; the mesh/rules plumbing is shared
with the dry-run, which is how the production path is validated here).
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.data import DataConfig, SyntheticLM
from repro.models.layers import QuantPolicy
from repro.train import TrainHParams, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.names()))
    ap.add_argument("--full", action="store_true",
                    help="full published config (needs a real pod)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microsteps", type=int, default=1)
    ap.add_argument("--qat", default=None,
                    help="QAT scheme (e.g. lq4) — train with fake quant")
    ap.add_argument("--grad-compress-bits", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = configs.get(args.arch) if args.full else configs.smoke(args.arch)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch))
    hp = TrainHParams(lr=args.lr, microsteps=args.microsteps,
                      grad_compress_bits=args.grad_compress_bits)
    policy = QuantPolicy.qat(args.qat) if args.qat else \
        QuantPolicy.train_fp()
    trainer = Trainer(cfg, hp, data,
                      TrainerConfig(total_steps=args.steps,
                                    ckpt_dir=args.ckpt_dir,
                                    ckpt_every=args.ckpt_every),
                      policy=policy)
    trainer.run()
    print(f"final loss: {trainer.history[-1]['loss']:.4f}  "
          f"(start {trainer.history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
