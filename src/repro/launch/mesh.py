"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host
devices *before* any jax call; tests/benches see the single real device.

Topology (TPU v5e target):
  single-pod: (16, 16)    = ("data", "model") — 256 chips
  multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; the
              "pod" axis is pure data parallelism across the DCN/ICI
              boundary (gradient all-reduce only, optionally LQ-compressed
              via core/gradcomp.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:    # older jax: meshes are implicitly Auto-typed
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def dp_axes(mesh) -> tuple:
    """The data-parallel axis names of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh():
    """1x1 mesh on the real local device (CPU tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
