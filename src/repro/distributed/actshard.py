"""Logical activation-sharding constraints (MaxText-style axis rules).

GSPMD propagates parameter shardings through the graph, but a few
activation tensors need explicit pins or the partitioner picks replicated
layouts — the worst offender being the (batch, seq, vocab) logits, which
replicated cost ~34 GiB/device on the llama3.2-1b train cell (dry-run
iteration 1, EXPERIMENTS.md §Perf).

Model code annotates tensors with *logical* axis names::

    x = constrain(x, "batch", "seq", "embed")
    logits = constrain(logits, "batch", "seq", "vocab")

and the launcher binds logical names to mesh axes for the active mesh::

    with activation_rules({"batch": ("data",), "vocab": ("model",)}):
        ...lower/compile/run...

Outside a binding (tests, single-device examples) ``constrain`` is an
exact no-op, so model code carries no mesh dependence.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "activation_rules", default=None)


@contextlib.contextmanager
def activation_rules(rules: dict):
    """Bind logical-axis -> mesh-axes (str | tuple | None) rules."""
    token = _RULES.set(dict(rules))
    try:
        yield
    finally:
        _RULES.reset(token)


def current_rules():
    return _RULES.get()


def constrain(x, *logical: str | None):
    """Apply with_sharding_constraint per the bound rules (no-op unbound)."""
    rules = _RULES.get()
    if rules is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"constrain: {len(logical)} axes for ndim {x.ndim}")
    spec = P(*[rules.get(name) if name else None for name in logical])
    return jax.lax.with_sharding_constraint(x, spec)


def default_rules(dp_axes, *, shard_seq: bool = False,
                  kv_heads: int = 0) -> dict:
    """Baseline logical bindings for the production meshes.

    ``shard_seq=True`` additionally shards the sequence dim of the
    residual stream over "model" (sequence parallelism — the activation-
    memory lever for the 94-layer cells; §Perf).

    ``kv_heads``: kept as an experiment knob but bound to None by
    default — §Perf iteration 2 showed GSPMD already shards attention
    evenly on the mixed (kv x group) head factorization; an explicit
    kv-only constraint (uneven at kv < model extent) forced padded
    reshards and cost +70% memory-term.  Refuted, recorded.
    """
    dp = tuple(dp_axes)
    return {
        "batch": dp,
        "seq": "model" if shard_seq else None,
        "embed": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": None,
        "kv_seq": None,
        "ff": "model",
        # MoE dispatch: three bindings were tried on the scout train cell
        # (§Perf): unconstrained GSPMD / E-only / (E, capacity) 2-D.
        # E-only cost 5x compute (capacity replicated over dp); 2-D fixed
        # compute but inflated collectives 4x (gather/scatter across both
        # axes).  Unconstrained wins the baseline; the shard_map all-to-all
        # dispatch is the recorded follow-up.
        "experts": None,
        "flat_tokens": None,
    }
