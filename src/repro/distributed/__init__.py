from .sharding import (ShardingRules, rules_for, tree_paths,
                       batch_sharding, cache_sharding, param_shardings)
from .checkpoint import CheckpointManager
from .straggler import StragglerMonitor
from . import elastic
