"""Fault-tolerant checkpoint manager.

Layout (one directory per step)::

    <root>/step_00001200/
        manifest.json        tree structure, shapes, dtypes, crc32 per leaf
        leaf_00000.npy ...   one .npy per leaf (host-gathered)
    <root>/step_00001200.COMMIT   empty marker, written LAST (atomic commit)

Guarantees:

  * **atomicity** — data is written into ``<dir>.tmp`` then os.rename'd;
    the COMMIT marker is created only after a full fsync'd write, so a
    preemption mid-write leaves either a previous complete checkpoint or
    an uncommitted .tmp that restore ignores;
  * **corruption detection** — restore verifies per-leaf crc32 against the
    manifest and skips (with a warning) to the next older checkpoint;
  * **retention** — ``keep`` newest committed checkpoints are retained;
  * **resume** — ``restore_latest`` returns (step, tree) or None, so the
    Trainer auto-resumes after node failure / preemption.

Arrays are gathered to host before save (multi-host note: on a real pod
each host writes its addressable shards; here process count is 1 and the
full array is written — the manifest format carries ``shard`` metadata so
the layout extends to per-host sharded writes unchanged).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # -- paths ------------------------------------------------------------
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def _commit_marker(self, step: int) -> str:
        return self._dir(step) + ".COMMIT"

    def committed_steps(self) -> list:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and name.endswith(".COMMIT"):
                steps.append(int(name[len("step_"):-len(".COMMIT")]))
        return sorted(steps)

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        final = self._dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        from .sharding import _key_str
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, (kp, leaf) in enumerate(flat):
            path = "/".join(_key_str(k) for k in kp)
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append({
                "index": i, "path": path, "file": fname,
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": zlib.crc32(arr.tobytes()),
                "shard": {"process": 0, "n_processes": 1},
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())

        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic on POSIX
        with open(self._commit_marker(step), "w") as f:
            f.flush()
            os.fsync(f.fileno())
        self._gc()
        return final

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._dir(s), ignore_errors=True)
            try:
                os.remove(self._commit_marker(s))
            except FileNotFoundError:
                pass

    # -- restore ----------------------------------------------------------
    def _load(self, step: int, like):
        d = self._dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = jax.tree_util.tree_flatten(like)
        if len(flat) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint step {step}: leaf count mismatch "
                f"({len(manifest['leaves'])} saved vs {len(flat)} expected)")
        leaves = []
        for entry in manifest["leaves"]:
            arr = np.load(os.path.join(d, entry["file"]))
            if zlib.crc32(arr.tobytes()) != entry["crc32"]:
                raise IOError(f"crc mismatch in {entry['file']} "
                              f"(step {step}, path {entry['path']})")
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore(self, step: int, like):
        """Restore one step, validating crc32.  Raises on corruption."""
        return self._load(step, like)

    def restore_latest(self, like, *, verbose: bool = True):
        """Newest uncorrupted committed checkpoint, or None.

        Walks newest -> oldest; a corrupt/partial checkpoint is skipped
        (node died mid-write) and the previous one is used instead.
        """
        for step in reversed(self.committed_steps()):
            try:
                tree = self._load(step, like)
                return step, tree
            except Exception as e:                      # corrupt -> skip
                if verbose:
                    print(f"[ckpt] step {step} unusable ({e}); "
                          f"trying previous")
        return None
