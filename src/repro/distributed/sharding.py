"""Path-regex -> PartitionSpec sharding rules (MaxText-style).

Parallelism map (DESIGN.md §6), on mesh axes ``(pod?, data, model)``:

  * DP / FSDP over ``dp = ("pod", "data")`` — batch over dp; parameters'
    non-TP dimension is *also* sharded over dp (ZeRO-3 style), which is what
    lets 235B-class models fit 16 GB HBM chips (params, grads and optimizer
    moments all inherit the spec).
  * TP over ``"model"`` — attention q/k/v column-parallel, output
    row-parallel; FFN in column-, out row-parallel; vocab/embedding sharded
    on the vocab dim; MoE experts sharded over ``"model"`` (EP).
  * SP — long-sequence KV caches shard the *sequence* dim.

Rules match "/"-joined tree paths with ``re.search``; the FIRST hit wins.
A rule's spec applies to the TRAILING dims of the leaf: scan-stacked params
(S, ...) / stacked experts (S, E, K, N) get ``None`` (replicated) padding on
the leading dims automatically, so one rule covers both flat and stacked
layouts.  Leaves with no matching rule are replicated.

GSPMD propagates everything else; the jit boundary pins params/opt-state,
batch and cache shardings only.
"""
from __future__ import annotations

import dataclasses
import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# tree path utilities
# ---------------------------------------------------------------------------

def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)


def tree_paths(tree):
    """Pytree of '/'-joined path strings, mirroring ``tree``'s structure."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(_key_str(k) for k in kp) for kp, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, paths)


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: tuple                 # ((regex, PartitionSpec), ...) first match
    dp: tuple                    # data-parallel mesh axes, e.g. ("data",)

    def spec_for(self, path: str, ndim: int) -> P:
        for pattern, spec in self.rules:
            if re.search(pattern, path):
                return _pad_spec(spec, ndim)
        return P()

    def shardings(self, tree, mesh: Mesh):
        """NamedSharding pytree for ``tree`` (arrays or ShapeDtypeStructs).

        jit *argument* shardings must divide the dim exactly (uneven
        shardings are only legal on intermediates), so any spec entry
        that does not divide its dim is dropped to replicated — e.g.
        mamba2's in_proj N=3352 on a 16-way model axis.
        """
        paths = tree_paths(tree)
        return jax.tree.map(
            lambda p, x: NamedSharding(
                mesh, _evenly(self.spec_for(p, x.ndim), x.shape, mesh)),
            paths, tree)


def _pad_spec(spec: P, ndim: int) -> P:
    """Left-pad ``spec`` with None so it applies to the trailing dims."""
    if len(spec) > ndim:
        # leaf smaller than rule (e.g. biases matched broadly): replicate
        return P()
    return P(*([None] * (ndim - len(spec)) + list(spec)))


def _axis_extent(mesh: Mesh, entry) -> int:
    names = entry if isinstance(entry, (tuple, list)) else (entry,)
    n = 1
    for name in names:
        n *= mesh.shape[name]
    return n


def _evenly(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop spec entries that do not divide their dimension."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is not None and dim % _axis_extent(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return P(*out)


def rules_for(dp, *, family: str = "dense") -> ShardingRules:
    """Parameter sharding rules for one model family.

    ``dp``: tuple of data-parallel axis names — ("data",) single-pod,
    ("pod", "data") multi-pod.
    """
    fsdp = dp if len(dp) == 1 else tuple(dp)
    # Quantized (QWeight) leaves append /packed /scale /zmin to the weight
    # path; all three share the float weight's (K-ish, N) layout, so the
    # same trailing spec applies — ``Q`` makes a rule cover both.
    Q = r"(/(packed|scale|zmin))?$"
    rules = [
        # --- embeddings / readout: vocab dim over model (TP), fsdp over dp
        (r"embed/table$", P("model", fsdp)),
        (r"lm_head/w" + Q, P(fsdp, "model")),
        (r"(^|/)pos/pos$", P(None, "model")),
        (r"enc_pos/pos$", P(None, "model")),
        # --- MoE (EP): experts over model, fsdp on the contraction dim
        (r"router/w$", P(fsdp, None)),
        (r"ffn/(wi_gate|wi_up)" + Q, P("model", fsdp, None)),
        (r"ffn/wo" + Q, P("model", None, fsdp)),
        # --- shared expert / dense FFN: column-parallel in, row-parallel out
        (r"(shared|ffn)/(wi_gate|wi_up|wi)/w" + Q, P(fsdp, "model")),
        (r"(shared|ffn)/wo/w" + Q, P("model", fsdp)),
        # --- attention: q/k/v column-parallel, o row-parallel
        (r"(mixer|cross)/(wq|wk|wv)/w" + Q, P(fsdp, "model")),
        (r"(mixer|cross)/wo/w" + Q, P("model", fsdp)),
        (r"(wq|wk|wv)/b$", P("model")),
        # --- mamba2 / rglru projections
        (r"mixer/in_proj/w" + Q, P(fsdp, "model")),
        (r"mixer/out_proj/w" + Q, P("model", fsdp)),
        (r"mixer/(in_x|in_gate)/w" + Q, P(fsdp, "model")),
        (r"mixer/(w_a|w_x)/w" + Q, P(fsdp, "model")),
        (r"mixer/out/w" + Q, P("model", fsdp)),
        (r"mixer/conv_w$", P(None, "model")),
        (r"mixer/conv_b$", P("model")),
        (r"mixer/Lambda$", P("model")),
        # --- frontend stub projection
        (r"frontend/w$", P(None, "model")),
        # norms / scalars / everything else: replicated (matched by default)
    ]
    return ShardingRules(rules=tuple(rules), dp=tuple(dp))


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def batch_sharding(batch, mesh: Mesh, dp) -> dict:
    """Input batch: leading (global batch) dim over the dp axes."""
    def spec(x):
        return NamedSharding(mesh, _evenly(
            P(tuple(dp), *([None] * (x.ndim - 1))), x.shape, mesh))
    return jax.tree.map(spec, batch)


#: cache leaf name -> (base ndim without stack dims, dim roles)
#: roles: 'b' batch, 's' kv-sequence, 'f' feature (TP-shardable), '-' none
_CACHE_LEAVES = {
    "k": (4, "bs--"),        # (B, S_kv, KV_heads, head_dim)
    "v": (4, "bs--"),
    "conv": (3, "b-f"),      # (B, K-1, conv_dim)
    "ssm": (4, "bf--"),      # (B, H, P, N)
    "h": (2, "bf"),          # (B, W)
}


def cache_sharding(cache, mesh: Mesh, dp, *, batch_size: int,
                   seq_axis_over_model: bool = True):
    """Decode-cache sharding, resolved per leaf *name* (path tail).

    Baseline: batch over dp; the KV *sequence* dim over "model" (SP —
    robust for any kv-head count); SSM/LRU state features over "model".
    When ``batch_size == 1`` (the long-context cell) batch can't shard:
    the KV sequence dim shards over (dp + model) instead and states
    replicate on batch.
    """
    dp = tuple(dp)
    paths = tree_paths(cache)

    def spec(path, x):
        parts = path.rsplit("/", 2)
        name = parts[-1]
        if name in ("packed", "scale", "zmin") and len(parts) >= 2:
            # LQ-quantized cache leaf: inherits the parent tensor's roles
            # (packed/scale/zmin all keep the (B, S, ..) leading layout)
            name = parts[-2]
        if name not in _CACHE_LEAVES:
            return NamedSharding(mesh, P())          # e.g. 'pos' scalar
        base_nd, roles = _CACHE_LEAVES[name]
        lead = [None] * (x.ndim - base_nd)
        dims = []
        for role in roles:
            if role == "b":
                dims.append(dp if batch_size > 1 else None)
            elif role == "s":
                if batch_size == 1:
                    dims.append((*dp, "model") if seq_axis_over_model
                                else dp)
                else:
                    dims.append("model" if seq_axis_over_model else None)
            elif role == "f":
                dims.append("model")
            else:
                dims.append(None)
        return NamedSharding(mesh, _evenly(P(*lead, *dims), x.shape, mesh))

    return jax.tree.map(spec, paths, cache)


def param_shardings(abstract_params, mesh: Mesh, rules: ShardingRules):
    return rules.shardings(abstract_params, mesh)
