"""Elastic re-meshing: resume a checkpoint on a different device count.

Scenario: a pod loses N hosts (or gains capacity back).  The job restarts
with a different ``data`` extent; parameters and optimizer state restored
from the checkpoint must be re-laid-out for the new mesh.

Because checkpoints store *logical* (unsharded) arrays (manifest carries
the shard metadata) and shardings are derived from path rules — not baked
into the data — resharding is just: build the new mesh, re-derive
NamedShardings from the same rules, and ``jax.device_put`` each restored
leaf.  This file packages that flow and the degraded-batch policy.

``plan_remesh`` chooses the largest data extent <= healthy device count
that keeps the model axis intact and divides the global batch, so training
continues at reduced throughput rather than halting (the global batch is
kept constant by raising grad-accumulation microsteps).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh

from .sharding import ShardingRules


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    mesh_shape: tuple            # new (data, model) or (pod, data, model)
    axis_names: tuple
    microsteps: int              # grad-accumulation factor to keep GBS


def plan_remesh(healthy_devices: int, *, model_extent: int,
                global_batch: int, prev_data_extent: int,
                pod_extent: int = 1) -> RemeshPlan:
    """Largest data extent that fits healthy devices & divides the batch."""
    if healthy_devices < model_extent:
        raise ValueError(f"cannot keep model axis: {healthy_devices} "
                         f"devices < model extent {model_extent}")
    max_data = healthy_devices // (model_extent * pod_extent)
    data = 1
    for d in range(max_data, 0, -1):
        if global_batch % d == 0:
            data = d
            break
    microsteps = max(1, prev_data_extent // data)
    if pod_extent > 1:
        return RemeshPlan((pod_extent, data, model_extent),
                          ("pod", "data", "model"), microsteps)
    return RemeshPlan((data, model_extent), ("data", "model"), microsteps)


def build_mesh(plan: RemeshPlan, devices=None) -> Mesh:
    import numpy as np
    devices = devices if devices is not None else jax.devices()
    n = 1
    for s in plan.mesh_shape:
        n *= s
    grid = np.asarray(devices[:n]).reshape(plan.mesh_shape)
    return Mesh(grid, plan.axis_names)


def reshard(tree, mesh: Mesh, rules: ShardingRules):
    """Lay restored host arrays out on the new mesh per the same rules."""
    shardings = rules.shardings(tree, mesh)
    return jax.tree.map(jax.device_put, tree, shardings)
