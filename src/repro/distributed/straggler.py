"""Straggler detection: per-step wall-time EMA + z-score flagging.

At thousand-node scale the slowest worker sets the step time; persistent
stragglers (bad HBM, thermal throttle, flaky NIC) must be detected and
acted on.  The monitor keeps an EMA of step wall-time and the EMA of its
variance; a step (or, fed per-replica durations, a replica) whose duration
z-score exceeds ``threshold`` for ``patience`` consecutive observations
fires the configured policy hook.

Policies are injected callables — ``log`` (default), or e.g. a drop-slowest
hook that triggers the elastic re-mesh (distributed/elastic.py).

Timing goes through the shared :class:`repro.obs.Stopwatch` primitive:
``clock`` is injectable (seconds, monotonic), so tests drive the monitor
with a fake clock instead of sleeping.
"""
from __future__ import annotations

import dataclasses

from repro.obs.metrics import DEFAULT_CLOCK, Stopwatch


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.9            # EMA decay
    threshold: float = 3.0        # z-score to flag
    patience: int = 3             # consecutive flags before firing
    warmup: int = 5               # observations before flagging starts
    on_straggler: object = None   # callable(name, duration, zscore)
    clock: object = DEFAULT_CLOCK  # injectable monotonic seconds source

    def __post_init__(self):
        self._mean = {}
        self._var = {}
        self._count = {}
        self._strikes = {}
        self._sw = None
        self.events = []

    # -- timing convenience ------------------------------------------------
    def start(self):
        self._sw = Stopwatch(self.clock)

    def stop(self, name: str = "step") -> float:
        dt = self._sw.elapsed()
        self.observe(name, dt)
        return dt

    # -- core --------------------------------------------------------------
    def observe(self, name: str, duration: float) -> bool:
        """Feed one duration; returns True if ``name`` is flagged."""
        m = self._mean.get(name, duration)
        v = self._var.get(name, 0.0)
        c = self._count.get(name, 0)
        z = 0.0
        if c >= self.warmup:
            # floor the std at 1% of the mean: perfectly steady histories
            # (v ~ 0) must still flag a 5x-slower step
            std = max(v ** 0.5, 0.01 * abs(m), 1e-9)
            z = (duration - m) / std
        if z > self.threshold:
            # robust update: outliers do NOT pollute the EMA (otherwise a
            # single slow step inflates the variance enough to mask the
            # next one and a 2-strike policy never fires)
            self._strikes[name] = self._strikes.get(name, 0) + 1
        else:
            self._strikes[name] = 0
            self._mean[name] = self.alpha * m + (1 - self.alpha) * duration
            self._var[name] = self.alpha * v + (1 - self.alpha) \
                * (duration - m) ** 2
        self._count[name] = c + 1

        flagged = self._strikes.get(name, 0) >= self.patience
        if flagged:
            self.events.append((name, duration, z))
            if self.on_straggler is not None:
                self.on_straggler(name, duration, z)
            else:
                print(f"[straggler] {name}: {duration * 1e3:.1f} ms "
                      f"(z={z:.1f})")
            self._strikes[name] = 0
        return flagged

    def stats(self, name: str = "step") -> dict:
        return {"mean_s": self._mean.get(name), "var": self._var.get(name),
                "count": self._count.get(name, 0)}
