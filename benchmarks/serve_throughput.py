"""Continuous-batching serve microbenchmark: throughput + latency.

Sweeps request arrival rate (one new request every `arrival` decode steps)
across 8/4/2-bit quantized KV pools, reporting decode tokens/sec, TTFT
and inter-token-latency p50/p95 (from a per-cell
:class:`repro.obs.Observability` attached after jit warmup), SLO
attainment (fraction of TTFT/ITL samples inside the benchmark targets,
via the same bucket-conservative ``good_fraction`` the SLO tracker
uses), mean and peak pool occupancy, and pool bytes — the serving-side
counterpart of the paper's memory-pressure analysis.  Wall times on the CPU host are
indicative only (the kernels target TPU); occupancy and bytes are exact.

Run:  PYTHONPATH=src python -m benchmarks.serve_throughput
"""
from __future__ import annotations

import json

import jax
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.obs import Observability, Stopwatch
from repro.obs.slo import good_fraction
from repro.serve import EngineConfig, PagedConfig, RequestParams, Server

CFG = ModelConfig(name="serve-bench", family="dense", n_layers=4,
                  d_model=128, vocab_size=512, n_heads=8, n_kv_heads=4,
                  head_dim=16, d_ff=256, dtype="float32", remat="none")

N_REQ, MAX_NEW = 8, 16
ARRIVALS = (1, 2, 4)          # decode steps between request arrivals
KV_BITS = (8, 4, 2)
# generous host-CPU SLO targets: the attainment columns exist to catch
# regressions in the tail (via the repro.obs.regress gate), not to
# grade a TPU-class latency budget on the CPU host
SLO_TTFT_MS = 2000.0
SLO_ITL_MS = 500.0


def _run_cell(params, kv_bits: int, arrival: int) -> dict:
    ecfg = EngineConfig(max_len=64, kv_bits=kv_bits, kv_group=16)
    pcfg = PagedConfig(max_slots=4, page_size=8, n_pages=48, max_context=64)
    server = Server(CFG, params, ecfg, pcfg)
    rng = np.random.default_rng(kv_bits * 10 + arrival)
    prompts = [list(map(int, rng.integers(0, CFG.vocab_size, size=int(n))))
               for n in rng.integers(6, 20, size=N_REQ)]

    # warm the two jits (prefill bucket + decode step) outside the clock,
    # then attach fresh observability so compile time stays out of the
    # latency histograms
    warm = server.submit(prompts[0], RequestParams(max_new_tokens=2))
    server.drain()
    assert len(server.output(warm)) == 2
    obs = Observability()
    server.set_obs(obs)

    occ, sw = [], Stopwatch()
    for p in prompts:
        server.submit(p, RequestParams(max_new_tokens=MAX_NEW))
        for _ in range(arrival):
            server.step()
            occ.append(server.pool.occupancy())
    while server.has_work:
        server.step()
        occ.append(server.pool.occupancy())
    dt = sw.elapsed()

    ttft = obs.metrics.find("serve_ttft_ms", tenant="default")
    itl = obs.metrics.find("serve_itl_ms", tenant="default")
    toks = N_REQ * MAX_NEW
    return {"tok_per_s": toks / dt,
            "steps": len(occ),
            "ttft_p50_ms": ttft.percentile(50),
            "ttft_p95_ms": ttft.percentile(95),
            "itl_p50_ms": itl.percentile(50),
            "itl_p95_ms": itl.percentile(95),
            "slo_ttft_attainment": good_fraction(ttft, SLO_TTFT_MS),
            "slo_itl_attainment": good_fraction(itl, SLO_ITL_MS),
            "occupancy_mean": float(np.mean(occ)),
            "occupancy_peak": float(np.max(occ)),
            "pool_bytes": server.pool.nbytes(),
            "decode_compilations": server.engine.decode_compilations}


def run(verbose: bool = True) -> dict:
    params = transformer.init_params(CFG, jax.random.key(0))
    rows = {}
    for bits in KV_BITS:
        for arrival in ARRIVALS:
            cell = _run_cell(params, bits, arrival)
            for k, v in cell.items():
                rows[f"kv{bits}_arr{arrival}_{k}"] = v

    if verbose:
        print("\n== continuous-batching serve throughput "
              f"({N_REQ} reqs x {MAX_NEW} toks, CPU host) ==")
        print(f"{'kv_bits':>8} {'arrival':>8} {'tok/s':>8} "
              f"{'ttft-p50':>9} {'ttft-p95':>9} {'itl-p50':>8} "
              f"{'itl-p95':>8} {'slo-ttft':>9} {'slo-itl':>8} "
              f"{'occ-mean':>9} {'occ-peak':>9} "
              f"{'pool-bytes':>11}")
        for bits in KV_BITS:
            for arrival in ARRIVALS:
                p = f"kv{bits}_arr{arrival}_"
                print(f"{bits:>8} {arrival:>8} {rows[p + 'tok_per_s']:>8.1f} "
                      f"{rows[p + 'ttft_p50_ms']:>9.2f} "
                      f"{rows[p + 'ttft_p95_ms']:>9.2f} "
                      f"{rows[p + 'itl_p50_ms']:>8.2f} "
                      f"{rows[p + 'itl_p95_ms']:>8.2f} "
                      f"{rows[p + 'slo_ttft_attainment']:>9.3f} "
                      f"{rows[p + 'slo_itl_attainment']:>8.3f} "
                      f"{rows[p + 'occupancy_mean']:>9.2f} "
                      f"{rows[p + 'occupancy_peak']:>9.2f} "
                      f"{rows[p + 'pool_bytes']:>11,}")
    return rows


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
