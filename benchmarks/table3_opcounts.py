"""Paper Table 3: multiply/add reduction from the 2-bit LUT scheme.

Counts from the EXACT AlexNet / VGG-16 conv shapes (models/convnet.py
reproduces 666M / 15347M conv MACs to the paper's figures), with the
paper's section-V accounting: per local region, bucket-combine costs
(2^bits - 1) adds and the dequantization affine 1 multiply.
"""
from __future__ import annotations

from repro.core import lut
from repro.models import convnet

PAPER = {                # network -> (orig_mult, lut_mult, lut_add), in M
    "alexnet": (666, 74, 222),
    "vgg16": (15347, 1705, 5116),
}


def run(verbose: bool = True) -> dict:
    rows = {}
    for cfg in (convnet.ALEXNET, convnet.VGG16):
        macs = convnet.conv_macs(cfg, conv_only=True)
        summary = lut.reduction_summary(macs, bits=2, region_size=9)
        rows[cfg.name] = summary
        if verbose:
            pm, plm, pla = PAPER[cfg.name]
            print(f"\n== Table 3 [{cfg.name}]: 2-bit LUT op counts ==")
            print(f"  original : {summary['orig_mult'] / 1e6:8.0f}M mult "
                  f"{summary['orig_add'] / 1e6:8.0f}M add   "
                  f"(paper {pm}M / {pm}M)")
            print(f"  2-bit LUT: {summary['lut_mult'] / 1e6:8.0f}M mult "
                  f"{summary['lut_add'] / 1e6:8.0f}M add   "
                  f"(paper {plm}M / {pla}M)")
            print(f"  reduction: {summary['mult_reduction']:.1f}x mult, "
                  f"{summary['add_reduction']:.1f}x add")
    return rows


if __name__ == "__main__":
    run()
