"""Multi-tenant fleet microbenchmark: two plans, one host budget.

Two tenants — "gold" (8/4-bit mixed plan, 8-bit KV, weight 3) and
"bronze" (4/2-bit mixed plan, 2-bit KV, weight 1) — share one host
behind the fleet router.  The benchmark:

  1. proves the shared ``budget_mb`` is enforced (an over-budget
     manifest raises ``FleetBudgetError`` before any engine is built);
  2. proves per-tenant greedy outputs match each tenant's **solo**
     ``PagedEngine`` token-for-token (router interleaving is invisible
     to a tenant's decode);
  3. sweeps request arrival rate and reports aggregate and per-tenant
     tokens/sec, pool occupancy, and the weighted-round-robin step
     split.

Wall times on the CPU host are indicative only (kernels target TPU);
byte accounting, rejection behavior, and parity are exact.

Run:  PYTHONPATH=src python -m benchmarks.fleet_throughput
"""
from __future__ import annotations

import dataclasses
import json

import jax
import numpy as np

from repro.fleet import (FleetBudgetError, FleetRegistry, FleetRouter,
                         TenantSpec)
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.obs import Stopwatch
from repro.plan import QuantPlan
from repro.serve import PagedEngine, Scheduler

CFG = ModelConfig(name="fleet-bench", family="dense", n_layers=4,
                  d_model=128, vocab_size=512, n_heads=8, n_kv_heads=4,
                  head_dim=16, d_ff=256, dtype="float32", remat="none")

N_REQ, MAX_NEW = 6, 12         # per tenant
ARRIVALS = (1, 2, 4)           # router steps between request arrivals

GOLD_PLAN = QuantPlan.from_assignment(
    {"layer.0": "lq8w", "layer.1": "lq8w"}, default="lq4w",
    meta={"tier": "gold"})
BRONZE_PLAN = QuantPlan.from_assignment(
    {"layer.0": "lq4w"}, default="lq2w", meta={"tier": "bronze"})

SPECS = (
    TenantSpec("gold", plan=GOLD_PLAN, kv_bits=8, kv_group=16, weight=3,
               max_slots=2, page_size=8, n_pages=32, max_context=48),
    TenantSpec("bronze", plan=BRONZE_PLAN, kv_bits=2, kv_group=16, weight=1,
               max_slots=2, page_size=8, n_pages=32, max_context=48),
)


def _prompts(seed: int) -> list:
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(0, CFG.vocab_size, size=int(n))))
            for n in rng.integers(6, 20, size=N_REQ)]


def _build_router(params, budget_mb: float) -> FleetRouter:
    registry = FleetRegistry(CFG, params, budget_mb=budget_mb,
                             backend="ref")
    for spec in SPECS:
        registry.register(spec)
    return FleetRouter(registry)


def _solo_outputs(params, spec: TenantSpec, prompts) -> list:
    """The tenant's workload on its own solo PagedEngine (no router)."""
    ecfg = dataclasses.replace(spec.engine_config(CFG), backend="ref")
    engine = PagedEngine(CFG, params, ecfg, spec.paged_config())
    pool = engine.new_pool()
    sched = Scheduler(engine, pool)
    rids = [sched.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
    outs = sched.drain(max_steps=5000)
    return [outs[r] for r in rids]


def run(verbose: bool = True) -> dict:
    params = transformer.init_params(CFG, jax.random.key(0))
    rows: dict = {}

    # 1. shared budget is enforced: the two tenants need ~1 MiB; a
    #    0.1 MiB host must reject the manifest before building engines.
    try:
        _build_router(params, budget_mb=0.1)
        raise AssertionError("over-budget manifest was NOT rejected")
    except FleetBudgetError as e:
        rows["over_budget_rejected"] = True
        if verbose:
            print(f"over-budget manifest rejected: {str(e)[:72]}...")

    router = _build_router(params, budget_mb=16)
    rows["used_mb"] = router.registry.total_bytes() / 2**20
    for t in router.registry:
        rows[f"{t.tenant_id}_weight_bytes"] = t.weight_bytes
        rows[f"{t.tenant_id}_pool_bytes"] = t.pool_bytes

    # 2. per-tenant parity with the solo engine, token for token, under
    #    interleaved routing (arrival = 1 router step between submits).
    prompts = {s.tenant_id: _prompts(seed=17 + i)
               for i, s in enumerate(SPECS)}
    rid_map: dict = {}
    for i in range(N_REQ):
        for tid in prompts:
            rid_map.setdefault(tid, []).append(
                router.submit(tid, prompts[tid][i], max_new_tokens=MAX_NEW))
            router.step()
    fleet_outs = router.drain(max_steps=10_000)
    for spec in SPECS:
        tid = spec.tenant_id
        solo = _solo_outputs(params, spec, prompts[tid])
        got = [fleet_outs[tid][r] for r in rid_map[tid]]
        assert got == solo, f"{tid}: fleet outputs diverge from solo engine"
    rows["solo_parity"] = True
    if verbose:
        print("per-tenant greedy outputs match solo engines token-for-token")

    # 3. throughput vs arrival rate (jits are warm from the parity pass).
    for arrival in ARRIVALS:
        router.reset_telemetry()                 # fresh stats per cell
        sw = Stopwatch()
        for i in range(N_REQ):
            for tid in prompts:
                router.submit(tid, prompts[tid][i], max_new_tokens=MAX_NEW)
            for _ in range(arrival):
                router.step()
        router.drain(max_steps=10_000)
        dt = sw.elapsed()
        snap = router.telemetry.snapshot()
        rows[f"arr{arrival}_tok_per_s"] = snap["aggregate"]["tokens"] / dt
        for tid, s in snap["tenants"].items():
            rows[f"arr{arrival}_{tid}_tok_per_s"] = s["tok_per_s"]
            rows[f"arr{arrival}_{tid}_steps"] = s["steps"]
            rows[f"arr{arrival}_{tid}_occ_mean"] = s["occupancy_mean"]

    if verbose:
        print(f"\n== fleet throughput ({len(SPECS)} tenants x {N_REQ} reqs "
              f"x {MAX_NEW} toks, CPU host) ==")
        print(f"{'arrival':>8} {'agg tok/s':>10} "
              + "".join(f"{t.tenant_id + ' tok/s':>14}"
                        f"{t.tenant_id + ' steps':>14}" for t in SPECS))
        for arrival in ARRIVALS:
            line = f"{arrival:>8} {rows[f'arr{arrival}_tok_per_s']:>10.1f} "
            for spec in SPECS:
                line += (f"{rows[f'arr{arrival}_{spec.tenant_id}_tok_per_s']:>14.1f}"
                         f"{rows[f'arr{arrival}_{spec.tenant_id}_steps']:>14}")
            print(line)
        print(f"host budget use: {rows['used_mb']:.3f} MiB "
              f"(gold {rows['gold_weight_bytes'] / 2**20:.3f} MiB weights, "
              f"bronze {rows['bronze_weight_bytes'] / 2**20:.3f} MiB)")
    return rows


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
