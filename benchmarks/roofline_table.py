"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json).

Prints the full 40-cell x 2-mesh table: the three terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, bytes/device — the §Roofline
deliverable (also written to experiments/roofline_table.md).
"""
from __future__ import annotations

import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "experiments")
DRYRUN = os.path.join(ROOT, "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(quant: str | None = None) -> list:
    rows = []
    if not os.path.isdir(DRYRUN):
        return rows
    for fn in sorted(os.listdir(DRYRUN)):
        if not fn.endswith(".json"):
            continue
        is_quant = "_lq" in fn or "_dq" in fn
        if (quant is None) == is_quant:
            continue
        rec = json.load(open(os.path.join(DRYRUN, fn)))
        if quant is not None and rec.get("quant") != quant:
            continue
        if quant is None and rec.get("variant"):
            continue              # §Perf variants live in EXPERIMENTS.md
        rows.append(rec)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]),
                             r["mesh"]))
    return rows


def fmt_row(r: dict) -> str:
    if r.get("status") == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"skipped: sub-quadratic attention required |||||")
    c, m, k = r["compute_s"], r["memory_s"], r["collective_s"]
    mem_gib = r["memory"]["per_chip_total"] / 2 ** 30
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {c * 1e3:.1f} | {m * 1e3:.1f} | {k * 1e3:.1f} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {mem_gib:.1f} |")


HEADER = ("| arch | shape | mesh | compute ms | memory ms | collective ms "
          "| bound | 6ND/HLO | GiB/chip |\n"
          "|---|---|---|---|---|---|---|---|---|")


def run(verbose: bool = True, quant: str | None = None):
    rows = load(quant)
    if not rows:
        if verbose:
            print("\n== roofline: no dry-run artifacts found — run "
                  "`python -m repro.launch.dryrun --all` first ==")
        return {}
    lines = [HEADER] + [fmt_row(r) for r in rows]
    table = "\n".join(lines)
    if verbose:
        print(f"\n== roofline table ({len(rows)} cells"
              + (f", quant={quant}" if quant else "") + ") ==")
        print(table)
        ok = [r for r in rows if r.get("status") == "ok"]
        for dom in ("compute", "memory", "collective"):
            n = sum(1 for r in ok if r.get("dominant") == dom)
            print(f"  {dom}-bound cells: {n}/{len(ok)}")
    out = os.path.join(ROOT, "roofline_table"
                       + (f"_{quant}" if quant else "") + ".md")
    with open(out, "w") as f:
        f.write(table + "\n")
    return {r["arch"] + "/" + r["shape"] + "/" + r["mesh"]: r
            for r in rows}


if __name__ == "__main__":
    run()
