"""Per-kernel microbenchmark: ref-path wall time + bytes accounting.

Wall-times on this CPU host are indicative only (the kernels target TPU;
interpret mode is a correctness harness, ~1000x slower than compiled),
so the table reports the REF path (XLA-compiled jnp) plus the
bytes-moved model that determines TPU performance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.obs import time_fn


def _time(fn, reps=3):
    return time_fn(fn, reps=reps)


def run(verbose: bool = True) -> dict:
    m, k, n, gs = 256, 4096, 4096, 128
    key = jax.random.key(0)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    rows = {}

    t_fp = _time(jax.jit(lambda: x @ w.astype(x.dtype)))
    rows["fp32_matmul_ms"] = t_fp * 1e3
    for bits in (8, 4, 2):
        qw = ops.quantize_weight(w, bits, gs)
        t = _time(jax.jit(lambda qw=qw: ops.quant_matmul(
            x, qw, backend="ref")))
        rows[f"quant_matmul_b{bits}_ms"] = t * 1e3
        rows[f"quant_matmul_b{bits}_bytes"] = qw.nbytes()
    t_aq = _time(jax.jit(lambda: ops.act_quant(
        x, bits=4, group_size=gs, backend="ref")[0]))
    rows["act_quant_b4_ms"] = t_aq * 1e3

    if verbose:
        print("\n== kernel microbench (ref path on CPU host) ==")
        print(f"  fp32 matmul {m}x{k}x{n}: {rows['fp32_matmul_ms']:.1f} ms "
              f"({w.size * 4:,} weight bytes)")
        for bits in (8, 4, 2):
            print(f"  quant_matmul {bits}-bit: "
                  f"{rows[f'quant_matmul_b{bits}_ms']:.1f} ms "
                  f"({rows[f'quant_matmul_b{bits}_bytes']:,} weight bytes)")
        print(f"  act_quant 4-bit: {rows['act_quant_b4_ms']:.1f} ms")
    return rows


if __name__ == "__main__":
    run()
