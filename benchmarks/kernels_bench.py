"""Per-kernel microbenchmark: ref-path wall time + bytes accounting.

Wall-times on this CPU host are indicative only (the kernels target TPU;
interpret mode is a correctness harness, ~1000x slower than compiled),
so the table reports the REF path (XLA-compiled jnp) plus the
bytes-moved model that determines TPU performance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import kvwire
from repro.kernels import ops
from repro.kernels import paged_attention as paged_attn
from repro.models import attention
from repro.obs import time_fn


def _time(fn, reps=3):
    return time_fn(fn, reps=reps)


def run_fused(verbose: bool = True) -> dict:
    """Fused paged-attention vs the gather+dequant+attention baseline.

    One decode step over the wire-format paged pool, kv bits
    {fp, 8, 4, 2} x context length: the triple round-trip the fused
    kernel eliminates, timed against the XLA fallback on identical
    pages.  On a CPU host the fused column runs the interpreter (a
    correctness harness, orders of magnitude slower than a compiled
    TPU kernel) — the regress gate compares same-backend history only,
    so the columns are self-consistent, never cross-backend.
    """
    b, kvh, g, d, gs = 2, 2, 2, 64, 16
    page_size = 16
    mode = paged_attn.default_mode() if paged_attn.available() else None
    key = jax.random.key(0)
    rows = {}
    for ctx in (128, 512):
        pps = ctx // page_size
        n_pages = b * pps + 1                     # page 0 = scratch
        kf = jax.random.normal(key, (n_pages, page_size, kvh, d),
                               jnp.float32)
        vf = jax.random.normal(jax.random.fold_in(key, 1), kf.shape,
                               jnp.float32)
        q = jax.random.normal(jax.random.fold_in(key, 2),
                              (b, 1, kvh, g, d), jnp.float32)
        table = (1 + jnp.arange(b * pps, dtype=jnp.int32)).reshape(b, pps)
        pos = jnp.full((b,), ctx - 1, jnp.int32)
        for bits in (None, 8, 4, 2):
            if bits is None:
                k_pg, v_pg = kf, vf
            else:
                k_pg = kvwire.quantize_kv(kf, bits, gs)
                v_pg = kvwire.quantize_kv(vf, bits, gs)
            label = "fp" if bits is None else f"kv{bits}"

            def baseline(k_pg=k_pg, v_pg=v_pg, bits=bits):
                kk = kvwire.gather_pages(k_pg, table)
                vv = kvwire.gather_pages(v_pg, table)
                if bits is not None:
                    kk = kvwire.dequantize_kv(kk, d)
                    vv = kvwire.dequantize_kv(vv, d)
                return attention.decode_attention(q, kk, vv, pos)

            t = _time(jax.jit(baseline), reps=2)
            rows[f"paged_attn_{label}_ctx{ctx}_baseline_ms"] = t * 1e3
            if mode is None:
                continue                          # no Pallas: XLA-only row

            def fused(k_pg=k_pg, v_pg=v_pg):
                return paged_attn.paged_attention(
                    q, k_pg, v_pg, table, pos,
                    interpret=mode == "interpret")

            t = _time(fused, reps=2)
            rows[f"paged_attn_{label}_ctx{ctx}_fused_ms"] = t * 1e3

    if verbose:
        print(f"\n== fused paged-attention vs gather+dequant baseline "
              f"(fused mode: {mode or 'unavailable'}) ==")
        for ctx in (128, 512):
            for label in ("fp", "kv8", "kv4", "kv2"):
                base = rows[f"paged_attn_{label}_ctx{ctx}_baseline_ms"]
                fkey = f"paged_attn_{label}_ctx{ctx}_fused_ms"
                fstr = f"{rows[fkey]:8.2f} ms fused" if fkey in rows \
                    else "     n/a fused"
                print(f"  {label:>4} ctx {ctx:4d}: {base:8.2f} ms baseline"
                      f"  {fstr}")
    return rows


def run(verbose: bool = True) -> dict:
    m, k, n, gs = 256, 4096, 4096, 128
    key = jax.random.key(0)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    rows = {}

    t_fp = _time(jax.jit(lambda: x @ w.astype(x.dtype)))
    rows["fp32_matmul_ms"] = t_fp * 1e3
    for bits in (8, 4, 2):
        qw = ops.quantize_weight(w, bits, gs)
        t = _time(jax.jit(lambda qw=qw: ops.quant_matmul(
            x, qw, backend="ref")))
        rows[f"quant_matmul_b{bits}_ms"] = t * 1e3
        rows[f"quant_matmul_b{bits}_bytes"] = qw.nbytes()
    t_aq = _time(jax.jit(lambda: ops.act_quant(
        x, bits=4, group_size=gs, backend="ref")[0]))
    rows["act_quant_b4_ms"] = t_aq * 1e3

    if verbose:
        print("\n== kernel microbench (ref path on CPU host) ==")
        print(f"  fp32 matmul {m}x{k}x{n}: {rows['fp32_matmul_ms']:.1f} ms "
              f"({w.size * 4:,} weight bytes)")
        for bits in (8, 4, 2):
            print(f"  quant_matmul {bits}-bit: "
                  f"{rows[f'quant_matmul_b{bits}_ms']:.1f} ms "
                  f"({rows[f'quant_matmul_b{bits}_bytes']:,} weight bytes)")
        print(f"  act_quant 4-bit: {rows['act_quant_b4_ms']:.1f} ms")
    return rows


if __name__ == "__main__":
    run()
    run_fused()
