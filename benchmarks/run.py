"""Benchmark aggregator: ``python -m benchmarks.run [names...]``.

One benchmark per paper table/figure:
  table2   DQ-vs-LQ accuracy at 8/6/4/2-bit        (paper Table 2)
  fig10    2-bit accuracy vs region size           (paper Fig. 10)
  table3   LUT multiply/add reduction              (paper Table 3)
  fig8     fixed-point speedup (CPU + TPU model)   (paper Fig. 8)
  table45  per-format hardware cost model          (paper Tables 4/5)
  kernels  per-kernel microbench
  serve    continuous-batching throughput + pool occupancy
  spec     self-speculative decode: acceptance + verifier steps/token
  fleet    multi-tenant fleet: two plans, one budget, per-tenant tok/s
  roofline dry-run roofline table (reads experiments/dryrun/)
  plan     mixed-precision plan Pareto sweep (accuracy proxy vs cost)
  kvplan   per-layer KV-bitwidth sweep (cache bytes/token vs kv loss)
"""
from __future__ import annotations

import sys


def main(argv=None):
    names = (argv if argv is not None else sys.argv[1:]) or [
        "table3", "fig8", "table45", "kernels", "serve", "spec", "fleet",
        "plan", "kvplan", "table2", "fig10", "roofline"]
    results = {}
    for name in names:
        if name == "table2":
            from . import table2_accuracy as m
        elif name == "fig10":
            from . import fig10_region_sweep as m
        elif name == "table3":
            from . import table3_opcounts as m
        elif name == "fig8":
            from . import fig8_speedup as m
        elif name == "table45":
            from . import table45_hw_cost as m
        elif name == "kernels":
            from . import kernels_bench as m
        elif name == "serve":
            from . import serve_throughput as m
        elif name == "spec":
            from . import spec_decode as m
        elif name == "fleet":
            from . import fleet_throughput as m
        elif name == "roofline":
            from . import roofline_table as m
        elif name == "plan":
            from . import plan_pareto as m
        elif name == "kvplan":
            from . import plan_pareto as m
            results[name] = m.run_kv()
            continue
        else:
            raise SystemExit(f"unknown benchmark {name!r}")
        results[name] = m.run()
    print("\nall benchmarks complete:", ", ".join(results))
    return results


if __name__ == "__main__":
    main()
