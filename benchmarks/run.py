"""Benchmark aggregator: ``python -m benchmarks.run [names...]``.

One benchmark per paper table/figure:
  table2   DQ-vs-LQ accuracy at 8/6/4/2-bit        (paper Table 2)
  fig10    2-bit accuracy vs region size           (paper Fig. 10)
  table3   LUT multiply/add reduction              (paper Table 3)
  fig8     fixed-point speedup (CPU + TPU model)   (paper Fig. 8)
  table45  per-format hardware cost model          (paper Tables 4/5)
  kernels  per-kernel microbench
  fused    fused paged-attention vs gather+dequant baseline sweep
  serve    continuous-batching throughput + pool occupancy
  spec     self-speculative decode: acceptance + verifier steps/token
  fleet    multi-tenant fleet: two plans, one budget, per-tenant tok/s
  roofline dry-run roofline table (reads experiments/dryrun/)
  plan     mixed-precision plan Pareto sweep (accuracy proxy vs cost)
  kvplan   per-layer KV-bitwidth sweep (cache bytes/token vs kv loss)

Whenever the ``serve`` and/or ``spec`` benchmarks run, their headline
serving numbers (tok/s, TTFT/ITL p50/p95, acceptance rate) are
consolidated into ``BENCH_serve.json`` at the repo root — the tracked
baseline that makes serving regressions visible in review diffs.
"""
from __future__ import annotations

import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# the headline serving metrics consolidated into BENCH_serve.json
_SERVE_KEYS = ("tok_per_s", "ttft_p50_ms", "ttft_p95_ms",
               "itl_p50_ms", "itl_p95_ms")
_SPEC_KEYS = ("acceptance_rate", "verify_steps_per_token")


def write_bench_serve(results: dict, path=None, history_path=None
                      ) -> dict | None:
    """Consolidate serve/spec results into BENCH_serve.json (repo root).

    Each consolidated run carries its provenance under ``"meta"`` (git
    sha, backend, device, timestamp — see ``benchmarks.history``) and is
    appended to the rolling history ``benchmarks/history.jsonl`` that
    ``python -m repro.obs.regress`` gates against.

    Returns the consolidated dict, or None when neither benchmark ran.
    """
    from . import history

    out = {}
    if "serve" in results:
        out["serve_throughput"] = {
            k: v for k, v in results["serve"].items()
            if k.endswith(_SERVE_KEYS)}
        # SLO-compliance fractions (share of requests/tokens inside the
        # benchmark's TTFT/ITL targets) ride along under their own
        # section; the regress gate's "attainment" band guards them
        slo = {k: v for k, v in results["serve"].items()
               if k.endswith("_attainment")}
        if slo:
            out["slo"] = slo
    if "spec" in results:
        out["spec_decode"] = {
            k: v for k, v in results["spec"].items()
            if k.endswith(_SPEC_KEYS)}
    if "fused" in results:
        # every *_ms row lands under the regress gate's _ms band, so a
        # fused-kernel slowdown vs same-backend history fails CI
        out["fused_attention"] = {
            k: v for k, v in results["fused"].items()
            if k.endswith("_ms")}
    if not out:
        return None
    meta = history.run_metadata()
    out["meta"] = meta
    path = path or REPO_ROOT / "BENCH_serve.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")
    from repro.obs.regress import flatten_metrics
    hpath = history_path or history.HISTORY_PATH
    history.append_entry(
        flatten_metrics({k: v for k, v in out.items() if k != "meta"}),
        hpath, meta=meta)
    print(f"appended to {hpath}")
    return out


def main(argv=None):
    names = (argv if argv is not None else sys.argv[1:]) or [
        "table3", "fig8", "table45", "kernels", "fused", "serve", "spec",
        "fleet", "plan", "kvplan", "table2", "fig10", "roofline"]
    results = {}
    for name in names:
        if name == "table2":
            from . import table2_accuracy as m
        elif name == "fig10":
            from . import fig10_region_sweep as m
        elif name == "table3":
            from . import table3_opcounts as m
        elif name == "fig8":
            from . import fig8_speedup as m
        elif name == "table45":
            from . import table45_hw_cost as m
        elif name == "kernels":
            from . import kernels_bench as m
        elif name == "fused":
            from . import kernels_bench as m
            results[name] = m.run_fused()
            continue
        elif name == "serve":
            from . import serve_throughput as m
        elif name == "spec":
            from . import spec_decode as m
        elif name == "fleet":
            from . import fleet_throughput as m
        elif name == "roofline":
            from . import roofline_table as m
        elif name == "plan":
            from . import plan_pareto as m
        elif name == "kvplan":
            from . import plan_pareto as m
            results[name] = m.run_kv()
            continue
        else:
            raise SystemExit(f"unknown benchmark {name!r}")
        results[name] = m.run()
    write_bench_serve(results)
    print("\nall benchmarks complete:", ", ".join(results))
    return results


if __name__ == "__main__":
    main()
