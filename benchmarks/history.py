"""Append-only benchmark history: ``benchmarks/history.jsonl``.

Every consolidated benchmark run (``python -m benchmarks.run serve
spec``) appends one JSON line here: the flattened headline metrics plus
run metadata (git sha, backend, device kind, jax version, timestamp).
``python -m repro.obs.regress`` compares a fresh ``BENCH_serve.json``
against the rolling baseline of this file and exits non-zero on
regression — the CI gate that keeps serving performance from drifting
silently.

The file is committed: history accumulates across PRs, and the regress
gate always has a baseline to compare against on a fresh clone.
"""
from __future__ import annotations

import datetime
import json
import pathlib
import subprocess

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
HISTORY_PATH = REPO_ROOT / "benchmarks" / "history.jsonl"


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def run_metadata() -> dict:
    """Provenance for one benchmark run: enough to tell whether two
    entries are comparable (same backend) and to trace a regression back
    to the commit that introduced it."""
    meta = {
        "git_sha": _git_sha(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    try:
        import jax
        meta["backend"] = jax.default_backend()
        meta["device"] = jax.devices()[0].device_kind
        meta["jax_version"] = jax.__version__
    except Exception:                                      # pragma: no cover
        meta.update(backend="unknown", device="unknown",
                    jax_version="unknown")
    return meta


def append_entry(metrics: dict, path=None, meta: dict | None = None) -> dict:
    """Append one ``{"meta": ..., "metrics": ...}`` line to the history.

    ``metrics`` is a flat ``{name: float}`` dict (nested BENCH dicts are
    flattened by the caller).  Returns the appended entry.
    """
    path = pathlib.Path(path or HISTORY_PATH)
    entry = {"meta": meta or run_metadata(), "metrics": metrics}
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def load_history(path=None) -> list[dict]:
    """All history entries, oldest first.  Missing file -> ``[]``;
    corrupt lines are skipped (an interrupted append must not take the
    regress gate down)."""
    path = pathlib.Path(path or HISTORY_PATH)
    if not path.exists():
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                entry = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(entry, dict) and "metrics" in entry:
                out.append(entry)
    return out
