"""Paper Table 2: accuracy at 8/6/4/2-bit, dynamic (DQ) vs local (LQ).

Setup mirrors the paper (section VI.E): weights quantized offline to
static 8-bit; inputs/activations at 8/6/4/2-bit, with one scale per layer
(DQ) vs one scale per local region (LQ, region = conv kernel size).
ImageNet/Caffe-zoo is replaced by the synthetic classification task
(DESIGN.md §5, changed assumption a) — the claim validated is the
*qualitative ordering*: no drop at 8-bit, DQ collapses at 2-bit, LQ
survives.
"""
from __future__ import annotations

from repro.models.layers import NO_QUANT

from . import common


def run(verbose: bool = True) -> dict:
    cfg, params, _ = common.trained_reference()
    fp32 = common.top1(params, cfg, NO_QUANT)
    rows = {"fp32": fp32}
    for bits in (8, 6, 4, 2):
        rows[f"dq{bits}"] = common.top1(
            params, cfg, common.ptq_policy(bits, granularity="per_tensor"))
        rows[f"lq{bits}"] = common.top1(
            params, cfg, common.ptq_policy(bits, granularity="per_group"))
    if verbose:
        print("\n== Table 2: top-1 accuracy, DQ vs LQ (paper section VI.E) ==")
        print(f"  fp32 baseline: {fp32:.3f}")
        print(f"  {'bits':>4} {'DQ':>7} {'LQ':>7}   (paper AlexNet: "
              f"2-bit DQ 22.9% vs LQ 46.8%)")
        for bits in (8, 6, 4, 2):
            print(f"  {bits:>4} {rows[f'dq{bits}']:>7.3f} "
                  f"{rows[f'lq{bits}']:>7.3f}")
        ok8 = rows["lq8"] >= fp32 - 0.02
        gap2 = rows["lq2"] - rows["dq2"]
        print(f"  [claim] 8-bit LQ no drop: {ok8};  "
              f"2-bit LQ-DQ gap: +{gap2:.3f}")
    return rows


if __name__ == "__main__":
    run()
