"""Paper Tables 4/5: hardware cost vs bit-width — TPU-analog cost model.

The paper synthesizes an FPGA matrix multiplier per format (FP32x32 /
8x8 / 8x4 / 8x2) and reports LUT/FF area, max frequency and power.  The
TPU has fixed multipliers, so area doesn't vary — the analog costs are
HBM bytes per weight, VMEM residency per 128x128 tile and achievable
arithmetic intensity, which set the memory-roofline performance
(DESIGN.md §5, assumption c).  Paper numbers are printed alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.roofline import HW

PAPER_T4 = {      # config -> (LUT#, FF#, MaxFreq MHz)
    "fp32x32": (17534, 11586, 269),
    "8x8": (1571, 1442, 322),
    "8x4": (923, 962, 532),
    "8x2": (535, 562, 556),
}
PAPER_T5 = {      # config -> (perf, power mW)
    "fp32x32": ("67 Gflops", 643),
    "8x8": ("890 Gops", 71),
    "8x4": ("2502 Gops", 51),
    "8x2": ("4511 Gops", 37),
}


def run(verbose: bool = True) -> dict:
    hw = HW()
    tile = 128 * 128
    rows = {}
    w = jax.random.normal(jax.random.key(0), (4096, 4096))
    for name, w_bits, a_bits in [("fp32x32", None, 32), ("8x8", 8, 8),
                                 ("8x4", 8, 4), ("8x2", 8, 2)]:
        if w_bits is None:
            bytes_per_weight = 4.0
            bytes_per_act = 4.0
        else:
            qw = ops.quantize_weight(w, w_bits, 128)
            bytes_per_weight = qw.nbytes() / w.size
            # paper "8xn": weights 8-bit, inputs n-bit (+ region affine)
            bytes_per_act = a_bits / 8 + 8.0 / 128
        vmem_tile = tile * (bytes_per_weight + bytes_per_act)
        # decode-shaped GEMM (the KV/activation-streaming regime): bytes
        # moved per MAC ~ (w + a) bytes / tile reuse; intensity relative
        # to the streamed operand
        intensity = 2.0 / (bytes_per_weight / 2 + bytes_per_act / 2)
        mem_bound_tflops = intensity * hw.hbm_bw / 1e12
        rows[name] = {
            "bytes_per_weight": bytes_per_weight,
            "bytes_per_act": bytes_per_act,
            "vmem_bytes_per_tile": vmem_tile,
            "arith_intensity": intensity,
            "membound_tflops": mem_bound_tflops,
        }
    if verbose:
        print("\n== Tables 4/5: per-format cost (TPU-analog model) ==")
        print(f"  {'config':>8} {'B/weight':>9} {'B/act':>6} "
              f"{'VMEM/tile':>10} {'mem-bound TF/s':>14}   "
              f"paper LUT#/FF#/power")
        for name, r in rows.items():
            lut, ff, _ = PAPER_T4[name]
            _, mw = PAPER_T5[name]
            print(f"  {name:>8} {r['bytes_per_weight']:>9.2f} "
                  f"{r['bytes_per_act']:>6.2f} "
                  f"{r['vmem_bytes_per_tile'] / 1024:>9.1f}K "
                  f"{r['membound_tflops']:>14.2f}   "
                  f"{lut}/{ff}/{mw}mW")
        print("  [claim] paper: area/power fall superlinearly with width "
              "(FPGA); here: the memory roofline rises as formats shrink "
              "— same deployment economics, TPU currency.")
    return rows


if __name__ == "__main__":
    run()
