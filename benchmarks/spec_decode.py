"""Self-speculative decoding benchmark: acceptance rate + verifier work.

Sweeps draft bitwidth (8/4/2-bit plans of the same checkpoint) and draft
length k against an 8-bit verifier, reporting the two numbers that decide
whether speculation pays: the draft-token acceptance rate and the
verifier steps per emitted token (a plain engine pays exactly 1.0; lower
is decode speedup, floored at 1/k).  Every cell also asserts the safety
property that makes the mode shippable — speculative greedy output is
token-for-token identical to the verifier-only engine, with ONE compiled
trace for the batched verify step.

Wall times on the CPU host are indicative only (the kernels target TPU);
acceptance, steps/token, and parity are exact.

Run:  PYTHONPATH=src python -m benchmarks.spec_decode
"""
from __future__ import annotations

import json

import jax
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.plan import QuantPlan
from repro.plan.plan import candidates_for
from repro.serve import EngineConfig, PagedConfig, RequestParams, Server
from repro.spec import SpeculativeEngine

CFG = ModelConfig(name="spec-bench", family="dense", n_layers=4,
                  d_model=128, vocab_size=512, n_heads=8, n_kv_heads=4,
                  head_dim=16, d_ff=256, dtype="float32", remat="none")

N_REQ, MAX_NEW = 6, 16
VERIFIER = "lq8w"
DRAFTS = ("lq8w", "lq4w", "lq2w")
KS = (2, 4)


def _cell(params, cands, draft: str, k: int, ref: list) -> dict:
    verifier_plan = QuantPlan(default=cands[VERIFIER])
    draft_plan = QuantPlan(default=cands[draft])
    ecfg = EngineConfig(max_len=64, plan=verifier_plan, kv_bits=8,
                        kv_group=16, backend="ref")
    pcfg = PagedConfig(max_slots=3, page_size=8, n_pages=48, max_context=64)
    eng = SpeculativeEngine(CFG, params, ecfg, pcfg,
                            draft_plan=draft_plan, spec_k=k)
    server = Server(CFG, params, ecfg, pcfg, engine=eng)
    outs = _drive(server)
    assert outs == ref, f"speculative output diverged at draft={draft} k={k}"
    assert eng.decode_compilations == 1    # one batched verify trace
    assert eng.draft_compilations == 1
    spt = eng.verify_steps_per_token()
    if k >= 2 and draft != "lq2w":
        assert spt < 1.0, f"no verifier saving at draft={draft} k={k}"
    return {"acceptance_rate": eng.acceptance_rate(),
            "verify_steps_per_token": spt,
            "rejected_tokens": server.scheduler.stats()["rejected_tokens"],
            "shared_weight_bytes": eng.shared_weight_bytes(),
            "draft_pool_bytes": server.pool.draft_nbytes()}


def _prompts():
    rng = np.random.default_rng(17)
    return [list(map(int, rng.integers(0, CFG.vocab_size, size=int(n))))
            for n in rng.integers(6, 20, size=N_REQ)]


def _drive(server) -> list:
    rids = []
    for p in _prompts():
        rids.append(server.submit(p, RequestParams(max_new_tokens=MAX_NEW)))
        server.step()
    outs = server.drain(max_steps=2000)
    return [outs[r] for r in rids]


def run(verbose: bool = True) -> dict:
    params = transformer.init_params(CFG, jax.random.key(0))
    cands = candidates_for(CFG, list(DRAFTS))
    # the verifier-only reference stream (the parity bar for every cell)
    ecfg = EngineConfig(max_len=64, plan=QuantPlan(default=cands[VERIFIER]),
                        kv_bits=8, kv_group=16, backend="ref")
    pcfg = PagedConfig(max_slots=3, page_size=8, n_pages=48, max_context=64)
    ref = _drive(Server(CFG, params, ecfg, pcfg))

    rows = {}
    for draft in DRAFTS:
        for k in KS:
            cell = _cell(params, cands, draft, k, ref)
            for key, v in cell.items():
                rows[f"{draft}_k{k}_{key}"] = v

    if verbose:
        print(f"\n== self-speculative decode ({N_REQ} reqs x {MAX_NEW} "
              f"toks, verifier {VERIFIER}, token-exact in every cell) ==")
        print(f"{'draft':>6} {'k':>3} {'accept':>8} {'verify-steps/tok':>17} "
              f"{'rejected':>9} {'shared-KiB':>11}")
        for draft in DRAFTS:
            for k in KS:
                p = f"{draft}_k{k}_"
                print(f"{draft:>6} {k:>3} "
                      f"{rows[p + 'acceptance_rate']:>8.3f} "
                      f"{rows[p + 'verify_steps_per_token']:>17.3f} "
                      f"{rows[p + 'rejected_tokens']:>9} "
                      f"{rows[p + 'shared_weight_bytes'] / 1024:>11.1f}")
        print("(steps/token: plain decode pays 1.0; floor is 1/k; "
              "identical draft==verifier plans hit it)")
    return rows


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
