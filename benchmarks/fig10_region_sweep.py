"""Paper Fig. 10: smaller local quantization regions -> better accuracy
at 2-bit (section VI.F; VGG-16 top-1 50.2% -> 68.3% with smaller regions).

Swept here as the group-size of the 2-bit activation quantizer on the
trained reference CNN; monotone improvement with shrinking regions is the
validated claim (plus the exact-MSE monotonicity test in
tests/test_quantize.py::test_region_monotonicity).
"""
from __future__ import annotations

from . import common


def run(verbose: bool = True) -> dict:
    cfg, params, _ = common.trained_reference()
    rows = {}
    for gs in (432, 108, 27, 9):
        rows[gs] = common.top1(
            params, cfg,
            common.ptq_policy(2, granularity="per_group", group_size=gs))
    if verbose:
        print("\n== Fig. 10: 2-bit accuracy vs local region size ==")
        for gs, acc in rows.items():
            print(f"  region {gs:>4}: top-1 {acc:.3f}")
        accs = list(rows.values())
        print(f"  [claim] smaller regions help: "
              f"{accs[-1] > accs[0]} (Δ=+{accs[-1] - accs[0]:.3f})")
    return rows


if __name__ == "__main__":
    run()
