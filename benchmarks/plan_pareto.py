"""Mixed-precision plan Pareto sweep: accuracy proxy vs modeled cost.

Sweeps a weight-byte budget between the uniform-narrow and uniform-wide
plans on a small transformer and emits the planner's (cost, KL-loss)
frontier as JSON, alongside the uniform-scheme points.  The planner's
acceptance bar — a searched plan strictly inside the uniform frontier
(cheaper than uniform-8 at lower sensitivity loss than uniform-2) — is
checked here and asserted in tests/test_plan.py.

``--kv`` (or :func:`run_kv`) sweeps the *cache* axis instead: per-layer
KV bitwidths searched over {8, 4, 2, 1}-bit wire formats against the
uniform-kv points {8, 4, 2}, in exact cache bytes/token.  The bar is the
same box: some genuinely mixed kv map strictly inside the uniform-kv
frontier (fewer bytes/token than uniform-8 at lower kv fake-quant loss
than uniform-2), plus a count of the uniform points each mixed plan
dominates outright.

Run:  PYTHONPATH=src python -m benchmarks.plan_pareto [--kv]
"""
from __future__ import annotations

import json
import sys

import jax

from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.plan import (QuantPlan, candidate_costs, greedy_search,
                        kv_bits_of_label, kv_candidate_costs, kv_label,
                        pareto_frontier, plan_kv_cost,
                        profile_kv_sensitivity, profile_sensitivity,
                        uniform_result)
from repro.plan.plan import candidates_for

CFG = ModelConfig(name="plan-bench", family="dense", n_layers=4,
                  d_model=64, vocab_size=256, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, dtype="float32", remat="none")

SCHEMES = ("lq8w", "lq4w", "lq2w")
KV_CANDIDATES = (8, 4, 2, 1)       # searched cache bitwidths
KV_UNIFORMS = (8, 4, 2)            # the uniform-kv comparison points
KV_GROUP = 16                      # divides head_dim
N_BUDGETS = 5
METRIC = "kl"


def _calib_params():
    params = transformer.init_params(CFG, jax.random.key(0))
    data = SyntheticLM(DataConfig(vocab_size=CFG.vocab_size, seq_len=32,
                                  global_batch=4, seed=7))
    batches = [{"tokens": data.batch(i)["tokens"]} for i in range(2)]
    return params, batches


def _profile():
    params, batches = _calib_params()
    cands = candidates_for(CFG, SCHEMES)
    prof = profile_sensitivity(params, CFG, batches, cands)
    costs = {l: {s: c.to_dict() for s, c in row.items()}
             for l, row in candidate_costs(CFG, cands).items()}
    return prof, costs


def run(verbose: bool = True) -> dict:
    prof, costs = _profile()
    uniforms = {s: uniform_result(s, prof.losses, costs, loss_key=METRIC)
                for s in SCHEMES}
    wide, narrow = uniforms[SCHEMES[0]], uniforms[SCHEMES[-1]]

    rows = []
    for i in range(N_BUDGETS):
        frac = (i + 1) / (N_BUDGETS + 1)
        budget = narrow.cost + frac * (wide.cost - narrow.cost)
        r = greedy_search(prof.losses, costs, budget=budget,
                          loss_key=METRIC)
        rows.append({"budget_bytes": budget, "bytes": r.cost,
                     "loss": r.loss, "feasible": r.feasible,
                     "assignment": dict(r.assignment)})

    frontier = pareto_frontier(
        [(r["bytes"], r["loss"]) for r in rows]
        + [(u.cost, u.loss) for u in uniforms.values()])
    # the acceptance bar: some searched plan strictly beats the box
    # spanned by uniform-wide cost and uniform-narrow loss
    inside = any(r["bytes"] < wide.cost and r["loss"] < narrow.loss
                 and len(set(r["assignment"].values())) > 1 for r in rows)

    out = {
        "model": CFG.name, "schemes": list(SCHEMES), "metric": METRIC,
        "uniform": {s: {"bytes": u.cost, "loss": u.loss}
                    for s, u in uniforms.items()},
        "planned": rows,
        "frontier": frontier,
        "mixed_plan_inside_uniform_frontier": inside,
        "sensitivity": prof.to_dict(),
    }
    if verbose:
        print(f"\n== mixed-precision plan Pareto ({CFG.name}, "
              f"{CFG.n_layers} layers) ==")
        print(f"  {'point':>16} {'bytes':>10} {METRIC:>12}")
        for s, u in uniforms.items():
            print(f"  {'uniform ' + s:>16} {u.cost:>10,.0f} {u.loss:>12.3e}")
        for r in rows:
            mix = "+".join(sorted(set(r["assignment"].values())))
            print(f"  {'plan ' + mix:>16} {r['bytes']:>10,.0f} "
                  f"{r['loss']:>12.3e}")
        print(f"  mixed plan strictly inside uniform frontier: {inside}")
    return out


# ---------------------------------------------------------------------------
# per-layer KV-bitwidth sweep (cache bytes/token vs kv fake-quant loss)
# ---------------------------------------------------------------------------

def run_kv(verbose: bool = True) -> dict:
    params, batches = _calib_params()
    kv_sens = profile_kv_sensitivity(params, CFG, batches, KV_CANDIDATES,
                                     kv_group=KV_GROUP)
    kv_costs = kv_candidate_costs(CFG, KV_CANDIDATES, kv_group=KV_GROUP)
    uniforms = {b: uniform_result(kv_label(b), kv_sens, kv_costs,
                                  cost_key="bytes_per_token",
                                  loss_key=METRIC)
                for b in KV_UNIFORMS}
    wide, narrow = uniforms[KV_UNIFORMS[0]], uniforms[KV_UNIFORMS[-1]]

    rows = []
    for i in range(N_BUDGETS):
        frac = (i + 1) / (N_BUDGETS + 1)
        budget = narrow.cost + frac * (wide.cost - narrow.cost)
        r = greedy_search(kv_sens, kv_costs, budget=budget,
                          cost_key="bytes_per_token", loss_key=METRIC)
        kv_map = {l: kv_bits_of_label(s) for l, s in r.assignment.items()}
        plan = QuantPlan.from_assignment(
            {}, default="fp32", kv_bits=kv_map, kv_group=KV_GROUP,
            meta={"origin": "plan_pareto --kv",
                  "budget_bytes_per_token": budget})
        exact = plan_kv_cost(CFG, plan.resolve_kv(CFG), kv_group=KV_GROUP)
        assert exact["bytes_per_token"] == r.cost    # cost model is exact
        dominated = sum(1 for u in uniforms.values()
                        if r.cost < u.cost and r.loss <= u.loss)
        rows.append({"budget_bytes_per_token": budget,
                     "bytes_per_token": r.cost, "loss": r.loss,
                     "feasible": r.feasible, "kv_bits": kv_map,
                     "mixed": len(set(kv_map.values())) > 1,
                     "uniform_points_dominated": dominated,
                     "plan": json.loads(plan.to_json())})

    frontier = pareto_frontier(
        [(r["bytes_per_token"], r["loss"]) for r in rows]
        + [(u.cost, u.loss) for u in uniforms.values()])
    # the acceptance bar: some genuinely mixed kv map strictly beats the
    # box spanned by uniform-8 bytes/token and uniform-2 loss
    inside = any(r["mixed"] and r["bytes_per_token"] < wide.cost
                 and r["loss"] < narrow.loss for r in rows)

    out = {
        "model": CFG.name, "kv_candidates": list(KV_CANDIDATES),
        "kv_uniforms": list(KV_UNIFORMS), "kv_group": KV_GROUP,
        "metric": METRIC,
        "uniform": {kv_label(b): {"bytes_per_token": u.cost, "loss": u.loss}
                    for b, u in uniforms.items()},
        "planned": rows,
        "frontier": frontier,
        "mixed_kv_inside_uniform_frontier": inside,
        "kv_sensitivity": kv_sens,
    }
    if verbose:
        print(f"\n== per-layer KV-bitwidth Pareto ({CFG.name}, "
              f"{CFG.n_layers} layers, group {KV_GROUP}) ==")
        print(f"  {'point':>20} {'B/token':>9} {METRIC:>12}")
        for b, u in uniforms.items():
            print(f"  {'uniform kv' + str(b):>20} {u.cost:>9,.0f} "
                  f"{u.loss:>12.3e}")
        for r in rows:
            mix = "+".join(str(b) for b in
                           sorted(set(r["kv_bits"].values()), reverse=True))
            print(f"  {'kv plan ' + mix:>20} {r['bytes_per_token']:>9,.0f} "
                  f"{r['loss']:>12.3e}  dominates "
                  f"{r['uniform_points_dominated']}/{len(uniforms)} uniforms")
        print(f"  mixed kv plan strictly inside uniform-kv frontier: "
              f"{inside}")
    return out


if __name__ == "__main__":
    if "--kv" in sys.argv[1:]:
        print(json.dumps(run_kv(), indent=2))
    else:
        print(json.dumps(run(), indent=2))
