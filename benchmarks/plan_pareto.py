"""Mixed-precision plan Pareto sweep: accuracy proxy vs modeled cost.

Sweeps a weight-byte budget between the uniform-narrow and uniform-wide
plans on a small transformer and emits the planner's (cost, KL-loss)
frontier as JSON, alongside the uniform-scheme points.  The planner's
acceptance bar — a searched plan strictly inside the uniform frontier
(cheaper than uniform-8 at lower sensitivity loss than uniform-2) — is
checked here and asserted in tests/test_plan.py.

Run:  PYTHONPATH=src python -m benchmarks.plan_pareto
"""
from __future__ import annotations

import json

import jax

from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.plan import (candidate_costs, greedy_search, pareto_frontier,
                        profile_sensitivity, uniform_result)
from repro.plan.plan import candidates_for

CFG = ModelConfig(name="plan-bench", family="dense", n_layers=4,
                  d_model=64, vocab_size=256, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, dtype="float32", remat="none")

SCHEMES = ("lq8w", "lq4w", "lq2w")
N_BUDGETS = 5
METRIC = "kl"


def _profile():
    params = transformer.init_params(CFG, jax.random.key(0))
    data = SyntheticLM(DataConfig(vocab_size=CFG.vocab_size, seq_len=32,
                                  global_batch=4, seed=7))
    batches = [{"tokens": data.batch(i)["tokens"]} for i in range(2)]
    cands = candidates_for(CFG, SCHEMES)
    prof = profile_sensitivity(params, CFG, batches, cands)
    costs = {l: {s: c.to_dict() for s, c in row.items()}
             for l, row in candidate_costs(CFG, cands).items()}
    return prof, costs


def run(verbose: bool = True) -> dict:
    prof, costs = _profile()
    uniforms = {s: uniform_result(s, prof.losses, costs, loss_key=METRIC)
                for s in SCHEMES}
    wide, narrow = uniforms[SCHEMES[0]], uniforms[SCHEMES[-1]]

    rows = []
    for i in range(N_BUDGETS):
        frac = (i + 1) / (N_BUDGETS + 1)
        budget = narrow.cost + frac * (wide.cost - narrow.cost)
        r = greedy_search(prof.losses, costs, budget=budget,
                          loss_key=METRIC)
        rows.append({"budget_bytes": budget, "bytes": r.cost,
                     "loss": r.loss, "feasible": r.feasible,
                     "assignment": dict(r.assignment)})

    frontier = pareto_frontier(
        [(r["bytes"], r["loss"]) for r in rows]
        + [(u.cost, u.loss) for u in uniforms.values()])
    # the acceptance bar: some searched plan strictly beats the box
    # spanned by uniform-wide cost and uniform-narrow loss
    inside = any(r["bytes"] < wide.cost and r["loss"] < narrow.loss
                 and len(set(r["assignment"].values())) > 1 for r in rows)

    out = {
        "model": CFG.name, "schemes": list(SCHEMES), "metric": METRIC,
        "uniform": {s: {"bytes": u.cost, "loss": u.loss}
                    for s, u in uniforms.items()},
        "planned": rows,
        "frontier": frontier,
        "mixed_plan_inside_uniform_frontier": inside,
        "sensitivity": prof.to_dict(),
    }
    if verbose:
        print(f"\n== mixed-precision plan Pareto ({CFG.name}, "
              f"{CFG.n_layers} layers) ==")
        print(f"  {'point':>16} {'bytes':>10} {METRIC:>12}")
        for s, u in uniforms.items():
            print(f"  {'uniform ' + s:>16} {u.cost:>10,.0f} {u.loss:>12.3e}")
        for r in rows:
            mix = "+".join(sorted(set(r["assignment"].values())))
            print(f"  {'plan ' + mix:>16} {r['bytes']:>10,.0f} "
                  f"{r['loss']:>12.3e}")
        print(f"  mixed plan strictly inside uniform frontier: {inside}")
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
