"""Paper Fig. 8: speedup of fixed-point over fp32 (paper: ~2x on Edison).

Two measurements stand in for the Edison board (DESIGN.md §5, assumption
b):
  (1) measured CPU wall-clock: int8 GEMM (int32 accumulate) vs fp32 GEMM
      on this host — the direct analogue of the paper's experiment;
  (2) the TPU roofline model: decode/serving GEMMs are HBM-bound, so
      projected speedup = fp bytes / packed bytes per weight
      (16/bits for bf16 baseline), the deployment-relevant number.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.obs import time_fn


def _time(fn, *args, reps=5):
    return time_fn(fn, *args, reps=reps)


def run(verbose: bool = True, n: int = 1024) -> dict:
    key = jax.random.key(0)
    a = jax.random.normal(key, (n, n), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, n), jnp.float32)
    a8 = (a * 16).astype(jnp.int8)
    b8 = (b * 16).astype(jnp.int8)

    f32 = jax.jit(lambda x, y: x @ y)
    i8 = jax.jit(lambda x, y: jax.lax.dot(
        x, y, preferred_element_type=jnp.int32))

    t_f32 = _time(f32, a, b)
    t_i8 = _time(i8, a8, b8)

    rows = {"cpu_fp32_s": t_f32, "cpu_int8_s": t_i8,
            "cpu_speedup": t_f32 / t_i8}
    # TPU roofline projection: HBM bytes per weight at each width
    w = jax.random.normal(key, (4096, 4096))
    fp_bytes = w.size * 2                          # bf16 deployment baseline
    for bits in (8, 4, 2):
        qw = ops.quantize_weight(w, bits, 128)
        rows[f"tpu_proj_speedup_{bits}bit"] = fp_bytes / qw.nbytes()

    if verbose:
        print("\n== Fig. 8: fixed-point speedup ==")
        print(f"  CPU GEMM {n}^3: fp32 {t_f32 * 1e3:.1f} ms, "
              f"int8 {t_i8 * 1e3:.1f} ms -> {t_f32 / t_i8:.2f}x "
              f"(paper: ~2x on Edison)")
        for bits in (8, 4, 2):
            print(f"  TPU memory-roofline projection {bits}-bit: "
                  f"{rows[f'tpu_proj_speedup_{bits}bit']:.1f}x over bf16")
    return rows


if __name__ == "__main__":
    run()
