"""Shared benchmark utilities: trained reference CNN + PTQ evaluation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import schemes
from repro.data import SyntheticClassification
from repro.models import convnet
from repro.models.layers import QuantPolicy
from repro.optim import adamw, apply_updates


def make_task(seed: int = 0):
    """The stand-in for the paper's image-classification task."""
    cfg = convnet.MINI_CNN
    data = SyntheticClassification(
        n_classes=cfg.n_classes, dim=cfg.input_hw * cfg.input_hw * cfg.in_ch,
        global_batch=128, seed=seed, noise=1.6)
    return cfg, data


def _images(cfg, batch):
    return batch["x"].reshape(-1, cfg.input_hw, cfg.input_hw, cfg.in_ch)


@functools.lru_cache(maxsize=1)
def trained_reference(steps: int = 400, seed: int = 0):
    """Train the fp32 reference model once; cached across benchmarks."""
    cfg, data = make_task(seed)
    params = convnet.init_params(cfg, jax.random.key(seed))
    opt = adamw(1e-2, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        def loss_fn(p):
            logits = convnet.apply(p, cfg, _images(cfg, batch))
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(
                logp, batch["y"][:, None], axis=1).mean()
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, state = opt.update(grads, state, params)
        return apply_updates(params, updates), state, loss

    for i in range(steps):
        params, state, loss = step(params, state, data.batch(i))
    return cfg, params, float(loss)


def top1(params, cfg, policy: QuantPolicy, *, n_batches: int = 8,
         seed: int = 1234) -> float:
    """Validation top-1 under a quantization policy (held-out stream)."""
    _, data = make_task(0)
    correct = total = 0

    @jax.jit
    def logits_of(batch):
        return convnet.apply(params, cfg, _images(cfg, batch),
                             policy=policy)

    for i in range(n_batches):
        batch = data.batch(seed + i)          # indices never seen in training
        pred = jnp.argmax(logits_of(batch), axis=-1)
        correct += int((pred == batch["y"]).sum())
        total += batch["y"].shape[0]
    return correct / total


def ptq_policy(a_bits: int | None, *, w_bits: int | None = 8,
               granularity: str = "per_group", group_size: int = 27):
    """Paper Table-2 setup: weights static 8-bit, inputs a_bits, DQ vs LQ.

    Default region 27 = the mini CNN's conv kernel size (3x3x3), mirroring
    the paper's region = kernel size choice (section VI.D).
    """
    cfg = schemes.QuantConfig(w_bits=w_bits, a_bits=a_bits,
                              granularity=granularity,
                              group_size=group_size)
    return QuantPolicy.qat(cfg)   # fake-quant forward = PTQ numerics
