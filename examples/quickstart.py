"""Quickstart: the paper's technique end to end in ~60 lines.

1. quantize a weight matrix into local quantization regions (8..1-bit),
2. run the packed-weight matmul and inspect the error/bytes trade-off,
3. apply the same scheme to a whole transformer and serve it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serve import Engine, EngineConfig

# --- 1. one projection -----------------------------------------------------
key = jax.random.key(0)
w = jax.random.normal(key, (1024, 1024))
x = jax.random.normal(jax.random.fold_in(key, 1), (8, 1024))
exact = x @ w

print("bits  weight-bytes   max-rel-error")
for bits in (8, 4, 2, 1):
    qw = ops.quantize_weight(w, bits, group_size=128)   # LQ regions along K
    out = ops.quant_matmul(x, qw, backend="ref")        # fused dequant-matmul
    rel = float(jnp.abs(out - exact).max() / jnp.abs(exact).max())
    print(f"{bits:>4}  {qw.nbytes():>12,}   {rel:.4f}")

# --- 2. a whole model ------------------------------------------------------
cfg = ModelConfig(name="demo", family="dense", n_layers=4, d_model=128,
                  vocab_size=512, n_heads=8, n_kv_heads=4, d_ff=256,
                  dtype="float32")
params = transformer.init_params(cfg, key)
prompt = {"tokens": jax.random.randint(key, (2, 16), 0, 512, jnp.int32)}

fp = Engine(cfg, params, EngineConfig(max_len=64))
lq = Engine(cfg, params, EngineConfig(max_len=64, weight_scheme="lq8w",
                                      kv_bits=8, kv_group=16,
                                      backend="ref"))
out_fp, _ = fp.generate(prompt, steps=12)
out_lq, _ = lq.generate(prompt, steps=12)

print("\nfp32 tokens :", out_fp[0].tolist())
print("lq8  tokens :", out_lq[0].tolist())
print("agreement   :", float((out_fp == out_lq).mean()))
print("cache bytes : fp", fp.cache_bytes(2), "-> lq8", lq.cache_bytes(2))
