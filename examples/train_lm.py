"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Exercises the full production stack on CPU: synthetic sharded data,
AdamW + cosine schedule, microbatch gradient accumulation, LQ gradient
compression (the paper's format on the DP all-reduce), atomic
checkpoints, and kill-resume fault tolerance (the run checkpoints every
50 steps; re-running this script resumes from the newest one).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.data import DataConfig, SyntheticLM
from repro.models.config import ModelConfig
from repro.optim import warmup_cosine
from repro.train import TrainHParams, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 12 x d512 GQA blocks + 32k vocab
    cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                      d_model=512, vocab_size=32000, n_heads=8,
                      n_kv_heads=4, d_ff=2048, dtype="float32",
                      remat="none")
    n = cfg.param_count()
    print(f"model: {n / 1e6:.1f}M params")

    data = SyntheticLM(DataConfig(vocab_size=32000, seq_len=256,
                                  global_batch=16))
    hp = TrainHParams(
        lr=warmup_cosine(3e-4, warmup_steps=50, total_steps=args.steps),
        microsteps=2,
        grad_compress_bits=8,        # paper-format compressed all-reduce
        clip_norm=1.0)
    trainer = Trainer(cfg, hp, data,
                      TrainerConfig(total_steps=args.steps, ckpt_every=50,
                                    ckpt_dir=args.ckpt_dir, log_every=20))
    trainer.run()
    h = trainer.history
    print(f"\nloss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over "
          f"{len(h)} steps "
          f"({1e3 * sum(r['wall_s'] for r in h[1:]) / max(len(h) - 1, 1):.0f}"
          f" ms/step)")
    print(f"checkpoints in {args.ckpt_dir} — re-run to resume.")


if __name__ == "__main__":
    main()
