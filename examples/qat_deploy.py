"""QAT -> deploy: train *through* the quantizer, then serve packed.

Beyond-paper workflow: the paper is post-training quantization; QAT
(straight-through gradients through the local-region rounding) recovers
most of the 2-bit gap.  This example trains a small LM twice — fp32 and
2-bit-QAT — then evaluates both under 2-bit deployment.

Run:  PYTHONPATH=src python examples/qat_deploy.py
"""
import jax
import jax.numpy as jnp

from repro.core import schemes
from repro.data import DataConfig, SyntheticLM
from repro.models.config import ModelConfig
from repro.models.layers import QuantPolicy
from repro.train import TrainHParams, Trainer, TrainerConfig, loss_fn

cfg = ModelConfig(name="qat-demo", family="dense", n_layers=4, d_model=128,
                  vocab_size=1024, n_heads=8, n_kv_heads=4, d_ff=256,
                  dtype="float32", remat="none")
data = SyntheticLM(DataConfig(vocab_size=1024, seq_len=64, global_batch=16))
STEPS = 120

q2 = schemes.QuantConfig(w_bits=2, a_bits=None, granularity="per_group",
                         group_size=32)


def eval_loss(params, policy):
    batch = data.batch(10_000)                      # held-out index range
    total, _ = loss_fn(params, cfg, batch, policy=policy,
                       hp=TrainHParams())
    return float(total)


runs = {}
for name, policy in [("fp32-train", QuantPolicy.train_fp()),
                     ("qat2-train", QuantPolicy.qat(q2))]:
    tr = Trainer(cfg, TrainHParams(lr=2e-3), data,
                 TrainerConfig(total_steps=STEPS, log_every=1000),
                 policy=policy)
    state = tr.run()
    runs[name] = state.params
    print(f"{name}: final train loss {tr.history[-1]['loss']:.3f}")

deploy = QuantPolicy.qat(q2)                        # 2-bit deployment numerics
print("\n          eval@fp32   eval@2-bit-LQ")
for name, params in runs.items():
    print(f"{name:>10}  {eval_loss(params, QuantPolicy.train_fp()):>8.3f}"
          f"   {eval_loss(params, deploy):>8.3f}")
print("\n[claim] QAT closes most of the 2-bit deployment gap the PTQ "
      "model pays.")
