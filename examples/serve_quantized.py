"""Continuous-batching serving demo across quantization schemes.

The paper's deployment story at serving time: the same checkpoint served
with fp32 and 8/4/2-bit local-quantization-region weights + quantized
paged KV cache.  A stream of staggered requests flows through the
continuous-batching layer (serve/server.py); per scheme we report

  * agree   — token agreement vs the fp32 run (paper Tables 1/2 trade),
  * exact   — continuous batching reproduces the solo engine's greedy
              tokens request-for-request (the scheduler is lossless),
  * tok/s, pool bytes, weight bytes.

Run:  PYTHONPATH=src python examples/serve_quantized.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.obs import Stopwatch
from repro.models.config import ModelConfig
from repro.serve import (Engine, EngineConfig, PagedConfig, RequestParams,
                         Server)

cfg = ModelConfig(name="serve-demo", family="dense", n_layers=6,
                  d_model=256, vocab_size=2048, n_heads=8, n_kv_heads=4,
                  d_ff=512, dtype="float32", remat="none")

# brief training so generations are structured (quantization agreement on
# random weights is meaningless — logits are noise-level ties)
from repro.data import DataConfig, SyntheticLM          # noqa: E402
from repro.train import TrainHParams, Trainer, TrainerConfig  # noqa: E402

_data = SyntheticLM(DataConfig(vocab_size=2048, seq_len=64,
                               global_batch=16))
_tr = Trainer(cfg, TrainHParams(lr=2e-3), _data,
              TrainerConfig(total_steps=80, log_every=1000))
params = _tr.run().params
print(f"[setup] trained 80 steps: loss {_tr.history[0]['loss']:.2f} -> "
      f"{_tr.history[-1]['loss']:.2f}\n")

N_REQ, MAX_NEW = 8, 24
rng = np.random.default_rng(7)
prompts = [list(map(int, rng.integers(0, 2048, size=int(n))))
           for n in rng.integers(8, 28, size=N_REQ)]
pcfg = PagedConfig(max_slots=4, page_size=8, n_pages=64, max_context=64)

schemes = [("fp32", None, None), ("lq8w+kv8", "lq8w", 8),
           ("lq4w+kv4", "lq4w", 4), ("lq2w+kv4", "lq2w", 4)]

ref_outs = None
print(f"{'scheme':>10} {'agree':>7} {'exact':>6} {'tok/s':>8} "
      f"{'pool-bytes':>11} {'weight-bytes':>13}")
for name, scheme, kv_bits in schemes:
    ecfg = EngineConfig(max_len=64, weight_scheme=scheme, kv_bits=kv_bits,
                        kv_group=16, backend="ref")
    # solo reference: one request at a time through the contiguous engine
    solo = Engine(cfg, params, ecfg)
    solo_outs = []
    for p in prompts:
        out, _ = solo.generate({"tokens": jnp.asarray([p], jnp.int32)},
                               steps=MAX_NEW - 1)
        solo_outs.append(np.asarray(out)[0].tolist())

    # continuous batching: staggered arrivals share the paged pool
    server = Server(cfg, params, ecfg, pcfg)
    server.submit(prompts[0], RequestParams(max_new_tokens=2))
    server.drain()                          # warm both jits off the clock
    sw = Stopwatch()
    rids = []
    for p in prompts:
        rids.append(server.submit(p, RequestParams(max_new_tokens=MAX_NEW)))
        server.step()                       # arrivals interleave with decode
    outs = server.drain()
    dt = sw.elapsed()

    got = [outs[r] for r in rids]
    exact = all(a == b for a, b in zip(got, solo_outs))
    if ref_outs is None:
        ref_outs = got
    agree = float(np.mean([np.mean(np.asarray(a) == np.asarray(b))
                           for a, b in zip(got, ref_outs)]))
    wbytes = sum(x.size * x.dtype.itemsize
                 for x in jax.tree.leaves(server.engine.params))
    print(f"{name:>10} {agree:>7.2f} {str(exact):>6} "
          f"{N_REQ * MAX_NEW / dt:>8.1f} {server.pool.nbytes():>11,} "
          f"{wbytes:>13,}")
