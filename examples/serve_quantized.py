"""Serve a small model with batched requests across quantization schemes.

The paper's deployment story: the same checkpoint served at fp32 and at
8/4/2-bit local-quantization-region weights (+ quantized KV cache),
reporting output agreement vs fp32 and the memory footprint — the
accuracy/cost trade-off of paper Tables 1/2 at serving time.

Run:  PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serve import Engine, EngineConfig

cfg = ModelConfig(name="serve-demo", family="dense", n_layers=6,
                  d_model=256, vocab_size=2048, n_heads=8, n_kv_heads=4,
                  d_ff=512, dtype="float32", remat="none")

# brief training so generations are structured (quantization agreement on
# random weights is meaningless — logits are noise-level ties)
from repro.data import DataConfig, SyntheticLM          # noqa: E402
from repro.train import TrainHParams, Trainer, TrainerConfig  # noqa: E402

_data = SyntheticLM(DataConfig(vocab_size=2048, seq_len=64,
                               global_batch=16))
_tr = Trainer(cfg, TrainHParams(lr=2e-3), _data,
              TrainerConfig(total_steps=80, log_every=1000))
params = _tr.run().params
print(f"[setup] trained 80 steps: loss {_tr.history[0]['loss']:.2f} -> "
      f"{_tr.history[-1]['loss']:.2f}\n")

BATCH, PROMPT, STEPS = 8, 24, 32
requests = {"tokens": jax.random.randint(jax.random.key(7),
                                         (BATCH, PROMPT), 0, 2048,
                                         jnp.int32)}

schemes = [("fp32", None, None), ("lq8w+kv8", "lq8w", 8),
           ("lq4w+kv4", "lq4w", 4), ("lq2w+kv4", "lq2w", 4)]

ref_out = None
print(f"{'scheme':>10} {'agree':>7} {'tok/s':>8} {'cache-bytes':>12} "
      f"{'weight-bytes':>13}")
for name, scheme, kv_bits in schemes:
    eng = Engine(cfg, params, EngineConfig(
        max_len=PROMPT + STEPS + 8, weight_scheme=scheme, kv_bits=kv_bits,
        kv_group=16, backend="ref"))
    out, _ = eng.generate(requests, steps=STEPS)        # compile+run
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out, _ = eng.generate(requests, steps=STEPS)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    if ref_out is None:
        ref_out = out
    agree = float((out == ref_out).mean())
    wbytes = sum(
        x.size * x.dtype.itemsize
        for x in jax.tree.leaves(eng.params))
    print(f"{name:>10} {agree:>7.2f} {BATCH * (STEPS + 1) / dt:>8.1f} "
          f"{eng.cache_bytes(BATCH):>12,} {wbytes:>13,}")
