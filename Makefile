# Developer entry points.  PYTHONPATH is injected so no install is needed.
PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: test smoke quickstart serve-demo bench

test:        ## tier-1: the full pytest suite
	$(PY) -m pytest -x -q

quickstart:  ## end-to-end quantize/serve example
	$(PY) examples/quickstart.py

smoke: test quickstart  ## tier-1 tests + quickstart example

serve-demo:  ## continuous-batching demo across quantization schemes
	$(PY) examples/serve_quantized.py

bench:       ## all paper benchmarks + serve throughput
	$(PY) -m benchmarks.run
