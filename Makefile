# Developer entry points.  PYTHONPATH is injected so no install is needed.
PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: test smoke quickstart serve-demo bench plan-smoke kv-plan-smoke \
	fleet-smoke spec-smoke obs-smoke numerics-smoke perf-smoke \
	fused-smoke slo-smoke

test:        ## tier-1: the full pytest suite
	$(PY) -m pytest -x -q

quickstart:  ## end-to-end quantize/serve example
	$(PY) examples/quickstart.py

smoke: test quickstart  ## tier-1 tests + quickstart example

serve-demo:  ## continuous-batching demo across quantization schemes
	$(PY) examples/serve_quantized.py

bench:       ## all paper benchmarks + serve throughput
	$(PY) -m benchmarks.run

plan-smoke:  ## mixed-precision planner: profile -> search -> serve a plan
	$(PY) -m repro.launch.plan --arch llama3.2-1b \
	    --schemes lq8w,lq4w,lq2w --budget-mb 0.06 --out /tmp/plan_smoke.json
	$(PY) -m repro.launch.serve --arch llama3.2-1b \
	    --plan /tmp/plan_smoke.json --steps 8
	$(PY) -m benchmarks.run plan

kv-plan-smoke: ## joint weight x kv plan -> serve via heterogeneous pool
	$(PY) -m repro.launch.plan --arch llama3.2-1b \
	    --schemes lq8w,lq4w,lq2w --budget-mb 0.075 \
	    --kv 8,4,2 --kv-group 16 --kv-tokens 256 \
	    --out /tmp/kv_plan_smoke.json
	$(PY) -m repro.launch.serve --arch llama3.2-1b \
	    --plan /tmp/kv_plan_smoke.json --continuous 3 \
	    --max-slots 2 --page-size 8 --n-pages 32 \
	    --prompt-len 12 --steps 6
	$(PY) -m benchmarks.run kvplan

spec-smoke:  ## search a 2-bit draft plan -> speculative serve parity bench
	$(PY) -m repro.launch.plan --arch llama3.2-1b \
	    --schemes lq2w --budget-mb 1 --out /tmp/spec_draft_smoke.json
	$(PY) -m repro.launch.serve --arch llama3.2-1b --scheme lq8w \
	    --continuous 3 --spec-plan /tmp/spec_draft_smoke.json --spec-k 3 \
	    --max-slots 2 --page-size 8 --n-pages 32 \
	    --prompt-len 12 --steps 6
	$(PY) -m benchmarks.run spec

obs-smoke:   ## serve with tracing + metrics + quality probes, validate all
	$(PY) -m repro.launch.serve --arch llama3.2-1b --continuous 3 \
	    --max-slots 2 --page-size 8 --n-pages 32 \
	    --prompt-len 12 --steps 6 \
	    --kv-bits 8 --kv-group 16 \
	    --numerics --numerics-every 2 \
	    --flight-out /tmp/obs_smoke_flight.json \
	    --trace-out /tmp/obs_smoke_trace.json \
	    --metrics-out /tmp/obs_smoke_metrics.json
	$(PY) -m repro.obs.check /tmp/obs_smoke_trace.json \
	    /tmp/obs_smoke_metrics.json --numerics

numerics-smoke: ## close the calibration loop: measure -> calibrate -> replan
	$(PY) -m repro.launch.serve --arch llama3.2-1b --continuous 3 \
	    --max-slots 2 --page-size 8 --n-pages 32 \
	    --prompt-len 12 --steps 6 \
	    --kv-bits 8 --kv-group 16 \
	    --numerics --numerics-every 2 --serve-metrics 0 \
	    --calibration-out /tmp/numerics_calib.json \
	    --trace-out /tmp/numerics_trace.json \
	    --metrics-out /tmp/numerics_metrics.json
	$(PY) -m repro.obs.check /tmp/numerics_trace.json \
	    /tmp/numerics_metrics.json --numerics
	$(PY) -m repro.launch.plan --arch llama3.2-1b \
	    --schemes lq8w,lq4w,lq2w --budget-ms 1000 \
	    --calibration /tmp/numerics_calib.json \
	    --out /tmp/numerics_plan.json

perf-smoke:  ## perf plane: phase breakdown + MFU gauges + regress gate
	$(PY) -m repro.launch.serve --arch llama3.2-1b --continuous 3 \
	    --max-slots 2 --page-size 8 --n-pages 32 \
	    --prompt-len 12 --steps 6 \
	    --kv-bits 8 --kv-group 16 \
	    --profile --profile-every 2 \
	    --trace-out /tmp/perf_smoke_trace.json \
	    --metrics-out /tmp/perf_smoke_metrics.json
	$(PY) -m repro.obs.check /tmp/perf_smoke_trace.json \
	    /tmp/perf_smoke_metrics.json --profile
	$(PY) -m repro.obs.regress BENCH_serve.json \
	    --history benchmarks/history.jsonl

fused-smoke: ## fused paged-attention serve + profile + bench regress gate
	$(PY) -m repro.launch.serve --arch llama3.2-1b --continuous 3 \
	    --max-slots 2 --page-size 8 --n-pages 32 \
	    --prompt-len 12 --steps 6 \
	    --kv-bits 4 --kv-group 16 \
	    --fused-attention \
	    --profile --profile-every 2 \
	    --trace-out /tmp/fused_smoke_trace.json \
	    --metrics-out /tmp/fused_smoke_metrics.json
	$(PY) -m repro.obs.check /tmp/fused_smoke_trace.json \
	    /tmp/fused_smoke_metrics.json --profile
	$(PY) -c "import json; from benchmarks import kernels_bench, run; \
	    run.write_bench_serve({'fused': kernels_bench.run_fused()}, \
	        path='/tmp/fused_smoke_bench.json')"
	$(PY) -m repro.obs.regress /tmp/fused_smoke_bench.json \
	    --history benchmarks/history.jsonl

slo-smoke:   ## SLO plane: fleet serve under a 2-tenant SLO manifest,
	##           validate + gate the report, then prove the gate trips
	$(PY) -m repro.launch.plan --arch llama3.2-1b \
	    --schemes lq8w,lq4w,lq2w --budget-mb 0.06 \
	    --out examples/fleet_plan_smoke.json
	$(PY) -m repro.launch.serve --fleet examples/fleet_smoke.json \
	    --fleet-requests 2 --prompt-len 12 --steps 6 \
	    --slo-report /tmp/slo_smoke_report.json \
	    --trace-out /tmp/slo_smoke_trace.json \
	    --metrics-out /tmp/slo_smoke_metrics.json \
	    --flight-out /tmp/slo_smoke_flight.json
	$(PY) -m repro.obs.check /tmp/slo_smoke_trace.json \
	    /tmp/slo_smoke_metrics.json --slo /tmp/slo_smoke_report.json
	$(PY) -m repro.obs.slo /tmp/slo_smoke_report.json
	$(PY) -m repro.obs.slo --demo-breach /tmp/slo_smoke_breach.json
	@$(PY) -m repro.obs.slo /tmp/slo_smoke_breach.json; st=$$?; \
	    test $$st -eq 1 || \
	    { echo "expected breach gate to exit 1, got $$st"; exit 1; }
	@echo "slo-smoke ok: healthy report passes, injected breach trips"

fleet-smoke: ## two-tenant fleet: plan one tenant, route a manifest, bench
	$(PY) -m repro.launch.plan --arch llama3.2-1b \
	    --schemes lq8w,lq4w,lq2w --budget-mb 0.06 \
	    --out examples/fleet_plan_smoke.json
	$(PY) -m repro.launch.serve --fleet examples/fleet_smoke.json \
	    --fleet-requests 2 --prompt-len 12 --steps 6
	$(PY) -m benchmarks.run fleet
