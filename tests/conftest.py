import os

# Tests must see the single real CPU device (the dry-run subprocess sets
# its own 512-device flag); keep any ambient flag from leaking in.
os.environ.pop("XLA_FLAGS", None)

import jax

jax.config.update("jax_enable_x64", False)
