"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode.

Every kernel runs its exact TPU body in Python (interpret=True) and must
match the pure-jnp oracle to float tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(0)


def _pallas_interpret_available() -> bool:
    """Probe the Pallas interpret path once; env/version gaps become skips."""
    try:
        w = jax.random.normal(KEY, (64, 32))
        qw = ops.quantize_weight(w, 8, 32)
        x = jax.random.normal(KEY, (2, 64))
        ops.quant_matmul(x, qw, backend="interpret")
        return True
    except Exception:
        return False


needs_pallas = pytest.mark.skipif(
    not _pallas_interpret_available(),
    reason="Pallas interpret backend unavailable in this jax build")


def _w(k, n, seed=0):
    return 2.0 * jax.random.normal(jax.random.fold_in(KEY, seed), (k, n))


def _x(m, k, dtype=jnp.float32, seed=1):
    return jax.random.normal(jax.random.fold_in(KEY, seed), (m, k)
                             ).astype(dtype)


# ---------------------------------------------------------------------------
# quant_matmul: fused dequant-matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4, 2, 1])
@pytest.mark.parametrize("m,k,n,gs", [
    (8, 256, 128, 64),
    (16, 512, 256, 128),
    (4, 128, 384, 32),
])
@needs_pallas
def test_quant_matmul_interpret_vs_ref(bits, m, k, n, gs):
    w = _w(k, n, seed=bits)
    qw = ops.quantize_weight(w, bits, gs)
    x = _x(m, k)
    got = ops.quant_matmul(x, qw, backend="interpret")
    want = ops.quant_matmul(x, qw, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@needs_pallas
def test_quant_matmul_dtypes(dtype):
    w = _w(256, 128, seed=3)
    qw = ops.quantize_weight(w, 4, 64)
    x = _x(8, 256, dtype)
    got = ops.quant_matmul(x, qw, backend="interpret")
    want = ops.quant_matmul(x, qw, backend="ref")
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-1)


@needs_pallas
def test_quant_matmul_unaligned_mn():
    """M, N not multiples of the tile: the kernel pads internally."""
    w = _w(256, 100, seed=4)
    qw = ops.quantize_weight(w, 8, 64)
    x = _x(5, 256)
    got = ops.quant_matmul(x, qw, backend="interpret")
    want = ops.quant_matmul(x, qw, backend="ref")
    assert got.shape == (5, 100)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_quant_matmul_vs_float():
    """8-bit quantized matmul approximates the float matmul closely."""
    w = _w(512, 256, seed=5)
    x = _x(16, 512)
    qw = ops.quantize_weight(w, 8, 64)
    got = ops.quant_matmul(x, qw, backend="ref")
    exact = x @ w
    rel = float(jnp.abs(got - exact).max() / jnp.abs(exact).max())
    assert rel < 2e-2


# ---------------------------------------------------------------------------
# act_quant: runtime activation quantization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("m,k,gs", [(8, 256, 64), (16, 128, 32),
                                    (3, 512, 128)])
@needs_pallas
def test_act_quant_interpret_vs_ref(bits, m, k, gs):
    x = _x(m, k, seed=bits + 20)
    gp, gs_, gz = ops.act_quant(x, bits=bits, group_size=gs,
                                backend="interpret")
    rp, rs, rz = ops.act_quant(x, bits=bits, group_size=gs, backend="ref")
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(rp))
    np.testing.assert_allclose(np.asarray(gs_), np.asarray(rs), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gz), np.asarray(rz), rtol=1e-6)


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_act_quant_reconstruction(bits):
    x = _x(8, 256, seed=bits + 30)
    p, s, z = ops.act_quant(x, bits=bits, group_size=64, backend="ref")
    xr = ref.act_dequant(p, s, z, bits=bits, group_size=64)
    step = np.asarray(s).max()
    assert np.abs(np.asarray(x) - np.asarray(xr)).max() <= step * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# lut_matmul: paper section V
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 2, 1])
@pytest.mark.parametrize("m,k,n,gs", [(8, 256, 128, 64), (4, 128, 96, 32)])
@needs_pallas
def test_lut_matmul_interpret_vs_ref(bits, m, k, n, gs):
    x = _x(m, k, seed=bits + 40)
    w = _w(k, n, seed=bits + 41)
    ap, asc, azm = ops.act_quant(x, bits=bits, group_size=gs, backend="ref")
    got = ops.lut_matmul(ap, asc, azm, w, bits=bits, group_size=gs,
                         backend="interpret")
    want = ops.lut_matmul(ap, asc, azm, w, bits=bits, group_size=gs,
                          backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("k,gs", [(96, 64), (130, 32), (100, 128)])
def test_lut_matmul_rejects_ragged_tail_region(k, gs):
    """Regression: a K not divisible by group_size used to silently drop
    the trailing partial local region from the product (the K grid walks
    whole regions only).  It must be a loud ValueError instead."""
    from repro.kernels.lut_matmul import lut_matmul as raw_lut
    bits, m, n = 2, 4, 8
    cpb = 8 // bits
    a_packed = jnp.zeros((m, -(-k // cpb)), jnp.uint8)
    a_scale = jnp.ones((m, -(-k // gs)), jnp.float32)
    w = jnp.ones((k, n), jnp.float32)
    with pytest.raises(ValueError, match="dropped"):
        raw_lut(a_packed, a_scale, a_scale, w, bits=bits, group_size=gs)


@pytest.mark.parametrize("k,gs", [(96, 64), (130, 32)])
def test_quant_matmul_rejects_ragged_tail_region(k, gs):
    """Same hazard audit on the dequant-matmul kernel: the ragged tail
    must fail loudly before any grid arithmetic."""
    from repro.kernels.quant_matmul import quant_matmul as raw_qm
    bits, m, n = 8, 4, 8
    x = jnp.ones((m, k), jnp.float32)
    packed = jnp.zeros((k, n), jnp.uint8)
    g = -(-k // gs)
    with pytest.raises(ValueError, match="dropped"):
        raw_qm(x, packed, jnp.ones((g, n)), jnp.zeros((g, n)),
               bits=bits, group_size=gs)


def test_lut_equals_dequant_matmul():
    """LUT forward == dequantized-activation matmul (paper eq. 8)."""
    x = _x(8, 256, seed=50)
    w = _w(256, 64, seed=51)
    ap, asc, azm = ops.act_quant(x, bits=2, group_size=64, backend="ref")
    lut_out = ops.lut_matmul(ap, asc, azm, w, bits=2, group_size=64,
                             backend="ref")
    xq = ref.act_dequant(ap, asc, azm, bits=2, group_size=64)
    np.testing.assert_allclose(np.asarray(lut_out), np.asarray(xq @ w),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# quant_dense: the full paper forward (weights + activations + LUT)
# ---------------------------------------------------------------------------

def test_quant_dense_paths_agree():
    x = _x(8, 256, seed=60)
    w = _w(256, 128, seed=61)
    qw = ops.quantize_weight(w, 8, 64)
    base = ops.quant_dense(x, qw, backend="ref")
    act = ops.quant_dense(x, qw, a_bits=8, backend="ref")
    lut = ops.quant_dense(x, qw, a_bits=2, lut=True, backend="ref")
    exact = x @ w
    for out, tol in [(base, 0.05), (act, 0.05), (lut, 0.6)]:
        rel = float(jnp.abs(out - exact).max() / jnp.abs(exact).max())
        assert rel < tol, rel


def test_qweight_bytes():
    k = n = 1024
    gs = 128
    w = _w(k, n)
    for bits in (8, 4, 2, 1):
        qw = ops.quantize_weight(w, bits, gs)
        expected = k * n * bits // 8 + 2 * (k // gs) * n * 4
        assert qw.nbytes() == expected
        # >= 3.2x smaller than fp32 even at 8-bit (incl. region metadata)
        assert qw.nbytes() <= k * n * 4 * bits / 8 / 0.9
