"""Training substrate: optimizer, microbatching, compression, QAT, loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gradcomp
from repro.data import DataConfig, SyntheticLM
from repro.models.config import ModelConfig
from repro.models.layers import QuantPolicy
from repro.optim import adamw, apply_updates, clip_by_global_norm, \
    warmup_cosine
from repro.train import TrainHParams, Trainer, TrainerConfig, make_train_step

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, d_ff=128,
                   dtype="float32", remat="none")


def _data(batch=8, seq=32):
    return SyntheticLM(DataConfig(vocab_size=256, seq_len=seq,
                                  global_batch=batch))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.asarray(5.0)}
    state = opt.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert abs(float(params["w"])) < 0.5


def test_weight_decay_mask():
    """1-D leaves (biases/norms) are not decayed."""
    opt = adamw(0.1, weight_decay=1.0)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = opt.init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    updates, _ = opt.update(zeros, state, params)
    assert float(jnp.abs(updates["w"]).sum()) > 0      # decayed
    assert float(jnp.abs(updates["b"]).sum()) == 0     # masked


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    n2 = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(n2), 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(fn(0)) == 0.0
    np.testing.assert_allclose(float(fn(10)), 1.0, rtol=1e-5)
    assert float(fn(100)) < float(fn(50)) < float(fn(10))


# ---------------------------------------------------------------------------
# train step semantics
# ---------------------------------------------------------------------------

def test_loss_decreases():
    data = _data()
    tr = Trainer(TINY, TrainHParams(lr=1e-3), data,
                 TrainerConfig(total_steps=25, log_every=100))
    tr.run()
    assert tr.history[-1]["loss"] < tr.history[0]["loss"]


def test_microbatch_equals_full_batch():
    """Gradient accumulation must match the single-batch gradient."""
    data = _data(batch=8)
    batch = data.batch(0)
    results = {}
    for ms in (1, 2, 4):
        init, step = make_train_step(TINY, TrainHParams(lr=1e-2,
                                                        microsteps=ms))
        state = init(jax.random.key(0))
        state, metrics = jax.jit(step)(state, batch)
        results[ms] = (float(metrics["loss"]),
                       np.asarray(jax.tree.leaves(state.params)[0]))
    np.testing.assert_allclose(results[1][0], results[2][0], rtol=1e-5)
    np.testing.assert_allclose(results[1][1], results[4][1],
                               rtol=5e-4, atol=5e-6)


def test_qat_policy_trains():
    data = _data()
    tr = Trainer(TINY, TrainHParams(lr=1e-3), data,
                 TrainerConfig(total_steps=12, log_every=100),
                 policy=QuantPolicy.qat("lq4"))
    tr.run()
    assert np.isfinite(tr.history[-1]["loss"])
    assert tr.history[-1]["loss"] < tr.history[0]["loss"]


# ---------------------------------------------------------------------------
# gradient compression (beyond-paper distributed tie-in)
# ---------------------------------------------------------------------------

def test_gradcomp_roundtrip_error_small():
    g = jax.random.normal(jax.random.key(0), (1000,)) * 1e-3
    out = gradcomp.roundtrip_leaf(g, 8, 128)
    rel = float(jnp.abs(out - g).max() / jnp.abs(g).max())
    assert rel < 0.01


def test_error_feedback_reduces_bias():
    """With error feedback the accumulated compressed sum tracks the true
    sum much better than without."""
    key = jax.random.key(1)
    gs = [0.01 * jax.random.normal(jax.random.fold_in(key, i), (256,))
          for i in range(50)]
    true_sum = sum(gs)

    acc_ef = jnp.zeros((256,))
    err = jnp.zeros((256,))
    acc_no = jnp.zeros((256,))
    for g in gs:
        q = gradcomp.roundtrip_leaf(g + err, 2, 64)
        err = (g + err) - q
        acc_ef = acc_ef + q
        acc_no = acc_no + gradcomp.roundtrip_leaf(g, 2, 64)
    e_ef = float(jnp.linalg.norm(acc_ef - true_sum))
    e_no = float(jnp.linalg.norm(acc_no - true_sum))
    assert e_ef < e_no


def test_compressed_training_converges():
    data = _data()
    tr = Trainer(TINY, TrainHParams(lr=1e-3, grad_compress_bits=8), data,
                 TrainerConfig(total_steps=20, log_every=100))
    tr.run()
    assert tr.history[-1]["loss"] < tr.history[0]["loss"]


def test_compressed_mean_matches_plain_mean():
    """compressed_mean_over_axis under shard_map == plain mean (8-bit)."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    import numpy as np_
    devs = np_.asarray(jax.devices()[:1])
    mesh = Mesh(devs, ("dp",))
    g = {"w": jax.random.normal(jax.random.key(2), (4, 64))}

    def fn(gg):
        return gradcomp.compressed_mean_over_axis(gg, "dp", bits=8,
                                                  group_size=32)

    out = shard_map(fn, mesh=mesh, in_specs=(P("dp"),),
                    out_specs=P("dp"))(g)
    rel = float(jnp.abs(out["w"] - g["w"]).max()
                / jnp.abs(g["w"]).max())
    assert rel < 0.02


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restart_exact():
    d1 = _data()
    d2 = _data()
    b1, b2 = d1.batch(7), d2.batch(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_data_sharding_partition():
    d = _data(batch=8)
    b = d.batch(0)
    shards = [SyntheticLM.shard(b, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards, 0),
                                  np.asarray(b["tokens"]))


def test_data_learnable():
    """The HMM stream is predictable: a bigram fit beats uniform entropy."""
    d = _data(batch=32, seq=64)
    b = d.batch(0)
    toks = np.asarray(b["tokens"])
    # unigram entropy must be well below log(vocab) (structure exists)
    counts = np.bincount(toks.reshape(-1), minlength=256) + 1e-9
    p = counts / counts.sum()
    h = -(p * np.log(p)).sum()
    assert h < np.log(256) * 0.95
