"""Serving: engine, quantized weights/KV-cache, fidelity across schemes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kvwire
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serve import Engine, EngineConfig

TINY = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")


@pytest.fixture(scope="module")
def setup():
    params = transformer.init_params(TINY, jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 12), 0,
                                          256, jnp.int32)}
    return params, batch


# ---------------------------------------------------------------------------
# kv wire format
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,gs", [(8, 16), (4, 16), (2, 8), (1, 8)])
def test_kv_roundtrip_error(bits, gs):
    x = jax.random.normal(jax.random.key(0), (2, 5, 2, 32))
    q = kvwire.quantize_kv(x, bits, gs)
    xr = kvwire.dequantize_kv(q, 32)
    step = float(np.asarray(q["scale"]).max())
    assert float(jnp.abs(x - xr).max()) <= step * 0.5 + 1e-6
    assert kvwire.kv_bits_of(q, 32) == bits


def test_kv_bytes_shrink():
    shape = (2, 64, 2, 64)
    fp = int(np.prod(shape)) * 2                      # bf16 baseline
    for bits in (8, 4, 2, 1):
        q = kvwire.make_quant_kv(shape, bits, 64)
        nbytes = kvwire.cache_nbytes(q)
        assert nbytes < fp * bits / 8 + np.prod(shape[:-1]) * 8 + 1


def test_kv_update_slot():
    q = kvwire.make_quant_kv((1, 8, 2, 32), 8, 16)
    new = jax.random.normal(jax.random.key(2), (1, 1, 2, 32))
    q2 = kvwire.update_quant_kv(q, new, 3, axis=1, bits=8, group_size=16)
    xr = kvwire.dequantize_kv(q2, 32)
    np.testing.assert_allclose(np.asarray(xr[:, 3]), np.asarray(new[:, 0]),
                               rtol=0.05, atol=0.05)
    assert float(jnp.abs(xr[:, 0]).max()) == 0        # untouched slots


# ---------------------------------------------------------------------------
# engine fidelity
# ---------------------------------------------------------------------------

def test_engine_greedy_deterministic(setup):
    params, batch = setup
    eng = Engine(TINY, params, EngineConfig(max_len=32))
    a, _ = eng.generate(batch, steps=6)
    b, _ = eng.generate(batch, steps=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("scheme", ["lq8w", "lq8"])
def test_engine_8bit_matches_fp_greedy(setup, scheme):
    """Paper Table 1: 8-bit has no accuracy drop — greedy tokens match."""
    params, batch = setup
    fp = Engine(TINY, params, EngineConfig(max_len=32))
    q = Engine(TINY, params, EngineConfig(max_len=32, weight_scheme=scheme,
                                          backend="ref"))
    a, _ = fp.generate(batch, steps=8)
    b, _ = q.generate(batch, steps=8)
    assert (np.asarray(a) == np.asarray(b)).mean() > 0.9


def test_engine_kv8_matches_fp(setup):
    params, batch = setup
    fp = Engine(TINY, params, EngineConfig(max_len=32))
    q = Engine(TINY, params, EngineConfig(max_len=32, kv_bits=8,
                                          kv_group=16))
    a, _ = fp.generate(batch, steps=8)
    b, _ = q.generate(batch, steps=8)
    assert (np.asarray(a) == np.asarray(b)).mean() > 0.9


def test_engine_cache_bytes_ordering(setup):
    params, _ = setup
    sizes = []
    for bits in (None, 8, 4, 2):
        eng = Engine(TINY, params, EngineConfig(
            max_len=64, kv_bits=bits, kv_group=16))
        sizes.append(eng.cache_bytes(2))
    assert sizes == sorted(sizes, reverse=True)


def test_temperature_sampling_runs(setup):
    params, batch = setup
    eng = Engine(TINY, params, EngineConfig(max_len=32, temperature=0.8,
                                            top_k=16))
    out, _ = eng.generate(batch, steps=5)
    assert out.shape == (2, 6)
    assert int(out.max()) < 256


def test_lut_serving_path(setup):
    """Paper section V: 8-bit weights + 2-bit LUT activations serve."""
    params, batch = setup
    eng = Engine(TINY, params, EngineConfig(
        max_len=32, weight_scheme="lq2_lut", backend="ref"))
    out, _ = eng.generate(batch, steps=4)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# ssm state quantization (mamba: the attention-free cache)
# ---------------------------------------------------------------------------

def test_mamba_state_quant_close_to_fp():
    cfg = ModelConfig(name="tssm", family="ssm", n_layers=2, d_model=64,
                      vocab_size=256, d_ff=0, rope=False,
                      pattern=(("mamba2", "none"),), ssm_state=16,
                      ssm_head_dim=16, dtype="float32")
    params = transformer.init_params(cfg, jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 12), 0,
                                          256, jnp.int32)}
    fp = Engine(cfg, params, EngineConfig(max_len=32))
    q8 = Engine(cfg, params, EngineConfig(max_len=32, kv_bits=8,
                                          kv_group=16))
    a, _ = fp.generate(batch, steps=8)
    b, _ = q8.generate(batch, steps=8)
    assert (np.asarray(a) == np.asarray(b)).mean() > 0.8
