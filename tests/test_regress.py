"""Tests for the benchmark regression harness (obs/regress.py,
benchmarks/history.py): run metadata, append-only history round trips,
rolling-baseline medians, direction-aware tolerance bands, and the CLI
exit codes (0 pass / 1 regression / 2 usage)."""
import json

import pytest

from benchmarks import history
from repro.obs import regress


def _entry(metrics, backend="cpu", sha="abc1234"):
    return {"meta": {"backend": backend, "git_sha": sha,
                     "device": "x", "jax_version": "0",
                     "timestamp": "2026-08-01T00:00:00+00:00"},
            "metrics": metrics}


BASE = {"serve_throughput.kv8_tok_per_s": 1000.0,
        "serve_throughput.kv8_itl_p50_ms": 2.0,
        "spec_decode.lq8w_acceptance_rate": 0.9,
        "spec_decode.lq8w_verify_steps_per_token": 0.5}


# ---------------------------------------------------------------------------
# history file
# ---------------------------------------------------------------------------

class TestHistory:
    def test_metadata_keys(self):
        meta = history.run_metadata()
        assert set(meta) >= {"git_sha", "backend", "device",
                             "jax_version", "timestamp"}
        assert meta["git_sha"] != ""

    def test_append_load_round_trip(self, tmp_path):
        p = tmp_path / "h.jsonl"
        history.append_entry({"a": 1.0}, p, meta={"backend": "cpu"})
        history.append_entry({"a": 2.0}, p, meta={"backend": "cpu"})
        got = history.load_history(p)
        assert [e["metrics"]["a"] for e in got] == [1.0, 2.0]

    def test_missing_file_and_corrupt_lines(self, tmp_path):
        assert history.load_history(tmp_path / "nope.jsonl") == []
        p = tmp_path / "h.jsonl"
        p.write_text('{"metrics": {"a": 1.0}, "meta": {}}\n'
                     "{truncated garbage\n\n")
        assert len(history.load_history(p)) == 1

    def test_committed_history_loads(self):
        # the tracked baseline the CI gate compares against
        entries = history.load_history()
        assert entries, "benchmarks/history.jsonl missing or empty"
        assert all("metrics" in e and "meta" in e for e in entries)


# ---------------------------------------------------------------------------
# baseline + bands
# ---------------------------------------------------------------------------

class TestBaseline:
    def test_rolling_median_over_window(self):
        hist = [_entry({"x_tok_per_s": v})
                for v in (1.0, 100.0, 110.0, 120.0)]
        base = regress.rolling_baseline(hist, window=3)
        assert base["x_tok_per_s"] == 110.0      # the 1.0 aged out

    def test_backend_filter(self):
        hist = [_entry({"x_tok_per_s": 10.0}, backend="tpu"),
                _entry({"x_tok_per_s": 100.0}, backend="cpu")]
        base = regress.rolling_baseline(hist, backend="cpu")
        assert base["x_tok_per_s"] == 100.0

    def test_band_lookup(self):
        assert regress.band_for("a.kv8_tok_per_s") == (True, 1.5)
        assert regress.band_for("a.itl_p50_ms") == (False, 1.5)
        assert regress.band_for("a.acceptance_rate") == (True, 1.05)
        assert regress.band_for("a.verify_steps_per_token") == (False, 1.05)
        assert regress.band_for("a.pool_occupancy") is None

    def test_flatten(self):
        flat = regress.flatten_metrics(
            {"serve": {"tok_per_s": 3.0}, "meta": {"sha": "x"},
             "flag": True})
        assert flat == {"serve.tok_per_s": 3.0}   # strings/bools dropped


class TestCompare:
    def test_within_band_passes(self):
        cur = dict(BASE)
        cur["serve_throughput.kv8_tok_per_s"] = 700.0    # 1.43x < 1.5x
        assert regress.compare(cur, BASE) == []

    def test_improvement_passes(self):
        cur = {k: (v * 3 if "tok_per_s" in k else v)
               for k, v in BASE.items()}
        assert regress.compare(cur, BASE) == []

    def test_throughput_regression_flagged(self):
        cur = dict(BASE)
        cur["serve_throughput.kv8_tok_per_s"] = 400.0    # 2.5x worse
        bad = regress.compare(cur, BASE)
        assert [b["metric"] for b in bad] == \
            ["serve_throughput.kv8_tok_per_s"]

    def test_latency_direction(self):
        cur = dict(BASE)
        cur["serve_throughput.kv8_itl_p50_ms"] = 4.0     # 2x slower
        assert len(regress.compare(cur, BASE)) == 1
        cur["serve_throughput.kv8_itl_p50_ms"] = 0.5     # faster: fine
        assert regress.compare(cur, BASE) == []

    def test_acceptance_band_is_tight(self):
        cur = dict(BASE)
        cur["spec_decode.lq8w_acceptance_rate"] = 0.8    # 1.125x > 1.05x
        assert len(regress.compare(cur, BASE)) == 1


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

@pytest.fixture
def bench_files(tmp_path):
    def write(current, hist_entries):
        cp = tmp_path / "BENCH.json"
        cp.write_text(json.dumps(current))
        hp = tmp_path / "history.jsonl"
        with open(hp, "w") as f:
            for e in hist_entries:
                f.write(json.dumps(e) + "\n")
        return str(cp), str(hp)
    return write


class TestCLI:
    CURRENT = {"serve_throughput": {"kv8_tok_per_s": 1000.0,
                                    "kv8_itl_p50_ms": 2.0}}

    def test_clean_run_exits_0(self, bench_files, capsys):
        cp, hp = bench_files(self.CURRENT, [
            _entry(regress.flatten_metrics(self.CURRENT))] * 3)
        assert regress.main([cp, "--history", hp]) == 0
        assert "OK" in capsys.readouterr().out

    def test_injected_regression_exits_1(self, bench_files, capsys):
        bad = {"serve_throughput": {"kv8_tok_per_s": 100.0,
                                    "kv8_itl_p50_ms": 2.0}}
        cp, hp = bench_files(bad, [
            _entry(regress.flatten_metrics(self.CURRENT))] * 3)
        assert regress.main([cp, "--history", hp]) == 1
        assert "REGRESSION" in capsys.readouterr().out

    def test_no_baseline_exits_0(self, bench_files, capsys):
        cp, hp = bench_files(self.CURRENT, [])
        assert regress.main([cp, "--history", hp]) == 0
        assert "no comparable baseline" in capsys.readouterr().out

    def test_append_on_pass(self, bench_files):
        cp, hp = bench_files(self.CURRENT, [
            _entry(regress.flatten_metrics(self.CURRENT))])
        assert regress.main([cp, "--history", hp, "--append"]) == 0
        assert len(history.load_history(hp)) == 2

    def test_no_append_on_fail(self, bench_files):
        bad = {"serve_throughput": {"kv8_tok_per_s": 100.0}}
        cp, hp = bench_files(bad, [
            _entry(regress.flatten_metrics(self.CURRENT))] * 2)
        assert regress.main([cp, "--history", hp, "--append"]) == 1
        assert len(history.load_history(hp)) == 2        # unchanged

    def test_unreadable_current_exits_1(self, tmp_path, capsys):
        assert regress.main([str(tmp_path / "nope.json")]) == 1
        assert "cannot read" in capsys.readouterr().out

    def test_usage_error_exits_2(self):
        with pytest.raises(SystemExit) as exc:
            regress.main([])
        assert exc.value.code == 2

    def test_tracked_baseline_gates_current_bench(self):
        # the real BENCH_serve.json must pass against the committed
        # history — this IS the CI gate, run as a test
        from benchmarks.history import REPO_ROOT
        assert regress.main([str(REPO_ROOT / "BENCH_serve.json")]) == 0
