"""Distribution substrate: sharding rules, checkpointing, straggler,
elastic re-mesh, roofline HLO analyzer."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import (CheckpointManager, StragglerMonitor, elastic,
                               rules_for, tree_paths)
from repro.distributed.sharding import batch_sharding, cache_sharding
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.roofline import hlo_cost

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, d_ff=128,
                   dtype="float32", remat="none")


def _mesh11():
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:    # older jax: meshes are implicitly Auto-typed
        return jax.make_mesh((1, 1), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(axis_type.Auto,) * 2)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_rules_match_expected_paths():
    rules = rules_for(("data",))
    assert rules.spec_for("decoder/super/0/mixer/wq/w", 3) == \
        P(None, ("data",), "model")
    assert rules.spec_for("decoder/super/0/mixer/wo/w", 3) == \
        P(None, "model", ("data",))
    assert rules.spec_for("embed/table", 2) == P("model", ("data",))
    assert rules.spec_for("decoder/super/0/ffn/wi_gate", 4) == \
        P(None, "model", ("data",), None)
    assert rules.spec_for("decoder/super/0/norm1/scale", 1) == P()
    # QWeight leaves share the float weight's layout
    assert rules.spec_for("decoder/super/0/mixer/wq/w/packed", 3) == \
        P(None, ("data",), "model")


def test_rules_multipod_dp():
    rules = rules_for(("pod", "data"))
    assert rules.spec_for("lm_head/w", 2) == P(("pod", "data"), "model")


def test_uneven_dims_fall_back_to_replicated():
    """mamba2 in_proj N=3352 doesn't divide 16 -> that dim replicates."""
    from repro.distributed.sharding import _evenly

    class StubMesh:                     # only .shape is consulted
        shape = {"data": 16, "model": 16}

    spec = _evenly(P("data", "model"), (768, 3352), StubMesh())
    assert spec == P("data", None)
    spec2 = _evenly(P("data", "model"), (768, 3200), StubMesh())
    assert spec2 == P("data", "model")


def test_all_params_get_shardings():
    params = transformer.init_params(TINY, jax.random.key(0))
    mesh = _mesh11()
    rules = rules_for(("data",))
    shardings = rules.shardings(params, mesh)
    assert len(jax.tree.leaves(shardings)) == len(jax.tree.leaves(params))


def test_cache_sharding_roles():
    mesh = _mesh11()
    cache = transformer.init_cache(TINY, 4, 16)
    sh = cache_sharding(cache, mesh, ("data",), batch_size=4)
    flat = {"/".join(map(str, jax.tree_util.keystr(kp).split("'")[1::2])): v
            for kp, v in jax.tree_util.tree_flatten_with_path(sh)[0]}
    # stacked KV leaf: (S, B, S_kv, KV, D) -> (None, dp, model-on-seq, ...)
    kv = [v for k, v in flat.items() if k.endswith("k")][0]
    assert kv.spec[1] in ("data", ("data",))


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def _state():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"mu": jnp.ones((3, 4)), "count": jnp.asarray(3)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    mgr.save(10, state)
    restored = mgr.restore(10, state)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, restored)


def test_checkpoint_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    for step in (10, 20, 30):
        mgr.save(step, jax.tree.map(lambda x: x + step, state))
    assert mgr.committed_steps() == [20, 30]
    step, tree = mgr.restore_latest(state)
    assert step == 30
    np.testing.assert_allclose(np.asarray(tree["w"]),
                               np.asarray(state["w"]) + 30)


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = _state()
    mgr.save(10, state)
    mgr.save(20, state)
    # corrupt the newest checkpoint's first leaf
    d = os.path.join(str(tmp_path), "step_00000020")
    fn = os.path.join(d, "leaf_00000.npy")
    arr = np.load(fn)
    arr = arr + 999
    np.save(fn, arr)
    step, _ = mgr.restore_latest(state, verbose=False)
    assert step == 10                                  # fell back


def test_checkpoint_partial_write_ignored(tmp_path):
    """A .tmp dir (preemption mid-write) is invisible to restore."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = _state()
    mgr.save(10, state)
    os.makedirs(os.path.join(str(tmp_path), "step_00000020.tmp"))
    step, _ = mgr.restore_latest(state, verbose=False)
    assert step == 10


def test_trainer_auto_resume(tmp_path):
    """Kill-and-restart: the second Trainer resumes from the checkpoint."""
    from repro.data import DataConfig, SyntheticLM
    from repro.train import TrainHParams, Trainer, TrainerConfig
    data = SyntheticLM(DataConfig(vocab_size=256, seq_len=16,
                                  global_batch=4))
    mk = lambda steps: Trainer(
        TINY, TrainHParams(lr=1e-3), data,
        TrainerConfig(total_steps=steps, ckpt_every=5, log_every=100,
                      ckpt_dir=str(tmp_path)))
    t1 = mk(10)
    t1.run()                               # writes step 5, 10
    t2 = mk(14)                            # "restarted job"
    t2.run()
    steps_run = [h["step"] for h in t2.history]
    assert steps_run[0] == 10              # resumed, not from scratch
    assert steps_run[-1] == 13


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

def test_straggler_flags_slow_worker():
    events = []
    mon = StragglerMonitor(threshold=3.0, patience=2, warmup=3,
                           on_straggler=lambda *a: events.append(a))
    rng = np.random.default_rng(1)
    for _ in range(20):
        mon.observe("w0", 0.10 + rng.normal() * 1e-4)
    for _ in range(2):
        mon.observe("w0", 0.50)            # 5x slower, twice
    assert events, "straggler not flagged"


def test_straggler_tolerates_noise():
    mon = StragglerMonitor(threshold=3.0, patience=3, warmup=5)
    rng = np.random.default_rng(0)
    flags = [mon.observe("w", 0.1 + abs(rng.normal()) * 0.002)
             for _ in range(100)]
    assert not any(flags)


# ---------------------------------------------------------------------------
# elastic re-mesh
# ---------------------------------------------------------------------------

def test_plan_remesh_shrinks_data_axis():
    plan = elastic.plan_remesh(192, model_extent=16, global_batch=256,
                               prev_data_extent=16)
    assert plan.mesh_shape == (8, 16)      # 192 // 16 = 12 -> largest div 8
    assert plan.microsteps == 2            # keeps global batch


def test_plan_remesh_rejects_too_few():
    with pytest.raises(ValueError):
        elastic.plan_remesh(8, model_extent=16, global_batch=256,
                            prev_data_extent=16)


def test_elastic_reshard_roundtrip():
    plan = elastic.plan_remesh(1, model_extent=1, global_batch=4,
                               prev_data_extent=1)
    mesh = elastic.build_mesh(plan)
    rules = rules_for(("data",))
    params = transformer.init_params(TINY, jax.random.key(0))
    host = jax.tree.map(lambda x: np.asarray(x), params)
    resharded = elastic.reshard(host, mesh, rules)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, resharded)


# ---------------------------------------------------------------------------
# roofline HLO analyzer
# ---------------------------------------------------------------------------

def test_hlo_cost_counts_loops():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    compiled = jax.jit(scanned).lower(w).compile()
    c = hlo_cost.analyze(compiled.as_text())
    np.testing.assert_allclose(c.flops, 7 * 2 * 128 ** 3, rtol=0.01)


def test_hlo_cost_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    compiled = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
    c = hlo_cost.analyze(compiled.as_text())
    np.testing.assert_allclose(c.flops, 2 * 64 * 32 * 48, rtol=1e-6)


def test_hlo_top_ops_profile():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(lambda x: (x @ x) @ x).lower(a).compile()
    rows = hlo_cost.top_ops(compiled.as_text(), 5, key="flops")
    assert rows and rows[0][2] == "dot"
