"""Self-speculative decoding: acceptance logic, multi-token paged decode,
pool rewind, draft/verifier weight sharing, and the token-exactness bar —
speculative greedy output == verifier-only engine, with one compiled
batched verify step."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kvwire
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.plan import QuantPlan
from repro.plan.plan import candidates_for
from repro.serve import (EngineConfig, PagedConfig, PagedEngine,
                         PagedKVPool, RequestParams, Server)
from repro.spec import (PairedKVPool, SpeculativeEngine, accept_lengths,
                        emitted_tokens, shared_segment_keys)

TINY = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")

CANDS = candidates_for(TINY, ["lq8w", "lq4w", "lq2w"])
PCFG = PagedConfig(max_slots=2, page_size=4, n_pages=40, max_context=32)


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.key(0))


def _prompts(seed=1, lens=(7, 12, 5)):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(0, 256, size=n))) for n in lens]


def _plan(scheme, kv=None, kv_default=None):
    p = QuantPlan(default=CANDS[scheme]) if scheme != "fp32" else \
        QuantPlan.uniform("fp32")
    if kv is not None or kv_default is not None:
        p = p.with_kv(kv or {}, default=kv_default, kv_group=16)
    return p


# ---------------------------------------------------------------------------
# acceptance logic (pure)
# ---------------------------------------------------------------------------

def test_accept_lengths_longest_prefix():
    props = np.array([[1, 2, 3], [1, 9, 3], [9, 2, 3], [1, 2, 9]])
    greedy = np.array([[1, 2, 3, 4], [1, 2, 3, 4], [1, 2, 3, 4],
                       [1, 2, 3, 4]])
    assert accept_lengths(props, greedy).tolist() == [3, 1, 0, 2]


def test_emitted_tokens_rules():
    props = np.array([[1, 2, 3], [1, 9, 3], [9, 2, 3]])
    greedy = np.array([[1, 2, 3, 4], [1, 2, 3, 4], [1, 2, 3, 4]])
    m = accept_lengths(props, greedy)
    out = emitted_tokens(props, greedy, m)
    # full acceptance: the k proposals, NO bonus token (g_3 == 4 dropped)
    assert out[0] == [1, 2, 3]
    # partial: accepted prefix + the verifier's correction g_m
    assert out[1] == [1, 2]
    # immediate mismatch: just the correction g_0 — a plain decode step
    assert out[2] == [1]
    # every emitted token is a verifier greedy token
    for toks in out:
        assert all(t in greedy for t in toks)


# ---------------------------------------------------------------------------
# multi-token paged decode == k sequential steps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits", [None, 8, 2])
def test_decode_multi_matches_sequential_steps(params, kv_bits):
    """The length-k verify forward writes the same cache bytes and scores
    the same greedy tokens as k single-token steps."""
    kw = dict(kv_bits=kv_bits, kv_group=16) if kv_bits else {}
    eng = PagedEngine(TINY, params, EngineConfig(max_len=32, **kw), PCFG)
    prompt = _prompts()[0]

    def prefilled():
        pool = eng.new_pool()
        assert pool.alloc(0, 4)
        first = eng.prefill_request(pool, prompt, pool.pages_of(0),
                                    jax.random.key(0))
        return pool, first

    pool_a, first = prefilled()
    table = np.stack([pool_a.table_array(0, PCFG.pages_per_slot),
                      np.zeros(PCFG.pages_per_slot, np.int32)])
    pos0 = np.array([len(prompt), 0], np.int32)
    run = np.array([[first, 11, 22], [0, 0, 0]], np.int32)

    greedy_multi = eng.decode_multi_batch(pool_a, run, table, pos0)

    pool_b, _ = prefilled()
    seq = []
    for i in range(run.shape[1]):
        toks = eng.decode_step_batch(pool_b, run[:, i], table, pos0 + i,
                                     jax.random.key(1))
        seq.append(toks)
    seq = np.stack(seq, axis=1)
    np.testing.assert_array_equal(greedy_multi[0], seq[0])
    # and the pool bytes agree leaf-for-leaf (same rows written)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), pool_a.pages, pool_b.pages)


# ---------------------------------------------------------------------------
# pool rewind: truncate un-writes without realloc
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_map", [(8, 8, 8), (8, None, 2), None])
def test_truncate_restores_never_speculated_bytes(kv_map):
    """After writing 10 rows and truncating to 5, the pool is
    byte-identical to one where only 5 rows were ever written — across
    homogeneous, heterogeneous, and fp geometries."""
    def build_and_write(n_rows):
        kw = {} if kv_map is None else dict(kv_bits=kv_map, kv_group=16)
        pool = PagedKVPool(TINY, n_pages=8, page_size=4, **kw)
        assert pool.alloc(1, 3)                 # rows 0..11 available
        x = jax.random.normal(jax.random.key(0),
                              (1, 12, TINY.n_kv_heads, TINY.head_dim))
        ids = pool.pages_of(1)
        wpos = np.arange(n_rows)
        page_idx = jnp.asarray([[ids[p // 4] for p in wpos]])
        row = jnp.asarray([wpos % 4])
        sup_key = ("super_segments" if "super_segments" in pool.pages
                   else "super")
        segs = list(pool.pages[sup_key])
        for s, seg in enumerate(segs):
            seg = list(seg) if isinstance(seg, tuple) else [seg]
            for j, blk in enumerate(seg):
                leaf = blk["self"]["k"]
                sample = jax.tree.leaves(leaf)[0]
                stack = sample.shape[0]
                one = jax.tree.map(lambda a: a[0], leaf)
                bits = kvwire.kv_bits_of(one, TINY.head_dim) \
                    if kvwire.is_quant_kv(one) else None
                kw2 = ({} if bits is None
                       else dict(bits=bits, group_size=16))
                one = kvwire.scatter_tokens(one, x[:, :n_rows], page_idx,
                                            row, **kw2)
                blk["self"]["k"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None],
                                               (stack,) + a.shape), one)
            segs[s] = tuple(seg)
        pool.pages[sup_key] = (segs if sup_key == "super_segments"
                               else tuple(segs))
        return pool

    full = build_and_write(10)
    freed = full.truncate(1, 5)
    assert freed == 1                           # rows 8..11's page released
    ref = build_and_write(5)                    # ref page 3 alloc'd, zero
    assert full.pages_of(1) == ref.pages_of(1)[:2]
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), full.pages, ref.pages)
    assert full.n_free == ref.n_free + 1        # ref still owns 3 pages


def test_truncate_validation_and_page_accounting():
    pool = PagedKVPool(TINY, n_pages=8, page_size=4)
    assert pool.alloc(1, 3) and pool.alloc(2, 2)
    with pytest.raises(ValueError):
        pool.truncate(1, -1)
    with pytest.raises(ValueError):             # can't keep more than owned
        pool.truncate(1, 13)
    assert pool.truncate(1, 12) == 0            # exact fit: nothing freed
    assert pool.truncate(1, 5) == 1             # 2 pages cover 5 tokens
    assert pool.pages_of(2) == pool.pages_of(2)  # other rids untouched
    assert pool.truncate(1, 0) == 2             # full release
    assert pool.n_free == pool.n_allocatable - 2
    assert pool.truncate(99, 0) == 0            # unknown rid is a no-op


def test_paired_pool_defrag_permutes_draft_coherently():
    pool = PairedKVPool(TINY, n_pages=10, page_size=4, kv_bits=8,
                        kv_group=16, draft_kv_bits=2, draft_kv_group=16)
    pool.alloc(1, 2), pool.alloc(2, 3), pool.alloc(3, 1)
    x = jax.random.normal(jax.random.key(0),
                          (1, 1, TINY.n_kv_heads, TINY.head_dim))
    page = jnp.asarray([[pool.pages_of(2)[0]]])
    row = jnp.asarray([[0]])
    for side, bits in ((pool.pages, 8), (pool.draft.pages, 2)):
        leaf = jax.tree.map(lambda a: a[0], side["super"][0]["self"]["k"])
        leaf = kvwire.scatter_tokens(leaf, x, page, row, bits=bits,
                                     group_size=16)
        side["super"][0]["self"]["k"] = jax.tree.map(lambda a: a[None],
                                                     leaf)

    def views():
        tbl = jnp.asarray([pool.pages_of(2)], jnp.int32)
        return [jax.tree.map(
            lambda a: kvwire.gather_pages(a[0], tbl),
            side["super"][0]["self"]["k"])
            for side in (pool.pages, pool.draft.pages)]

    before = views()
    pool.free(1)
    pool.defrag()
    after = views()
    for want, got in zip(before, after):
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), want, got)


# ---------------------------------------------------------------------------
# the acceptance bar: speculative greedy == verifier-only, exactly
# ---------------------------------------------------------------------------

def _run_server(srv, prompts, max_new):
    rids = []
    for i, (p, n) in enumerate(zip(prompts, max_new)):
        rids.append(srv.submit(p, RequestParams(max_new_tokens=n)))
        srv.step()                              # staggered arrivals
    outs = srv.drain(max_steps=400)
    return [outs[r] for r in rids]


@pytest.mark.parametrize("verifier,draft", [
    # (draft=2-bit, verifier=8-bit) over a mixed per-layer kv plan
    (_plan("lq8w", kv={"layer.0": 8}, kv_default=2), _plan("lq2w")),
    # (draft=4-bit, verifier=fp) — draft carries its own mixed kv map
    (_plan("fp32", kv={"layer.0": 8}, kv_default=2),
     _plan("lq4w", kv={"layer.0": 8}, kv_default=2)),
])
@pytest.mark.parametrize("spec_k", [2, 3])
def test_speculative_matches_verifier_only_token_for_token(
        params, verifier, draft, spec_k):
    """Speculative greedy decode is token-for-token identical to the
    verifier-only PagedEngine across mixed weight x kv plans, with ONE
    compiled trace for the batched verify step."""
    prompts = _prompts()
    max_new = [10, 6, 8]
    ecfg = EngineConfig(max_len=32, plan=verifier, backend="ref")

    ref = _run_server(Server(TINY, params, ecfg, PCFG), prompts, max_new)

    eng = SpeculativeEngine(TINY, params, ecfg, PCFG, draft_plan=draft,
                            spec_k=spec_k)
    srv = Server(TINY, params, ecfg, PCFG, engine=eng)
    outs = _run_server(srv, prompts, max_new)

    assert outs == ref
    assert eng.decode_compilations == 1          # one batched verify trace
    assert eng.draft_compilations == 1           # one draft step trace
    s = srv.scheduler.stats()
    assert s["preemptions"] == 0                 # rollbacks != preemptions
    if s["rejected_tokens"]:
        assert eng.verify_steps_per_token() < 1.0 or \
            eng.acceptance_rate() == 0.0
    assert eng.verify_steps_per_token() <= 1.0


def test_mismatched_verifier_scheme_still_exact(params):
    """A uniform-scheme verifier (no plan) with a planned draft: the
    engine quantizes the verifier through the scheme path and stays
    token-exact (no weight sharing possible, shared bytes == 0)."""
    ecfg = EngineConfig(max_len=32, weight_scheme="lq8w", a_bits=8,
                        kv_bits=8, kv_group=16, backend="ref")
    prompts = _prompts()
    max_new = [8, 6, 7]
    ref = _run_server(Server(TINY, params, ecfg, PCFG), prompts, max_new)
    eng = SpeculativeEngine(TINY, params, ecfg, PCFG,
                            draft_plan=_plan("lq2w"), spec_k=2)
    srv = Server(TINY, params, ecfg, PCFG, engine=eng)
    assert _run_server(srv, prompts, max_new) == ref
    assert eng.shared_weight_bytes() == 0


def test_speculative_survives_preemption_exactly(params):
    """Pool pressure under speculation: lookahead pages force preemption;
    the rolled-back victim still reproduces the verifier-only stream."""
    prompts = _prompts()[:2]
    max_new = [14, 14]
    ecfg = EngineConfig(max_len=32, plan=_plan("lq8w"), backend="ref")
    tight = PagedConfig(max_slots=2, page_size=4, n_pages=11,
                        max_context=32)
    ref = _run_server(Server(TINY, params, ecfg, tight), prompts, max_new)

    eng = SpeculativeEngine(TINY, params, ecfg, tight,
                            draft_plan=_plan("lq2w"), spec_k=2)
    srv = Server(TINY, params, ecfg, tight, engine=eng)
    outs = _run_server(srv, prompts, max_new)
    assert outs == ref
    assert srv.pool.n_allocated == 0


def test_identical_plans_accept_everything(params):
    """Draft == verifier: every proposal accepted, k tokens per cycle,
    verifier steps/token == 1/k, and the packed leaves are SHARED."""
    plan = _plan("lq8w", kv={}, kv_default=8)
    ecfg = EngineConfig(max_len=32, plan=plan, backend="ref")
    eng = SpeculativeEngine(TINY, params, ecfg, PCFG, draft_plan=plan,
                            spec_k=3)
    srv = Server(TINY, params, ecfg, PCFG, engine=eng)
    rid = srv.submit(_prompts()[0], RequestParams(max_new_tokens=13))
    srv.drain(max_steps=200)
    assert len(srv.output(rid)) == 13
    assert eng.acceptance_rate() == 1.0
    # 12 post-prefill tokens in 4 cycles of k=3
    assert eng.verify_steps_per_token() == pytest.approx(1 / 3, abs=0.01)
    assert srv.scheduler.stats()["rejected_tokens"] == 0
    # full sharing: draft params ARE the verifier's buffers
    v_leaves = jax.tree.leaves(eng.verifier.params["decoder"])
    d_leaves = jax.tree.leaves(eng.draft.params["decoder"])
    assert all(x is y for x, y in zip(v_leaves, d_leaves))
    assert eng.shared_weight_bytes() > 0


def test_rejected_tokens_counted_not_preempted(params):
    """The satellite bar: speculative rejections roll the slot back in
    place — rejected_tokens counts them, preemptions stays 0."""
    ecfg = EngineConfig(max_len=32, plan=_plan("lq8w"), backend="ref")
    eng = SpeculativeEngine(TINY, params, ecfg, PCFG,
                            draft_plan=_plan("lq2w"), spec_k=3)
    srv = Server(TINY, params, ecfg, PCFG, engine=eng)
    rids = [srv.submit(p, RequestParams(max_new_tokens=8))
            for p in _prompts()]
    srv.drain(max_steps=300)
    s = srv.scheduler.stats()
    assert s["rejected_tokens"] > 0              # 2-bit draft misses often
    assert s["preemptions"] == 0
    done = [srv.scheduler.request(r) for r in rids]
    assert sum(r.rejected_tokens for r in done) == s["rejected_tokens"]


# ---------------------------------------------------------------------------
# weight sharing mechanics
# ---------------------------------------------------------------------------

def test_shared_segment_keys_partial_overlap(params):
    verifier = QuantPlan.from_assignment({"layer.0": CANDS["lq8w"]},
                                         default=CANDS["lq4w"])
    draft = QuantPlan.from_assignment({"layer.0": CANDS["lq8w"]},
                                      default=CANDS["lq2w"])
    shared = shared_segment_keys(TINY, verifier, draft)
    assert shared                                # layer.0's segment aligns
    assert all(k[-1] == CANDS["lq8w"] for k in shared)
    eng = SpeculativeEngine(TINY, params,
                            EngineConfig(max_len=32, plan=verifier,
                                         backend="ref"),
                            PCFG, draft_plan=draft, spec_k=2)
    assert set(eng.shared_keys) == set(shared)
    assert 0 < eng.shared_weight_bytes()


def test_engine_validation(params):
    ecfg = EngineConfig(max_len=32, plan=_plan("lq8w"), backend="ref")
    with pytest.raises(ValueError, match="greedy-only"):
        SpeculativeEngine(TINY, params,
                          dataclasses.replace(ecfg, temperature=0.7),
                          PCFG, draft_plan=_plan("lq2w"))
    with pytest.raises(ValueError, match="spec_k"):
        SpeculativeEngine(TINY, params, ecfg, PCFG,
                          draft_plan=_plan("lq2w"), spec_k=0)
    with pytest.raises(ValueError, match="draft"):
        SpeculativeEngine(TINY, params, ecfg, PCFG, draft_plan=None)
    packed = transformer.quantize_params(params, TINY, _plan("lq8w"))
    with pytest.raises(ValueError, match="raw fp checkpoint"):
        SpeculativeEngine(TINY, packed, ecfg, PCFG,
                          draft_plan=_plan("lq2w"))


def test_draft_shadow_mirrors_verifier_plan_kv_map(params):
    """A verifier plan with a per-layer kv map must NOT leave the draft's
    shadow pool at fp pages: a draft plan without its own kv map mirrors
    the verifier's resolved per-layer layout."""
    verifier = _plan("lq8w", kv={"layer.0": 8}, kv_default=2)
    ecfg = EngineConfig(max_len=32, plan=verifier, backend="ref")
    eng = SpeculativeEngine(TINY, params, ecfg, PCFG,
                            draft_plan=_plan("lq2w"), spec_k=2)
    assert eng.draft._kv_layout == eng.verifier._kv_layout
    pool = eng.new_pool()
    assert pool.draft_nbytes() == pool.nbytes()     # same wire geometry
    assert "super_segments" in pool.draft.pages     # genuinely per-layer
    fp = PagedKVPool(TINY, n_pages=PCFG.n_pages,
                     page_size=PCFG.page_size).nbytes()
    assert pool.draft_nbytes() < fp                 # not fp fallback


def test_draft_rows_overwritten_before_read(params):
    """The no-rewind draft invariant: long drains never let a stale draft
    row reach an attention read (checked indirectly — a run with heavy
    rejection still matches the verifier-only stream exactly, which
    would fail if stale draft K/V leaked into later proposals' context
    and desynced the draft from its own accepted history)."""
    ecfg = EngineConfig(max_len=48, plan=_plan("lq8w"), backend="ref")
    pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=40,
                       max_context=48)
    prompts = _prompts(lens=(5, 9))
    max_new = [30, 24]
    ref = _run_server(Server(TINY, params, ecfg, pcfg), prompts, max_new)
    eng = SpeculativeEngine(TINY, params, ecfg, pcfg,
                            draft_plan=_plan("lq2w"), spec_k=4)
    srv = Server(TINY, params, ecfg, pcfg, engine=eng)
    assert _run_server(srv, prompts, max_new) == ref
    assert eng.decode_compilations == 1
