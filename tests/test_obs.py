"""Tests for repro.obs: tracer span trees, metric percentiles, no-op
cost, and the serve/spec/fleet wiring (traces + latency histograms with
no extra decode retraces and token-identical outputs)."""
import json

import jax
import numpy as np
import pytest

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.obs import (NOOP, DEFAULT_MS_BUCKETS, Histogram, MetricsRegistry,
                       Observability, Stopwatch, Tracer)
from repro.obs.check import check_metrics, check_trace
from repro.serve import EngineConfig, PagedConfig, RequestParams, Server

TINY = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")


class FakeClock:
    """Deterministic injectable clock: advance() moves time explicitly."""

    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

class TestTracer:
    def test_span_ts_dur_from_injected_clock(self):
        clk = FakeClock(10.0)
        tr = Tracer(clock=clk)
        with tr.span("outer"):
            clk.advance(0.5)
        ev = tr.events[0]
        assert ev["name"] == "outer" and ev["ph"] == "X"
        assert ev["ts"] == 0.0 and ev["dur"] == pytest.approx(0.5e6)

    def test_span_tree_nesting(self):
        clk = FakeClock()
        tr = Tracer(clock=clk)
        with tr.span("decode", step=0):
            with tr.span("draft"):
                clk.advance(0.001)
            with tr.span("verify"):
                clk.advance(0.002)
        with tr.span("decode", step=1):
            clk.advance(0.001)
        forest = tr.span_tree(tid=0)
        assert [n["name"] for n in forest] == ["decode", "decode"]
        assert [c["name"] for c in forest[0]["children"]] == \
            ["draft", "verify"]
        assert forest[0]["args"] == {"step": 0}
        assert forest[1]["children"] == []

    def test_span_tree_deterministic_under_frozen_clock(self):
        tr = Tracer(clock=lambda: 42.0)       # time never moves
        with tr.span("a"):
            with tr.span("b"):
                pass
            with tr.span("c"):
                pass
        (root,) = tr.span_tree()
        assert [c["name"] for c in root["children"]] == ["b", "c"]

    def test_lanes_are_independent(self):
        tr = Tracer(clock=FakeClock())
        r1 = tr.new_tid("req-1")
        r2 = tr.new_tid("req-2")
        assert r1 != r2 and r1 != 0
        with tr.span("request", tid=r1):
            with tr.span("decode"):           # engine lane, not nested in r1
                pass
        assert [n["name"] for n in tr.span_tree(tid=r1)] == ["request"]
        assert [n["name"] for n in tr.span_tree(tid=0)] == ["decode"]

    def test_retro_complete_span(self):
        clk = FakeClock(50.0)
        tr = Tracer(clock=clk)
        t0 = clk()
        clk.advance(1.25)
        tr.complete("request", t0, 1.25, tid=3, rid=7)
        ev = tr.events[0]
        assert ev["ts"] == pytest.approx(0.0)
        assert ev["dur"] == pytest.approx(1.25e6)
        assert ev["tid"] == 3 and ev["args"] == {"rid": 7}

    def test_chrome_export_is_valid(self):
        clk = FakeClock()
        tr = Tracer(clock=clk)
        tr.name_thread(0, "engine")
        rid = tr.new_tid("req-0")
        with tr.span("prefill", n_tokens=4):
            clk.advance(0.01)
        tr.event("first_token", tid=rid)
        doc = json.loads(tr.to_json())
        assert doc["displayTimeUnit"] == "ms"
        phs = {ev["ph"] for ev in doc["traceEvents"]}
        assert phs == {"M", "X", "i"}
        names = {ev["name"] for ev in doc["traceEvents"] if ev["ph"] == "M"}
        assert names == {"process_name", "thread_name"}
        for ev in doc["traceEvents"]:
            assert "depth" not in ev       # internal field stays internal

    def test_instant_event_fields(self):
        tr = Tracer(clock=FakeClock())
        tr.event("preempt", rid=2)
        ev = tr.events[0]
        assert ev["ph"] == "i" and ev["s"] == "t" and ev["args"]["rid"] == 2


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class TestHistogram:
    def test_percentiles_uniform(self):
        h = Histogram(DEFAULT_MS_BUCKETS)
        for v in range(1, 101):               # 1..100 ms
            h.record(float(v))
        assert h.count == 100
        assert h.percentile(50) == pytest.approx(50.0, rel=0.25)
        assert h.percentile(95) == pytest.approx(95.0, rel=0.25)
        assert h.percentile(99) == pytest.approx(99.0, rel=0.25)

    def test_percentile_clamped_to_observed_range(self):
        h = Histogram(DEFAULT_MS_BUCKETS)
        h.record(3.0)
        h.record(3.5)
        assert h.percentile(0) >= 3.0
        assert h.percentile(100) <= 3.5

    def test_overflow_bucket_reports_max(self):
        h = Histogram(buckets=(1.0, 2.0))
        h.record(1000.0)
        assert h.percentile(99) == 1000.0
        assert h.snapshot()["max"] == 1000.0

    def test_snapshot_fields(self):
        h = Histogram(DEFAULT_MS_BUCKETS)
        h.record(2.0)
        snap = h.snapshot()
        for field in ("count", "sum", "min", "max", "p50", "p95", "p99"):
            assert field in snap
        assert snap["count"] == 1 and snap["sum"] == 2.0


class TestRegistry:
    def test_counter_gauge_histogram_and_labels(self):
        reg = MetricsRegistry()
        reg.counter("toks", tenant="gold").inc(3)
        reg.counter("toks", tenant="gold").inc()
        reg.counter("toks", tenant="bronze").inc()
        reg.gauge("occ").set(0.5)
        reg.histogram("lat_ms").record(4.0)
        snap = reg.snapshot()
        assert snap["counters"]['toks{tenant="gold"}'] == 4
        assert snap["counters"]['toks{tenant="bronze"}'] == 1
        assert snap["gauges"]["occ"] == 0.5
        assert snap["histograms"]["lat_ms"]["count"] == 1

    def test_find_does_not_create(self):
        reg = MetricsRegistry()
        assert reg.find("nope") is None
        assert reg.snapshot()["counters"] == {}
        reg.counter("yes").inc()
        assert reg.find("yes").value == 1

    def test_kind_collision_raises(self):
        reg = MetricsRegistry()
        reg.counter("m")
        with pytest.raises(TypeError):
            reg.gauge("m")

    def test_prometheus_export(self):
        reg = MetricsRegistry()
        reg.counter("toks", tenant="gold").inc(2)
        reg.histogram("lat_ms", buckets=(1.0, 10.0)).record(5.0)
        text = reg.to_prometheus()
        assert '# TYPE toks counter' in text
        assert 'toks{tenant="gold"} 2' in text
        assert 'lat_ms_bucket{le="10"} 1' in text
        assert 'lat_ms_bucket{le="+Inf"} 1' in text
        assert 'lat_ms_count 1' in text

    def test_save_selects_format_by_suffix(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("c").inc()
        p_json = tmp_path / "m.json"
        p_prom = tmp_path / "m.prom"
        reg.save(str(p_json))
        reg.save(str(p_prom))
        assert json.loads(p_json.read_text())["counters"]["c"] == 1
        assert p_prom.read_text().startswith("# TYPE c counter")

    def test_stopwatch_uses_injected_clock(self):
        clk = FakeClock(7.0)
        sw = Stopwatch(clock=clk)
        clk.advance(0.25)
        assert sw.elapsed() == pytest.approx(0.25)
        assert sw.elapsed_ms() == pytest.approx(250.0)
        sw.reset()
        assert sw.elapsed() == 0.0


# ---------------------------------------------------------------------------
# no-op path
# ---------------------------------------------------------------------------

class TestNoop:
    def test_noop_records_nothing(self):
        obs = Observability(enabled=False)
        with obs.span("decode"):
            pass
        obs.event("preempt")
        obs.metrics.counter("c", tenant="x").inc(5)
        obs.metrics.histogram("h").record(1.0)
        assert obs.tracer.events == ()
        assert obs.metrics.snapshot() == {}
        assert obs.metrics.find("c", tenant="x") is None

    def test_noop_singleton_disabled(self):
        assert NOOP.enabled is False
        assert NOOP.tracer.enabled is False
        assert NOOP.metrics.enabled is False


# ---------------------------------------------------------------------------
# serve wiring
# ---------------------------------------------------------------------------

def _serve(obs=None, n_req=3, max_new=6, seed=0):
    params = transformer.init_params(TINY, jax.random.key(0))
    ecfg = EngineConfig(max_len=32, kv_bits=8, kv_group=16, backend="ref")
    pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=24, max_context=32)
    server = Server(TINY, params, ecfg, pcfg, seed=seed, obs=obs)
    rng = np.random.default_rng(3)
    rids = [server.submit(list(map(int, rng.integers(0, 256, size=5))),
                          RequestParams(max_new_tokens=max_new))
            for _ in range(n_req)]
    server.drain()
    return server, [server.output(r) for r in rids]


class TestServeWiring:
    def test_trace_and_metrics_valid(self):
        obs = Observability()
        server, _ = _serve(obs=obs)
        names = check_trace(obs.tracer.to_chrome())
        assert names["prefill"] == 3 and names["queued"] == 3
        assert names["request"] == 3 and names["decode"] >= 1
        keys = check_metrics(obs.metrics.snapshot())
        assert 'serve_ttft_ms{tenant="default"}' in keys
        ttft = obs.metrics.find("serve_ttft_ms", tenant="default")
        assert ttft.count == 3
        itl = obs.metrics.find("serve_itl_ms", tenant="default")
        assert itl.count == 3 * (6 - 1)       # max_new-1 gaps per request
        assert obs.metrics.find("serve_tokens_total",
                                tenant="default").value == 18
        assert obs.metrics.find("serve_completions_total",
                                tenant="default").value == 3

    def test_tokens_identical_and_no_retrace(self):
        _, plain = _serve(obs=None)
        server, traced = _serve(obs=Observability())
        assert traced == plain                 # instrumentation is invisible
        assert server.engine.decode_compilations == 1

    def test_request_lane_carries_lifecycle(self):
        obs = Observability()
        server, _ = _serve(obs=obs, n_req=1)
        req = server.scheduler.request(0)
        assert req.trace_tid != 0
        lane = obs.tracer.span_tree(tid=req.trace_tid)
        assert sorted(n["name"] for n in lane) == ["queued", "request"]
        events = [e["name"] for e in obs.tracer.events
                  if e["tid"] == req.trace_tid and e["ph"] == "i"]
        assert "submit" in events and "first_token" in events

    def test_set_obs_swaps_sink(self):
        server, _ = _serve(obs=None)
        obs = Observability()
        server.set_obs(obs)
        server.submit([1, 2, 3], RequestParams(max_new_tokens=3))
        server.drain()
        assert obs.metrics.find("serve_ttft_ms", tenant="default").count == 1
        assert any(e["name"] == "prefill" for e in obs.tracer.events)

    def test_pool_events(self):
        obs = Observability()
        _serve(obs=obs, n_req=2)
        allocs = [e for e in obs.tracer.events if e["name"] == "alloc"]
        frees = [e for e in obs.tracer.events if e["name"] == "free"]
        assert len(allocs) >= 2 and len(frees) == 2   # growth allocs too
        pages = sum(e["args"]["n_pages"] for e in allocs)
        assert obs.metrics.find("pool_alloc_total").value == pages


# ---------------------------------------------------------------------------
# speculative wiring
# ---------------------------------------------------------------------------

class TestSpecWiring:
    def test_draft_verify_spans_and_counters(self):
        from repro.plan import QuantPlan
        from repro.plan.plan import candidates_for
        from repro.spec import SpeculativeEngine
        cands = candidates_for(TINY, ["lq2w"])
        params = transformer.init_params(TINY, jax.random.key(0))
        ecfg = EngineConfig(max_len=32, kv_bits=8, kv_group=16,
                            backend="ref")
        pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=24,
                           max_context=32)
        obs = Observability()
        eng = SpeculativeEngine(TINY, params, ecfg, pcfg,
                                draft_plan=QuantPlan(default=cands["lq2w"]),
                                spec_k=3, obs=obs)
        server = Server(TINY, params, ecfg, pcfg, engine=eng, obs=obs)
        rng = np.random.default_rng(3)
        server.submit(list(map(int, rng.integers(0, 256, size=5))),
                      RequestParams(max_new_tokens=6))
        server.drain()
        check_trace(obs.tracer.to_chrome(), spec=True)
        check_metrics(obs.metrics.snapshot(), spec=True)
        decodes = [n for n in obs.tracer.span_tree(tid=0)
                   if n["name"] == "decode"]
        assert decodes, "no decode spans on the engine lane"
        kids = [c["name"] for c in decodes[0]["children"]]
        assert kids == ["draft", "verify"]
        drafted = obs.metrics.find("spec_drafted_total").value
        accepted = obs.metrics.find("spec_accepted_total").value
        assert drafted > 0 and 0 <= accepted <= drafted
        rate = obs.metrics.find("spec_acceptance_rate").value
        assert rate == pytest.approx(accepted / drafted)
        assert eng.decode_compilations == 1    # batched verify: one trace
        draft_hist = obs.metrics.find("serve_decode_step_ms", engine="draft")
        assert draft_hist is not None and draft_hist.count > 0


# ---------------------------------------------------------------------------
# fleet wiring + telemetry
# ---------------------------------------------------------------------------

class TestFleetTelemetry:
    def test_degenerate_window_still_reports_rate(self):
        from repro.fleet import FleetTelemetry
        t = FleetTelemetry(clock=lambda: 5.0, min_window_s=1e-3)
        t.note_step("a", 0.25)                # first == last step instant
        t.note_token("a")
        t.note_token("a")
        snap = t.snapshot()
        assert snap["tenants"]["a"]["tok_per_s"] == pytest.approx(2000.0)
        assert snap["aggregate"]["tok_per_s"] == pytest.approx(2000.0)

    def test_idle_tenant_still_zero(self):
        from repro.fleet import FleetTelemetry
        t = FleetTelemetry(clock=lambda: 5.0)
        t.register("idle")
        assert t.snapshot()["tenants"]["idle"]["tok_per_s"] == 0.0

    def test_moving_clock_unchanged_by_floor(self):
        from repro.fleet import FleetTelemetry
        clk = FakeClock(0.0)
        t = FleetTelemetry(clock=clk)
        t.note_step("a", 0.5)
        for _ in range(4):
            t.note_token("a")
        clk.advance(2.0)
        t.note_step("a", 0.5)
        assert t.snapshot()["tenants"]["a"]["tok_per_s"] == \
            pytest.approx(2.0)

    def test_snapshot_merges_latency_percentiles(self):
        from repro.fleet import FleetTelemetry
        obs = Observability()
        obs.metrics.histogram("serve_ttft_ms", tenant="gold").record(10.0)
        obs.metrics.histogram("serve_itl_ms", tenant="gold").record(2.0)
        t = FleetTelemetry(obs=obs)
        t.note_step("gold", 0.1)
        snap = t.snapshot()
        assert "p50" in snap["tenants"]["gold"]["ttft_ms"]
        assert "p95" in snap["tenants"]["gold"]["itl_ms"]

    def test_router_snapshot_has_per_tenant_latency(self):
        from repro.fleet import FleetRegistry, FleetRouter, TenantSpec
        params = transformer.init_params(TINY, jax.random.key(0))
        registry = FleetRegistry(TINY, params, budget_mb=64, backend="ref")
        for tid, scheme, bits in (("gold", "lq8w", 8), ("bronze", "lq2w", 2)):
            registry.register(TenantSpec(tid, scheme=scheme, kv_bits=bits,
                                         kv_group=16, max_slots=2,
                                         page_size=4, n_pages=16,
                                         max_context=24))
        router = FleetRouter(registry, obs=Observability())
        rng = np.random.default_rng(0)
        for tid in ("gold", "bronze"):
            router.submit(tid, list(map(int, rng.integers(0, 256, size=6))),
                          max_new_tokens=4)
        router.drain(max_steps=1000)
        snap = router.telemetry.snapshot()
        for tid in ("gold", "bronze"):
            assert snap["tenants"][tid]["ttft_ms"]["p50"] > 0
            assert snap["tenants"][tid]["itl_ms"]["p95"] > 0
        check_trace(router.obs.tracer.to_chrome())
