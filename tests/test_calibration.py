"""core/calibration.py observers: convergence, robustness, jit-compat.

The mixed-precision sensitivity profiler (repro/plan/sensitivity.py)
leans on these observers for per-layer activation ranges, so their
numerics get dedicated coverage here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import calibration


def _stream(n=16, size=2048, lo=-3.0, hi=5.0, seed=0):
    key = jax.random.key(seed)
    for i in range(n):
        k = jax.random.fold_in(key, i)
        yield jax.random.uniform(k, (size,), minval=lo, maxval=hi)


# ---------------------------------------------------------------------------
# minmax
# ---------------------------------------------------------------------------

def test_minmax_tracks_true_range():
    state = calibration.init("minmax")
    for x in _stream():
        state = calibration.update(state, x)
    lo, hi = calibration.bounds(state)
    assert -3.0 <= float(lo) < -2.8 and 4.8 < float(hi) <= 5.0
    assert int(state.count) == 16


def test_unknown_observer_rejected():
    with pytest.raises(ValueError):
        calibration.init("median")


# ---------------------------------------------------------------------------
# EMA: bounds converge to the stationary batch extremes
# ---------------------------------------------------------------------------

def test_ema_first_batch_initializes_exactly():
    state = calibration.init("ema", momentum=0.9)
    x = jnp.asarray([-1.0, 2.0])
    state = calibration.update(state, x)
    lo, hi = calibration.bounds(state)
    assert float(lo) == -1.0 and float(hi) == 2.0


def test_ema_converges_on_stationary_stream():
    """On an i.i.d. stream the EMA bounds converge toward the typical
    per-batch extremes and stay inside the global envelope."""
    state = calibration.init("ema", momentum=0.8)
    batch_los, batch_his = [], []
    for x in _stream(n=50, seed=3):
        state = calibration.update(state, x)
        batch_los.append(float(x.min()))
        batch_his.append(float(x.max()))
    lo, hi = calibration.bounds(state)
    assert np.min(batch_los) <= float(lo) <= np.mean(batch_los) + 0.05
    assert np.mean(batch_his) - 0.05 <= float(hi) <= np.max(batch_his)


def test_ema_forgets_transients_minmax_does_not():
    """An early outlier batch decays out of the EMA range but pins the
    min/max observer forever — the reason EMA exists."""
    ema = calibration.init("ema", momentum=0.7)
    mm = calibration.init("minmax")
    spike = jnp.asarray([-100.0, 100.0])
    ema = calibration.update(ema, spike)
    mm = calibration.update(mm, spike)
    for x in _stream(n=40, seed=5):
        ema = calibration.update(ema, x)
        mm = calibration.update(mm, x)
    elo, ehi = calibration.bounds(ema)
    mlo, mhi = calibration.bounds(mm)
    assert float(ehi) < 10.0 and float(elo) > -10.0     # spike decayed
    assert float(mhi) == 100.0 and float(mlo) == -100.0  # spike pinned


# ---------------------------------------------------------------------------
# percentile: histogram quantiles, outlier robustness
# ---------------------------------------------------------------------------

def test_percentile_bounds_clip_outliers():
    state = calibration.init("percentile", percentile=99.0,
                             hist_range=(-30.0, 30.0))
    key = jax.random.key(7)
    for i in range(8):
        x = jax.random.normal(jax.random.fold_in(key, i), (4096,))
        x = x.at[0].set(25.0)                  # 1 / 4096 outlier per batch
        state = calibration.update(state, x)
    lo, hi = calibration.bounds(state)
    assert float(hi) < 5.0                     # outlier excluded
    assert float(lo) > -5.0
    assert 1.5 < float(hi)                     # but the bulk is covered


def test_percentile_empty_histogram_falls_back_to_minmax():
    state = calibration.init("percentile")
    lo, hi = calibration.bounds(state)
    assert not np.isfinite(float(lo)) or float(lo) > 0  # inf sentinel
    state = calibration.update(state, jnp.asarray([0.5, 1.5]))
    lo, hi = calibration.bounds(state)
    assert 0.0 <= float(lo) <= 0.6 and 1.4 <= float(hi) <= 1.6


def test_percentile_converges_to_quantiles():
    """Histogram CDF read-out approximates the true stream quantiles."""
    state = calibration.init("percentile", percentile=97.5,
                             hist_range=(-30.0, 30.0))
    xs = []
    for x in _stream(n=30, lo=-8.0, hi=8.0, seed=11):
        state = calibration.update(state, x)
        xs.append(np.asarray(x))
    lo, hi = calibration.bounds(state)
    want_hi = np.quantile(np.concatenate(xs), 0.975)
    want_lo = np.quantile(np.concatenate(xs), 0.025)
    assert abs(float(hi) - want_hi) < 0.25     # bin width ~0.03
    assert abs(float(lo) - want_lo) < 0.25


# ---------------------------------------------------------------------------
# jit-compatibility: observers run inside jit / scan unchanged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["minmax", "ema", "percentile"])
def test_update_is_jittable(kind):
    state = calibration.init(kind)
    xs = jnp.stack([x for x in _stream(n=6, size=128, seed=13)])
    step = jax.jit(calibration.update)
    for x in xs:
        state = step(state, x)
    ref = calibration.init(kind)
    for x in xs:
        ref = calibration.update(ref, x)
    np.testing.assert_allclose(np.asarray(calibration.bounds(state)),
                               np.asarray(calibration.bounds(ref)),
                               rtol=1e-6)


@pytest.mark.parametrize("kind", ["minmax", "ema", "percentile"])
def test_observer_state_scans(kind):
    """ObserverState is a registered pytree: lax.scan carries it."""
    xs = jnp.stack([x for x in _stream(n=8, size=256, seed=17)])

    def body(state, x):
        return calibration.update(state, x), ()

    state, _ = jax.lax.scan(body, calibration.init(kind), xs)
    lo, hi = calibration.bounds(state)
    assert float(lo) < float(hi)
    assert int(state.count) == 8


def test_calibrate_helper_end_to_end():
    lo, hi = calibration.calibrate(lambda b: b * 2.0,
                                   list(_stream(n=4, seed=19)),
                                   kind="minmax")
    assert float(lo) >= -6.0 and float(hi) <= 10.0
    assert float(hi) > 9.0
