"""Property tests for the heterogeneous paged KV pool.

Random alloc/free/defrag sequences against a pool with per-layer page
geometry must preserve the allocator invariants the decode path relies
on: the scratch page 0 is never handed out, no physical page is ever
owned by two requests (page ids are global across layers, so per-slot
disjointness IS cross-layer disjointness), the free list and the page
tables partition the allocatable pages, and defrag compacts to
``[1, n_allocated]`` while preserving each request's page order.  Pool bytes
are checked against the exact per-layer wire arithmetic
(``kvwire.kv_token_nbytes``), not just monotonicity.

Hypothesis is optional extra coverage (same guard as tests/test_packing.py);
the exact-bytes and example-sequence tests always run.
"""
import jax
import numpy as np
import pytest

try:        # property tests are extra coverage; the container may lack it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import kvwire
from repro.models.config import ModelConfig
from repro.serve import PagedKVPool, pool_nbytes

TINY = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")

KV_MAPS = [(8, None, 2), (2, 2, 8), (None, 1, 4), (8, 8, 8), (None,) * 3]
N_PAGES, PAGE_SIZE, KV_GROUP = 8, 4, 16


def _expected_nbytes(cfg, kv_map, n_pages, page_size, kv_group):
    """Sum of exact per-layer page bytes, from the wire format arithmetic."""
    per_token = sum(
        kvwire.kv_token_nbytes(cfg.n_kv_heads, cfg.head_dim, b, kv_group,
                               fp_itemsize=cfg.activation_dtype.itemsize)
        for b in kv_map)
    return int(per_token * page_size * n_pages)


@pytest.mark.parametrize("kv_map", KV_MAPS)
def test_pool_nbytes_is_sum_of_per_layer_page_bytes(kv_map):
    got = pool_nbytes(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                      kv_bits=kv_map, kv_group=KV_GROUP)
    assert got == _expected_nbytes(TINY, kv_map, N_PAGES, PAGE_SIZE,
                                   KV_GROUP)


def _check_invariants(pool):
    tables = {rid: list(t) for rid, t in pool.page_tables.items()}
    owned = [p for t in tables.values() for p in t]
    # scratch page 0 stays reserved
    assert 0 not in owned and 0 not in pool._free
    # no page aliased across requests (page ids are global across layers)
    assert len(owned) == len(set(owned))
    # free list and tables partition the allocatable pages
    assert not set(owned) & set(pool._free)
    assert sorted(owned + list(pool._free)) == list(range(1, pool.n_pages))
    assert pool.n_allocated == len(owned)
    assert pool.n_free == pool.n_allocatable - len(owned)


def _run_ops(pool, ops):
    """Drive the allocator; returns {rid: pages} shadow bookkeeping."""
    shadow = {}
    for kind, rid, n in ops:
        if kind == 0:                       # alloc
            before = pool.pages_of(rid)
            ok = pool.alloc(rid, n)
            after = pool.pages_of(rid)
            if ok:
                assert after[:len(before)] == before    # append-only
                assert len(after) == len(before) + n
                shadow[rid] = after
            else:                           # all-or-nothing on exhaustion
                assert after == before
                assert n > pool.n_free
        elif kind == 1:                     # free
            freed = pool.free(rid)
            assert freed == len(shadow.pop(rid, []))
        elif kind == 2:                     # defrag
            mapping = pool.defrag()
            assert set(mapping) == {p for t in shadow.values() for p in t}
            shadow = {rid: [mapping[p] for p in t]
                      for rid, t in shadow.items()}
            # compact: allocated pages are exactly [1, n_allocated],
            # preserving each request's page order
            owned = sorted(p for t in shadow.values() for p in t)
            assert owned == list(range(1, pool.n_allocated + 1))
        else:                               # truncate (speculative rewind)
            owned = shadow.get(rid, [])
            keep = min(n, len(owned) * pool.page_size)
            keep_pages = -(-keep // pool.page_size)
            freed = pool.truncate(rid, keep)
            assert freed == len(owned) - keep_pages
            if owned:
                shadow[rid] = owned[:keep_pages]
        for rid2, t in shadow.items():
            assert pool.pages_of(rid2) == t
        _check_invariants(pool)
    return shadow


def test_example_sequence_all_maps():
    """Deterministic walk of every kv map (always runs, no hypothesis)."""
    ops = [(0, 1, 2), (0, 2, 3), (3, 2, 7), (1, 1, 0), (2, 0, 0),
           (0, 3, 4), (3, 3, 9), (3, 3, 2), (0, 4, 9), (1, 2, 0),
           (2, 0, 0), (0, 5, 1), (3, 5, 0), (1, 3, 0), (2, 0, 0)]
    for kv_map in KV_MAPS:
        pool = PagedKVPool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                           kv_bits=kv_map, kv_group=KV_GROUP)
        _run_ops(pool, ops)
        assert pool.nbytes() == _expected_nbytes(
            TINY, kv_map, N_PAGES, PAGE_SIZE, KV_GROUP)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        kv_map=st.sampled_from(KV_MAPS),
        ops=st.lists(
            st.tuples(st.integers(0, 3),    # alloc/free/defrag/truncate
                      st.integers(1, 5),    # rid
                      st.integers(0, 12)),  # pages requested / keep tokens
            min_size=1, max_size=24),
    )
    def test_random_alloc_free_defrag_never_aliases(kv_map, ops):
        pool = PagedKVPool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                           kv_bits=kv_map, kv_group=KV_GROUP)
        _run_ops(pool, ops)
        assert pool.nbytes() == _expected_nbytes(
            TINY, kv_map, N_PAGES, PAGE_SIZE, KV_GROUP)


def _defrag_data_check(kv_map, sizes, victim):
    """Write a sentinel token row into every allocated page of every layer
    (at that layer's own wire format), shuffle the pool with frees +
    defrag, and check each surviving request still reads its own rows —
    i.e. pages never alias across slots or layers under compaction."""
    pool = PagedKVPool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                       kv_bits=kv_map, kv_group=KV_GROUP)
    rids = [1, 2, 3]
    for r, n in zip(rids, sizes):
        assert pool.alloc(r, n)

    # one token row per rid, scattered into page row 0 of its first page
    # at that layer's own wire format (every run has stack size 1 here)
    import jax.numpy as jnp
    toks = {r: jax.random.normal(jax.random.key(r),
                                 (1, 1, TINY.n_kv_heads, TINY.head_dim))
            for r in rids}
    for s, seg in enumerate(pool.pages["super_segments"]):
        bits = kv_map[s]
        kw = {} if bits is None else dict(bits=bits, group_size=KV_GROUP)
        leaf = jax.tree.map(lambda a: a[0], seg[0]["self"]["k"])
        for r in rids:
            page = jnp.asarray([pool.pages_of(r)[0]])
            row = jnp.asarray([0])
            leaf = kvwire.scatter_token(leaf, toks[r], page, row, **kw)
        seg[0]["self"]["k"] = jax.tree.map(lambda a: a[None], leaf)

    def slot_views():
        """{(seg, rid): full gathered wire view of rid's pages}."""
        out = {}
        for s, seg in enumerate(pool.pages["super_segments"]):
            leaf = jax.tree.map(lambda a: a[0], seg[0]["self"]["k"])
            for r in rids:
                if r == victim and victim_freed[0]:
                    continue
                tbl = jnp.asarray([pool.pages_of(r)], jnp.int32)
                out[(s, r)] = kvwire.gather_pages(leaf, tbl)
        return out

    victim_freed = [False]
    before = slot_views()
    victim_freed[0] = True
    pool.free(victim)
    pool.defrag()
    after = slot_views()
    # a defrag is a pure page permutation: every surviving request reads
    # back byte-identical wire data at every layer's own format
    for key, want in before.items():
        if key[1] == victim:
            continue
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), want, after[key])


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(kv_map=st.sampled_from([(8, None, 2), (2, 1, 8)]),
           sizes=st.tuples(st.integers(1, 2), st.integers(1, 2),
                           st.integers(1, 2)),
           victim=st.sampled_from([1, 2, 3]))
    def test_defrag_preserves_slot_data_across_geometries(kv_map, sizes,
                                                          victim):
        _defrag_data_check(kv_map, sizes, victim)
else:
    def test_defrag_preserves_slot_data_example():
        """Hypothesis-free fallback: fixed draws of the same property."""
        _defrag_data_check((8, None, 2), (2, 1, 2), 2)
        _defrag_data_check((2, 1, 8), (1, 2, 1), 1)


def _truncate_data_check(kv_map, keep_tokens):
    """Speculative-rewind property on mixed geometry: truncating one rid
    (1) leaves every other rid's wire data byte-identical at every
    layer's own format, (2) leaves the kept prefix rows intact, and
    (3) resets the dropped rows to the exact zero wire state — the byte
    sums of a rewound pool match a pool that never wrote them."""
    import jax.numpy as jnp
    pool = PagedKVPool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                       kv_bits=kv_map, kv_group=KV_GROUP)
    fresh = PagedKVPool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                        kv_bits=kv_map, kv_group=KV_GROUP)
    rids, n_pages_each = [1, 2], 3
    for r in rids:
        assert pool.alloc(r, n_pages_each)
    total = n_pages_each * PAGE_SIZE
    x = jax.random.normal(jax.random.key(7),
                          (1, total, TINY.n_kv_heads, TINY.head_dim))
    for s, seg in enumerate(pool.pages["super_segments"]):
        bits = kv_map[s]
        kw = {} if bits is None else dict(bits=bits, group_size=KV_GROUP)
        leaf = jax.tree.map(lambda a: a[0], seg[0]["self"]["k"])
        for r in rids:
            ids = pool.pages_of(r)
            page_idx = jnp.asarray([[ids[t // PAGE_SIZE]
                                     for t in range(total)]])
            row = jnp.asarray([[t % PAGE_SIZE for t in range(total)]])
            leaf = kvwire.scatter_tokens(leaf, x, page_idx, row, **kw)
        seg[0]["self"]["k"] = jax.tree.map(lambda a: a[None], leaf)

    def rows_of(r):
        tbl = jnp.asarray([pool.pages_of(r)], jnp.int32)
        return [jax.tree.map(
            lambda a: np.asarray(kvwire.gather_pages(a[0], tbl)),
            seg[0]["self"]["k"])
            for seg in pool.pages["super_segments"]]

    before = {r: rows_of(r) for r in rids}
    old_pages_1 = pool.pages_of(1)
    freed = pool.truncate(1, keep_tokens)
    assert freed == n_pages_each - -(-keep_tokens // PAGE_SIZE)
    _check_invariants(pool)
    # (1) the untouched rid reads back byte-identical wire data
    for want, got in zip(before[2], rows_of(2)):
        jax.tree.map(np.testing.assert_array_equal, want, got)
    kept_pages = pool.pages_of(1)
    assert kept_pages == old_pages_1[:len(kept_pages)]   # no realloc
    dropped = [p for p in old_pages_1 if p not in kept_pages]
    for s, seg in enumerate(pool.pages["super_segments"]):
        leaf = jax.tree.map(lambda a: np.asarray(a[0]),
                            seg[0]["self"]["k"])
        fresh_leaf = jax.tree.map(
            lambda a: np.asarray(a[0]),
            fresh.pages["super_segments"][s][0]["self"]["k"])
        view = before[1][s]          # gathered (1, total, ...) pre-rewind
        for t in range(len(kept_pages) * PAGE_SIZE):
            got = jax.tree.map(
                lambda a: a[kept_pages[t // PAGE_SIZE], t % PAGE_SIZE],
                leaf)
            if t < keep_tokens:      # (2) kept prefix intact
                jax.tree.map(
                    lambda a, w: np.testing.assert_array_equal(a, w[0, t]),
                    got, view)
            else:                    # (3) rewound rows: zero wire state
                jax.tree.map(
                    lambda a, f: np.testing.assert_array_equal(
                        a, f[0, t % PAGE_SIZE]),
                    got, fresh_leaf)
        # (3) released pages read as never-written pool bytes
        for p in dropped:
            jax.tree.map(
                lambda a, b: np.testing.assert_array_equal(a[p], b[p]),
                leaf, fresh_leaf)


@pytest.mark.parametrize("keep_tokens", [0, 3, 4, 7, 12])
def test_truncate_preserves_other_slots_and_zeroes_suffix(keep_tokens):
    _truncate_data_check((8, None, 2), keep_tokens)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(kv_map=st.sampled_from([(8, None, 2), (2, 2, 8), (2, 1, 8)]),
           keep_tokens=st.integers(0, 12))
    def test_truncate_property_mixed_geometry(kv_map, keep_tokens):
        _truncate_data_check(kv_map, keep_tokens)


SCRATCH_SENTINEL = 1e33


def _poison_scratch(pool):
    """Fill page 0 with unmistakable garbage at every leaf — the state a
    decode step leaves behind after scatter-writing inactive slots (whose
    padded table entries all point at scratch)."""
    import jax.numpy as jnp

    def poison(a):
        bad = 255 if a.dtype == jnp.uint8 else SCRATCH_SENTINEL
        # page axis: 1 on stacked super leaves (S, n_pages, ps, KV, ·),
        # 0 on tail leaves (n_pages, ps, KV, ·)
        return a.at[:, 0].set(bad) if a.ndim == 5 else a.at[0].set(bad)

    pool.pages = jax.tree.map(poison, pool.pages)


def _iter_page_leaves(pool):
    """Every (n_pages, page_size, ...) array of the pool, destacked."""
    pages = pool.pages
    blocks = []
    if "super_segments" in pages:
        for seg in pages["super_segments"]:
            blocks.extend((blk, True) for blk in seg)
    elif pages.get("super"):
        blocks.extend((blk, True) for blk in pages["super"])
    for blk in pages.get("tail", ()):
        blocks.append((blk, False))
    for blk, stacked in blocks:
        for leaf in blk.get("self", {}).values():
            for a in jax.tree.leaves(leaf):
                if stacked:
                    for i in range(a.shape[0]):
                        yield a[i]
                else:
                    yield a


def _assert_live_rows_clean(pool, rid):
    """The hygiene property: the first ``live`` rows of rid's gathered
    view (the only rows the position mask ever exposes) contain no trace
    of scratch.  With no real writes in these sequences, clean == the
    exact zero wire state."""
    n = len(pool.pages_of(rid))
    if not n:
        return
    live = n * pool.page_size
    tbl = np.asarray(pool.table_array(rid, pool.n_pages))
    assert (tbl[:n] != 0).all()          # live prefix never maps to scratch
    for a in _iter_page_leaves(pool):
        view = np.asarray(a)[tbl].reshape(-1, *a.shape[2:])[:live]
        assert not view.any(), \
            f"scratch bytes leaked into rid {rid}'s live rows"


def _scratch_hygiene_check(kv_map, ops):
    pool = PagedKVPool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                       kv_bits=kv_map, kv_group=KV_GROUP)
    _poison_scratch(pool)
    shadow = _run_ops(pool, ops)
    for rid in shadow:
        _assert_live_rows_clean(pool, rid)
    # scratch is STILL garbage: hygiene is an allocator + position-mask
    # guarantee (page 0 is never handed out; padded table entries sit past
    # the live prefix), not a zeroing pass — nothing needs to scrub it
    dirty = any(bool(np.asarray(a)[0].all())
                for a in _iter_page_leaves(pool))
    assert dirty, "scratch was scrubbed: the test lost its teeth"


def test_scratch_garbage_never_reaches_live_rows():
    """Overflow + free + defrag + truncate + realloc with a poisoned
    scratch page: no sequence can surface scratch bytes inside any
    slot's position-visible rows (the fused kernel and the XLA gather
    both read exactly these rows)."""
    ops = [(0, 1, 3), (0, 2, 3), (0, 3, 9), (1, 1, 0), (2, 0, 0),
           (0, 3, 4), (3, 3, 5), (0, 1, 2), (1, 2, 0), (0, 4, 9),
           (2, 0, 0), (0, 4, 2), (3, 4, 0), (0, 2, 1)]
    for kv_map in [(8, None, 2), (8, 8, 8), (None,) * 3]:
        _scratch_hygiene_check(kv_map, ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(kv_map=st.sampled_from(KV_MAPS),
           ops=st.lists(
               st.tuples(st.integers(0, 3), st.integers(1, 5),
                         st.integers(0, 12)),
               min_size=1, max_size=24))
    def test_scratch_hygiene_property(kv_map, ops):
        _scratch_hygiene_check(kv_map, ops)


def test_random_write_rewind_defrag_sequences():
    """Interleaved write/rewind/defrag on mixed geometry: rewinds never
    alias pages (invariants hold at every step) and the allocator's view
    stays consistent with the shadow bookkeeping."""
    rng = np.random.default_rng(11)
    for kv_map in KV_MAPS[:3]:
        pool = PagedKVPool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                           kv_bits=kv_map, kv_group=KV_GROUP)
        ops = [(int(rng.integers(0, 4)), int(rng.integers(1, 5)),
                int(rng.integers(0, 12))) for _ in range(40)]
        _run_ops(pool, ops)
        assert pool.nbytes() == _expected_nbytes(
            TINY, kv_map, N_PAGES, PAGE_SIZE, KV_GROUP)
