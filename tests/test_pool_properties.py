"""Property tests for the heterogeneous paged KV pool.

Random alloc/free/defrag sequences against a pool with per-layer page
geometry must preserve the allocator invariants the decode path relies
on: the scratch page 0 is never handed out, no physical page is ever
owned by two requests (page ids are global across layers, so per-slot
disjointness IS cross-layer disjointness), the free list and the page
tables partition the allocatable pages, and defrag compacts to
``[1, n_allocated]`` while preserving each request's page order.  Pool bytes
are checked against the exact per-layer wire arithmetic
(``kvwire.kv_token_nbytes``), not just monotonicity.

Hypothesis is optional extra coverage (same guard as tests/test_packing.py);
the exact-bytes and example-sequence tests always run.
"""
import jax
import numpy as np
import pytest

try:        # property tests are extra coverage; the container may lack it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import kvwire
from repro.models.config import ModelConfig
from repro.serve import PagedKVPool, pool_nbytes

TINY = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")

KV_MAPS = [(8, None, 2), (2, 2, 8), (None, 1, 4), (8, 8, 8), (None,) * 3]
N_PAGES, PAGE_SIZE, KV_GROUP = 8, 4, 16


def _expected_nbytes(cfg, kv_map, n_pages, page_size, kv_group):
    """Sum of exact per-layer page bytes, from the wire format arithmetic."""
    per_token = sum(
        kvwire.kv_token_nbytes(cfg.n_kv_heads, cfg.head_dim, b, kv_group,
                               fp_itemsize=cfg.activation_dtype.itemsize)
        for b in kv_map)
    return int(per_token * page_size * n_pages)


@pytest.mark.parametrize("kv_map", KV_MAPS)
def test_pool_nbytes_is_sum_of_per_layer_page_bytes(kv_map):
    got = pool_nbytes(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                      kv_bits=kv_map, kv_group=KV_GROUP)
    assert got == _expected_nbytes(TINY, kv_map, N_PAGES, PAGE_SIZE,
                                   KV_GROUP)


def _check_invariants(pool):
    tables = {rid: list(t) for rid, t in pool.page_tables.items()}
    owned = [p for t in tables.values() for p in t]
    # scratch page 0 stays reserved
    assert 0 not in owned and 0 not in pool._free
    # no page aliased across requests (page ids are global across layers)
    assert len(owned) == len(set(owned))
    # free list and tables partition the allocatable pages
    assert not set(owned) & set(pool._free)
    assert sorted(owned + list(pool._free)) == list(range(1, pool.n_pages))
    assert pool.n_allocated == len(owned)
    assert pool.n_free == pool.n_allocatable - len(owned)


def _run_ops(pool, ops):
    """Drive the allocator; returns {rid: pages} shadow bookkeeping."""
    shadow = {}
    for kind, rid, n in ops:
        if kind == 0:                       # alloc
            before = pool.pages_of(rid)
            ok = pool.alloc(rid, n)
            after = pool.pages_of(rid)
            if ok:
                assert after[:len(before)] == before    # append-only
                assert len(after) == len(before) + n
                shadow[rid] = after
            else:                           # all-or-nothing on exhaustion
                assert after == before
                assert n > pool.n_free
        elif kind == 1:                     # free
            freed = pool.free(rid)
            assert freed == len(shadow.pop(rid, []))
        else:                               # defrag
            mapping = pool.defrag()
            assert set(mapping) == {p for t in shadow.values() for p in t}
            shadow = {rid: [mapping[p] for p in t]
                      for rid, t in shadow.items()}
            # compact: allocated pages are exactly [1, n_allocated],
            # preserving each request's page order
            owned = sorted(p for t in shadow.values() for p in t)
            assert owned == list(range(1, pool.n_allocated + 1))
        for rid2, t in shadow.items():
            assert pool.pages_of(rid2) == t
        _check_invariants(pool)
    return shadow


def test_example_sequence_all_maps():
    """Deterministic walk of every kv map (always runs, no hypothesis)."""
    ops = [(0, 1, 2), (0, 2, 3), (1, 1, 0), (2, 0, 0), (0, 3, 4),
           (0, 4, 9), (1, 2, 0), (2, 0, 0), (0, 5, 1), (1, 3, 0),
           (2, 0, 0)]
    for kv_map in KV_MAPS:
        pool = PagedKVPool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                           kv_bits=kv_map, kv_group=KV_GROUP)
        _run_ops(pool, ops)
        assert pool.nbytes() == _expected_nbytes(
            TINY, kv_map, N_PAGES, PAGE_SIZE, KV_GROUP)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        kv_map=st.sampled_from(KV_MAPS),
        ops=st.lists(
            st.tuples(st.integers(0, 2),    # 0=alloc, 1=free, 2=defrag
                      st.integers(1, 5),    # rid
                      st.integers(1, 4)),   # pages requested
            min_size=1, max_size=24),
    )
    def test_random_alloc_free_defrag_never_aliases(kv_map, ops):
        pool = PagedKVPool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                           kv_bits=kv_map, kv_group=KV_GROUP)
        _run_ops(pool, ops)
        assert pool.nbytes() == _expected_nbytes(
            TINY, kv_map, N_PAGES, PAGE_SIZE, KV_GROUP)


def _defrag_data_check(kv_map, sizes, victim):
    """Write a sentinel token row into every allocated page of every layer
    (at that layer's own wire format), shuffle the pool with frees +
    defrag, and check each surviving request still reads its own rows —
    i.e. pages never alias across slots or layers under compaction."""
    pool = PagedKVPool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE,
                       kv_bits=kv_map, kv_group=KV_GROUP)
    rids = [1, 2, 3]
    for r, n in zip(rids, sizes):
        assert pool.alloc(r, n)

    # one token row per rid, scattered into page row 0 of its first page
    # at that layer's own wire format (every run has stack size 1 here)
    import jax.numpy as jnp
    toks = {r: jax.random.normal(jax.random.key(r),
                                 (1, 1, TINY.n_kv_heads, TINY.head_dim))
            for r in rids}
    for s, seg in enumerate(pool.pages["super_segments"]):
        bits = kv_map[s]
        kw = {} if bits is None else dict(bits=bits, group_size=KV_GROUP)
        leaf = jax.tree.map(lambda a: a[0], seg[0]["self"]["k"])
        for r in rids:
            page = jnp.asarray([pool.pages_of(r)[0]])
            row = jnp.asarray([0])
            leaf = kvwire.scatter_token(leaf, toks[r], page, row, **kw)
        seg[0]["self"]["k"] = jax.tree.map(lambda a: a[None], leaf)

    def slot_views():
        """{(seg, rid): full gathered wire view of rid's pages}."""
        out = {}
        for s, seg in enumerate(pool.pages["super_segments"]):
            leaf = jax.tree.map(lambda a: a[0], seg[0]["self"]["k"])
            for r in rids:
                if r == victim and victim_freed[0]:
                    continue
                tbl = jnp.asarray([pool.pages_of(r)], jnp.int32)
                out[(s, r)] = kvwire.gather_pages(leaf, tbl)
        return out

    victim_freed = [False]
    before = slot_views()
    victim_freed[0] = True
    pool.free(victim)
    pool.defrag()
    after = slot_views()
    # a defrag is a pure page permutation: every surviving request reads
    # back byte-identical wire data at every layer's own format
    for key, want in before.items():
        if key[1] == victim:
            continue
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), want, after[key])


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(kv_map=st.sampled_from([(8, None, 2), (2, 1, 8)]),
           sizes=st.tuples(st.integers(1, 2), st.integers(1, 2),
                           st.integers(1, 2)),
           victim=st.sampled_from([1, 2, 3]))
    def test_defrag_preserves_slot_data_across_geometries(kv_map, sizes,
                                                          victim):
        _defrag_data_check(kv_map, sizes, victim)
else:
    def test_defrag_preserves_slot_data_example():
        """Hypothesis-free fallback: fixed draws of the same property."""
        _defrag_data_check((8, None, 2), (2, 1, 2), 2)
        _defrag_data_check((2, 1, 8), (1, 2, 1), 1)
