"""Multi-tenant fleet: registry pricing/budget, plan-tagged admission,
weighted round-robin routing, telemetry, and solo-engine parity."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.fleet import (FleetAdmissionError, FleetBudgetError,
                         FleetManifest, FleetRegistry, FleetRouter,
                         FleetTelemetry, TenantSpec, build_fleet,
                         load_manifest)
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.plan import QuantPlan, plan_cost
from repro.serve import PagedEngine, Scheduler, pool_nbytes

TINY = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")

GOLD_PLAN = QuantPlan.from_assignment({"layer.0": "lq8w"}, default="lq4w")


def _spec(tid="t0", **kw):
    base = dict(kv_group=16, max_slots=2, page_size=4, n_pages=24,
                max_context=32)
    base.update(kw)
    return TenantSpec(tid, **base)


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.key(0))


def _prompts(seed=3, lens=(6, 9, 5)):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(0, 256, size=n))) for n in lens]


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

def test_spec_rejects_plan_and_scheme():
    with pytest.raises(ValueError):
        TenantSpec("t", plan=GOLD_PLAN, scheme="lq2w")


def test_spec_rejects_bad_weight_and_quota():
    with pytest.raises(ValueError):
        TenantSpec("t", weight=0)
    with pytest.raises(ValueError):
        TenantSpec("t", max_queued=0)
    with pytest.raises(ValueError):
        TenantSpec("")


def test_spec_resolved_plan_fits_regions():
    # registry schemes default to group_size=128; a d_model=64 model must
    # get a fitted region size, matching the planner's candidates_for
    cfgs = _spec(scheme="lq4w").resolved_plan(TINY).resolve(TINY)
    assert all(TINY.d_model % c.group_size == 0 for c in cfgs)
    assert all(c.w_bits == 4 for c in cfgs)


def test_spec_a_bits_folds_into_uniform_plan():
    cfgs = _spec(scheme="lq4w", a_bits=4).resolved_plan(TINY).resolve(TINY)
    assert all(c.a_bits == 4 for c in cfgs)
    with pytest.raises(ValueError):          # per-layer under a plan
        TenantSpec("t", plan=GOLD_PLAN, a_bits=4)


# ---------------------------------------------------------------------------
# registry: pricing + shared budget
# ---------------------------------------------------------------------------

def test_registry_pricing_matches_costmodel(params):
    reg = FleetRegistry(TINY, params, backend="ref")
    spec = _spec(plan=GOLD_PLAN, kv_bits=8)
    priced = reg.price(spec)
    want_w = plan_cost(TINY, spec.resolved_plan(TINY).resolve(TINY))["bytes"]
    want_p = pool_nbytes(TINY, n_pages=spec.n_pages,
                         page_size=spec.page_size, kv_bits=8, kv_group=16)
    assert priced["weight_bytes"] == want_w
    assert priced["pool_bytes"] == want_p
    assert priced["total"] == want_w + want_p


def test_registry_enforces_shared_budget(params):
    reg = FleetRegistry(TINY, params, budget_mb=0.01, backend="ref")
    with pytest.raises(FleetBudgetError):
        reg.register(_spec(scheme="lq2w"))
    assert len(reg) == 0                       # nothing half-registered

    # two tenants fit one budget only together under a roomier cap
    # (share_weights off: this test is about the budget gate itself —
    # identical-plan tenants WOULD dedup and admit, see
    # test_identical_plan_tenants_share_packed_leaves)
    one = FleetRegistry(TINY, params, backend="ref").price(
        _spec(scheme="lq2w", kv_bits=2))
    budget_mb = 1.5 * one["total"] / 2**20     # fits one, not two
    reg = FleetRegistry(TINY, params, budget_mb=budget_mb, backend="ref",
                        share_weights=False)
    reg.register(_spec("a", scheme="lq2w", kv_bits=2))
    with pytest.raises(FleetBudgetError):
        reg.register(_spec("b", scheme="lq2w", kv_bits=2))
    assert sorted(reg.tenants) == ["a"]


def test_registry_rejects_duplicate_ids(params):
    reg = FleetRegistry(TINY, params, backend="ref")
    reg.register(_spec("dup"))
    with pytest.raises(ValueError):
        reg.register(_spec("dup"))


def test_registry_tracks_aggregate_bytes(params):
    reg = FleetRegistry(TINY, params, budget_mb=64, backend="ref")
    t1 = reg.register(_spec("a", scheme="lq8w", kv_bits=8))
    t2 = reg.register(_spec("b", scheme="lq2w", kv_bits=2))
    assert reg.total_bytes() == t1.total_bytes + t2.total_bytes
    assert t2.weight_bytes < t1.weight_bytes   # 2-bit wire < 8-bit wire
    assert t2.pool_bytes < t1.pool_bytes       # 2-bit pool < 8-bit pool
    assert reg.remaining_bytes() == reg.budget_bytes - reg.total_bytes()


# ---------------------------------------------------------------------------
# mixed-KV tenants: heterogeneous pool pricing under the shared budget
# ---------------------------------------------------------------------------

MIXED_KV_PLAN = QuantPlan.from_assignment(
    {"layer.0": "lq4w"}, default="lq4w",
    kv_bits={"layer.0": 8}, kv_default=2, kv_group=16)


def test_spec_rejects_kv_bits_with_kv_plan():
    with pytest.raises(ValueError, match="per-layer under a plan"):
        TenantSpec("t", plan=MIXED_KV_PLAN, kv_bits=8)
    # a plan without a kv map still takes the spec's uniform kv_bits
    TenantSpec("t", plan=GOLD_PLAN, kv_bits=8)


def test_mixed_kv_pricing_matches_exact_pool_bytes(params):
    """Registry totals are eval_shape-exact for heterogeneous geometry."""
    reg = FleetRegistry(TINY, params, backend="ref")
    spec = _spec(plan=MIXED_KV_PLAN)
    kv_bits, kv_group = spec.pool_kv(TINY)
    assert kv_bits == (8, 2, 2) and kv_group == 16
    priced = reg.price(spec)
    want_p = pool_nbytes(TINY, n_pages=spec.n_pages,
                         page_size=spec.page_size, kv_bits=(8, 2, 2),
                         kv_group=16)
    assert priced["pool_bytes"] == want_p
    tenant = reg.register(spec)
    assert tenant.pool_bytes == want_p == tenant.pool.nbytes()
    # the engine's actual pool is genuinely heterogeneous
    assert "super_segments" in tenant.pool.pages


def test_mixed_kv_tenants_fit_where_uniform8_do_not(params):
    """The packing win: two mixed-KV tenants admit under a budget that
    rejects their uniform-8-bit-cache equivalents."""
    reg0 = FleetRegistry(TINY, params, backend="ref")
    uni8 = _spec("u", plan=GOLD_PLAN, kv_bits=8)
    mixed = _spec("m", plan=QuantPlan(
        assignments=GOLD_PLAN.assignments, default=GOLD_PLAN.default,
        kv_bits=(("layer.0", 8),), kv_default=2, kv_group=16))
    cost_uni, cost_mixed = reg0.price(uni8), reg0.price(mixed)
    assert cost_mixed["weight_bytes"] == cost_uni["weight_bytes"]
    assert cost_mixed["pool_bytes"] < cost_uni["pool_bytes"]

    # midpoint budget: two mixed-KV tenants fit, two uniform-8 do not
    budget_mb = (cost_mixed["total"] + cost_uni["total"]) / 2**20
    assert 2 * cost_mixed["total"] <= budget_mb * 2**20
    assert 2 * cost_uni["total"] > budget_mb * 2**20

    # share_weights off: this test isolates the POOL pricing win — with
    # dedup on, the identical weight plans would be priced once and both
    # pairs would fit
    reg = FleetRegistry(TINY, params, budget_mb=budget_mb, backend="ref",
                        share_weights=False)
    reg.register(dataclasses.replace(uni8, tenant_id="u1"))
    with pytest.raises(FleetBudgetError):           # second uniform-8: no
        reg.register(dataclasses.replace(uni8, tenant_id="u2"))

    reg = FleetRegistry(TINY, params, budget_mb=budget_mb, backend="ref",
                        share_weights=False)
    t1 = reg.register(dataclasses.replace(mixed, tenant_id="m1"))
    t2 = reg.register(dataclasses.replace(mixed, tenant_id="m2"))
    assert reg.total_bytes() == t1.total_bytes + t2.total_bytes
    assert t1.pool_bytes == t1.pool.nbytes()        # exact, not modeled
    # and the registered mixed tenants actually serve
    sched = t1.scheduler
    rid = sched.submit(_prompts()[0], max_new_tokens=3)
    outs = sched.drain(max_steps=200)
    assert len(outs[rid]) == 3


# ---------------------------------------------------------------------------
# cross-tenant weight sharing: identical packed leaves priced once
# ---------------------------------------------------------------------------

def test_identical_plan_tenants_share_packed_leaves(params):
    """The dedup regression bar: two identical-plan tenants admit under a
    budget that would reject private weight copies — the second tenant's
    packed leaves come from the registry cache and are priced once."""
    one = FleetRegistry(TINY, params, backend="ref").price(
        _spec(plan=GOLD_PLAN, kv_bits=8))
    # fits one full copy + one extra pool, NOT two full copies
    budget_mb = (one["total"] + one["pool_bytes"]
                 + 0.5 * one["weight_bytes"]) / 2**20

    private = FleetRegistry(TINY, params, budget_mb=budget_mb,
                            backend="ref", share_weights=False)
    private.register(_spec("a", plan=GOLD_PLAN, kv_bits=8))
    with pytest.raises(FleetBudgetError):
        private.register(_spec("b", plan=GOLD_PLAN, kv_bits=8))

    shared = FleetRegistry(TINY, params, budget_mb=budget_mb, backend="ref")
    ta = shared.register(_spec("a", plan=GOLD_PLAN, kv_bits=8))
    tb = shared.register(_spec("b", plan=GOLD_PLAN, kv_bits=8))
    assert ta.shared_bytes == 0
    assert tb.shared_bytes == one["weight_bytes"]   # every leaf re-used
    assert tb.weight_bytes == 0                     # incremental cost: pool
    assert shared.total_bytes() == \
        one["total"] + one["pool_bytes"]
    # the share is real, not just an accounting fiction: the engines hold
    # the SAME packed arrays (same buffers, not equal copies)
    a_leaves = jax.tree.leaves(ta.engine.params["decoder"])
    b_leaves = jax.tree.leaves(tb.engine.params["decoder"])
    assert all(x is y for x, y in zip(a_leaves, b_leaves))
    # and both still serve, token-for-token alike (same plan, same seed
    # stream is irrelevant under greedy)
    pa = _prompts()[0]
    ra = ta.scheduler.submit(pa, max_new_tokens=4)
    rb = tb.scheduler.submit(pa, max_new_tokens=4)
    outs_a = ta.scheduler.drain(max_steps=200)
    outs_b = tb.scheduler.drain(max_steps=200)
    assert outs_a[ra] == outs_b[rb]


def test_partial_plan_overlap_shares_aligned_segments(params):
    """Tenants whose plans agree on some layers share those segments only
    — the discount equals the overlapping layers' wire bytes."""
    from repro.plan import leaf_key_bytes
    from repro.models.transformer import plan_leaf_keys
    reg = FleetRegistry(TINY, params, backend="ref")
    a = _spec("a", plan=GOLD_PLAN, kv_bits=8)              # 8w / 4w / 4w
    b_plan = QuantPlan.from_assignment({"layer.0": "lq8w"}, default="lq2w")
    b = _spec("b", plan=b_plan, kv_bits=8)                 # 8w / 2w / 2w
    reg.register(a)
    tb = reg.register(b)
    keys_a = set(plan_leaf_keys(TINY, a.resolved_plan(TINY)))
    keys_b = set(plan_leaf_keys(TINY, b.resolved_plan(TINY)))
    overlap = keys_a & keys_b
    assert overlap                                         # layer.0 aligns
    assert tb.shared_bytes == sum(leaf_key_bytes(TINY, k) for k in overlap)
    assert 0 < tb.shared_bytes < reg.price(b)["weight_bytes"]


def test_price_without_sharing_is_pure(params):
    """``price()`` stays a pure full-cost quote; only registration (and
    ``with_sharing=True``) applies the dedup discount."""
    reg = FleetRegistry(TINY, params, backend="ref")
    spec = _spec(plan=GOLD_PLAN, kv_bits=8)
    before = reg.price(spec)
    reg.register(dataclasses.replace(spec, tenant_id="a"))
    assert reg.price(spec) == before
    discounted = reg.price(spec, with_sharing=True)
    assert discounted["weight_bytes"] == 0
    assert discounted["shared_bytes"] == before["weight_bytes"]


# ---------------------------------------------------------------------------
# manifest
# ---------------------------------------------------------------------------

def test_manifest_round_trip(tmp_path):
    plan_path = tmp_path / "gold.json"
    GOLD_PLAN.save(str(plan_path))
    manifest = {"arch": "llama3.2-1b", "budget_mb": 8, "tenants": [
        {"id": "gold", "plan": "gold.json", "kv_bits": 8, "kv_group": 16,
         "weight": 3},
        {"id": "bronze", "scheme": "lq2w", "kv_bits": 2, "kv_group": 16},
    ]}
    mpath = tmp_path / "fleet.json"
    mpath.write_text(json.dumps(manifest))
    m = load_manifest(str(mpath))
    assert m.arch == "llama3.2-1b" and m.budget_mb == 8
    gold, bronze = m.tenants
    assert gold.plan == GOLD_PLAN              # relative path resolved
    assert gold.weight == 3 and bronze.scheme == "lq2w"


def test_manifest_rejects_duplicates_and_empty():
    with pytest.raises(ValueError):
        FleetManifest(arch="x", tenants=(_spec("a"), _spec("a")))
    with pytest.raises(ValueError):
        FleetManifest(arch="x", tenants=())


def test_manifest_entry_needs_id():
    with pytest.raises(ValueError):
        TenantSpec.from_manifest({"scheme": "lq2w"})


# ---------------------------------------------------------------------------
# router: admission, quotas, weighted round-robin
# ---------------------------------------------------------------------------

def _router(params, **reg_kw):
    reg = FleetRegistry(TINY, params, backend="ref", **reg_kw)
    reg.register(_spec("gold", plan=GOLD_PLAN, kv_bits=8, weight=3))
    reg.register(_spec("bronze", scheme="lq2w", kv_bits=2, weight=1,
                       max_queued=2))
    return FleetRouter(reg)


def test_router_rejects_unknown_tenant(params):
    router = _router(params)
    with pytest.raises(FleetAdmissionError):
        router.submit("nobody", _prompts()[0])


def test_router_quota_rejection_counted(params):
    router = _router(params)
    p = _prompts()[0]
    router.submit("bronze", p, max_new_tokens=4)
    router.submit("bronze", p, max_new_tokens=4)
    with pytest.raises(FleetAdmissionError):   # max_queued=2
        router.submit("bronze", p, max_new_tokens=4)
    assert router.telemetry.per_tenant["bronze"].rejected == 1
    assert router.telemetry.per_tenant["bronze"].submitted == 2
    router.drain(max_steps=500)                # the admitted two complete
    assert router.telemetry.per_tenant["bronze"].completed == 2


def test_router_invalid_request_propagates(params):
    router = _router(params)
    with pytest.raises(ValueError):
        router.submit("gold", _prompts()[0], max_new_tokens=0)
    with pytest.raises(ValueError):
        router.submit("gold", [])


def test_weighted_round_robin_split(params):
    """With both tenants saturated, a 3:1 weight split yields a 3:1 step
    split (smooth WRR), measured over a window where both have work."""
    router = _router(params)
    for p in _prompts(lens=(5, 5)):
        router.submit("gold", p, max_new_tokens=24)
        router.submit("bronze", p, max_new_tokens=24)
    picks = []
    for _ in range(16):                        # both stay busy >= 16 steps
        tid, _ = router.step()
        picks.append(tid)
    assert picks.count("gold") == 12 and picks.count("bronze") == 4
    # smooth WRR interleaves rather than bursting: bronze never starves
    # longer than one full cycle of 4
    gaps = [i for i, t in enumerate(picks) if t == "bronze"]
    assert all(b - a <= 4 for a, b in zip(gaps, gaps[1:]))
    router.drain(max_steps=2000)


def test_step_returns_none_when_idle(params):
    router = _router(params)
    assert router.step() is None
    assert not router.has_work


# ---------------------------------------------------------------------------
# end-to-end: tagging, parity, telemetry
# ---------------------------------------------------------------------------

def test_completions_report_tenant(params):
    router = _router(params)
    done = []
    router.on_complete = done.append
    router.submit("gold", _prompts()[0], max_new_tokens=3)
    router.submit("bronze", _prompts()[1], max_new_tokens=3)
    router.drain(max_steps=500)
    assert sorted(c.tenant for c in done) == ["bronze", "gold"]
    assert all(len(c.tokens) == 3 for c in done)


def test_fleet_matches_solo_engines_token_for_token(params):
    """The acceptance bar: interleaved multi-tenant routing reproduces
    each tenant's solo PagedEngine greedy output exactly."""
    reg = FleetRegistry(TINY, params, backend="ref")
    reg.register(_spec("gold", plan=GOLD_PLAN, kv_bits=8, weight=3))
    reg.register(_spec("bronze", scheme="lq2w", kv_bits=2, weight=1))
    router = FleetRouter(reg)
    prompts = _prompts()
    rids = {}
    for i, p in enumerate(prompts):            # interleaved arrivals
        for tid in ("gold", "bronze"):
            rids.setdefault(tid, []).append(
                router.submit(tid, p, max_new_tokens=8))
        router.step()
    outs = router.drain(max_steps=2000)

    for tid in ("gold", "bronze"):
        spec = router.registry[tid].spec
        ecfg = dataclasses.replace(spec.engine_config(TINY), backend="ref")
        engine = PagedEngine(TINY, params, ecfg, spec.paged_config())
        sched = Scheduler(engine, engine.new_pool())
        solo_rids = [sched.submit(p, max_new_tokens=8) for p in prompts]
        solo = sched.drain(max_steps=2000)
        for fleet_rid, solo_rid in zip(rids[tid], solo_rids):
            assert outs[tid][fleet_rid] == solo[solo_rid]
    # the two tenants' plans genuinely differ: so do their outputs
    assert any(outs["gold"][a] != outs["bronze"][b]
               for a, b in zip(rids["gold"], rids["bronze"]))


def test_telemetry_counts_and_snapshot(params):
    clock = iter(float(i) for i in range(10_000))
    router = _router(params)
    router.reset_telemetry(FleetTelemetry(clock=lambda: next(clock)))
    router.submit("gold", _prompts()[0], max_new_tokens=5)
    router.drain(max_steps=500)
    snap = router.telemetry.snapshot()
    g = snap["tenants"]["gold"]
    assert g["submitted"] == 1 and g["completed"] == 1
    assert g["tokens"] == 5
    assert g["steps"] >= 4                     # first token at admission
    assert g["tok_per_s"] > 0                  # deterministic fake clock
    assert snap["aggregate"]["tokens"] == 5
    json.loads(router.telemetry.to_json())     # JSON-able


def test_telemetry_rejected_tokens_distinct_from_preemptions():
    """Speculative rollbacks ride Completion.rejected_tokens into their
    own counter — preemptions are not inflated by them."""
    t = FleetTelemetry()
    t.note_complete("a", 1, 7)
    t.note_complete("a", 0, 5)
    snap = t.snapshot()
    assert snap["tenants"]["a"]["preemptions"] == 1
    assert snap["tenants"]["a"]["rejected_tokens"] == 12
    assert snap["aggregate"]["rejected_tokens"] == 12
    assert snap["aggregate"]["preemptions"] == 1


def test_telemetry_aggregate_uses_union_window():
    clock = iter([0.0, 1.0, 2.0, 3.0])
    t = FleetTelemetry(clock=lambda: next(clock))
    for tid in ("a", "b", "a", "b"):
        t.note_step(tid, 0.5)
        t.note_token(tid)
    snap = t.snapshot()
    # host rate = 4 tokens over the union window [0, 3] — NOT the sum of
    # per-tenant rates (1.0 + 1.0), whose windows overlap
    assert snap["aggregate"]["tok_per_s"] == round(4 / 3, 3)
    assert snap["tenants"]["a"]["tok_per_s"] == 1.0


def test_idle_tenant_snapshot_schema(params):
    """A tenant that never saw traffic still gets a full zeroed stats
    row, so --stats-out consumers see one schema for every tenant."""
    router = _router(params)
    router.submit("gold", _prompts()[0], max_new_tokens=2)
    router.drain(max_steps=200)
    snap = router.telemetry.snapshot()
    assert set(snap["tenants"]["bronze"]) == set(snap["tenants"]["gold"])
    assert snap["tenants"]["bronze"]["tokens"] == 0
    assert snap["tenants"]["bronze"]["tok_per_s"] == 0.0
    assert router.stats()["tenants"]["bronze"]["queued"] == 0


def test_router_stats_include_budget(params):
    router = _router(params, budget_mb=64)
    s = router.stats()
    assert s["budget_mb"] == 64
    assert s["used_mb"] > 0
    assert set(s["tenants"]) == {"gold", "bronze"}
    assert "bytes" in s["tenants"]["gold"]


def test_build_fleet_from_manifest(tmp_path, params):
    plan_path = tmp_path / "gold.json"
    GOLD_PLAN.save(str(plan_path))
    mpath = tmp_path / "fleet.json"
    mpath.write_text(json.dumps({
        "arch": "tiny", "budget_mb": 64, "tenants": [
            {"id": "gold", "plan": "gold.json", "kv_bits": 8,
             "kv_group": 16, "max_slots": 2, "page_size": 4, "n_pages": 24,
             "max_context": 32},
            {"id": "bronze", "scheme": "lq2w", "kv_bits": 2, "kv_group": 16,
             "max_slots": 2, "page_size": 4, "n_pages": 24,
             "max_context": 32}]}))
    router = build_fleet(str(mpath), TINY, params, backend="ref")
    assert router.registry.budget_mb == 64
    router = build_fleet(str(mpath), TINY, params, budget_mb=32,
                         backend="ref")      # CLI override wins
    assert router.registry.budget_mb == 32
    rid = router.submit("gold", _prompts()[0], max_new_tokens=2)
    outs = router.drain(max_steps=200)
    assert len(outs["gold"][rid]) == 2

    with pytest.raises(FleetBudgetError):    # over-budget manifest rejected
        build_fleet(str(mpath), TINY, params, budget_mb=0.01, backend="ref")
