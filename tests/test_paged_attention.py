"""Fused paged-attention kernel (kernels/paged_attention.py): interpret-mode
parity with the XLA gather+dequant+attention path, kernel-level and through
every engine.

The contract is TOKEN identity, not bit identity — the online softmax
re-associates the reduction — so the kernel-level checks use float
tolerance and the serving checks require exact greedy token streams.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kvwire
from repro.kernels import paged_attention as paged_attn
from repro.models import attention, transformer
from repro.models.config import ModelConfig
from repro.plan import QuantPlan
from repro.plan.plan import candidates_for
from repro.serve import Engine, EngineConfig, PagedConfig, RequestParams, \
    Server
from repro.spec import SpeculativeEngine

pytestmark = pytest.mark.skipif(
    not paged_attn.available(),
    reason="Pallas unavailable: fused kernel gated off on this host")

TINY = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")

KEY = jax.random.key(0)


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.key(0))


# ---------------------------------------------------------------------------
# kernel level: parity vs gather -> dequant -> decode_attention
# ---------------------------------------------------------------------------

def _case(bits, *, b=2, lq=1, kvh=2, gq=2, d=32, gs=16, page_size=4,
          pps=4, ragged=True):
    """One synthetic paged-pool decode case + its XLA baseline inputs.

    Page 0 (the scratch page) is filled with large garbage so any leak
    past the position mask shows up as a parity failure, and table rows
    past each slot's live pages point at scratch (the padded-table state
    the pool hands the engine).
    """
    n_pages = b * pps + 1
    kf = jax.random.normal(KEY, (n_pages, page_size, kvh, d), jnp.float32)
    vf = jax.random.normal(jax.random.fold_in(KEY, 1), kf.shape,
                           jnp.float32)
    kf = kf.at[0].set(1e4)                     # scratch garbage
    vf = vf.at[0].set(-1e4)
    q = jax.random.normal(jax.random.fold_in(KEY, 2),
                          (b, lq, kvh, gq, d), jnp.float32)
    table = (1 + jnp.arange(b * pps, dtype=jnp.int32)).reshape(b, pps)
    # slot 0 sits mid-page (padded entries after its live prefix resolve
    # to real-but-masked rows); slot 1 at a page boundary
    full = pps * page_size
    pos = jnp.asarray([full - page_size - 2, full - lq] if ragged
                      else [full - lq] * b, jnp.int32)[:b]
    if bits is None:
        return q, kf, vf, table, pos
    k_pg = kvwire.quantize_kv(kf, bits, gs)
    v_pg = kvwire.quantize_kv(vf, bits, gs)
    return q, k_pg, v_pg, table, pos


def _baseline(q, k_pg, v_pg, table, pos, d):
    kk = kvwire.gather_pages(k_pg, table)
    vv = kvwire.gather_pages(v_pg, table)
    if isinstance(kk, dict):
        kk = kvwire.dequantize_kv(kk, d)
        vv = kvwire.dequantize_kv(vv, d)
    return attention.decode_attention(q, kk, vv, pos)


@pytest.mark.parametrize("lq", [1, 3])
@pytest.mark.parametrize("bits", [None, 8, 4, 2])
def test_kernel_matches_xla_baseline(bits, lq):
    q, k_pg, v_pg, table, pos = _case(bits, lq=lq)
    want = _baseline(q, k_pg, v_pg, table, pos, q.shape[-1])
    got = paged_attn.paged_attention(q, k_pg, v_pg, table, pos,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bits", [4, 2])
def test_lut_and_affine_dequant_agree(bits):
    """The LUT masked-matmul dataflow is an exact reformulation of the
    affine dequant (section V): same pages, same scores, same output."""
    q, k_pg, v_pg, table, pos = _case(bits)
    affine = paged_attn.paged_attention(q, k_pg, v_pg, table, pos,
                                        dequant="affine", interpret=True)
    lut = paged_attn.paged_attention(q, k_pg, v_pg, table, pos,
                                     dequant="lut", interpret=True)
    np.testing.assert_allclose(np.asarray(lut), np.asarray(affine),
                               rtol=2e-5, atol=2e-5)


def test_auto_mode_selects_lut_at_low_bits():
    assert paged_attn.dequant_path(4) == "lut"
    assert paged_attn.dequant_path(2) == "lut"
    assert paged_attn.dequant_path(8) == "affine"
    assert paged_attn.dequant_path(None) == "fp"
    assert paged_attn.dequant_path(8, "affine") == "affine"


def test_rejects_bad_dequant_modes():
    q, k_pg, v_pg, table, pos = _case(8)
    with pytest.raises(ValueError, match="dequant"):
        paged_attn.paged_attention(q, k_pg, v_pg, table, pos,
                                   dequant="nearest", interpret=True)
    with pytest.raises(ValueError, match="bits <= 4"):
        paged_attn.paged_attention(q, k_pg, v_pg, table, pos,
                                   dequant="lut", interpret=True)


def test_resolve_mode_gates_on_flag_and_host():
    assert paged_attn.resolve_mode(False) is None
    assert paged_attn.resolve_mode(True) in ("pallas", "interpret")


# ---------------------------------------------------------------------------
# engine level: token-exact serving across formats, one compiled step
# ---------------------------------------------------------------------------

def _prompts(seed=1, lens=(7, 12, 5)):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(0, 256, size=n))) for n in lens]


def _serve(params, ecfg, pcfg, prompts, max_new, stagger=True):
    srv = Server(TINY, params, ecfg, pcfg)
    rids = []
    for i, (p, n) in enumerate(zip(prompts, max_new)):
        rids.append(srv.submit(p, RequestParams(max_new_tokens=n)))
        if stagger and i == 0:
            srv.step(); srv.step()
    outs = srv.drain(max_steps=500)
    return [outs[r] for r in rids], srv


@pytest.mark.parametrize("kv_bits", [None, 8, 4, 2])
def test_fused_serving_token_identical(params, kv_bits):
    """The acceptance bar: --fused-attention changes the dataflow, never
    a token — staggered continuous batching, every wire format."""
    kw = dict(kv_bits=kv_bits, kv_group=16) if kv_bits else {}
    pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=24,
                       max_context=32)
    prompts, max_new = _prompts(), [8, 6, 7]
    ref, rsrv = _serve(params, EngineConfig(max_len=32, **kw), pcfg,
                       prompts, max_new)
    out, srv = _serve(params,
                      EngineConfig(max_len=32, fused_attention=True, **kw),
                      pcfg, prompts, max_new)
    assert srv.engine.fused_mode is not None
    assert rsrv.engine.fused_mode is None
    assert out == ref
    assert srv.engine.decode_compilations == 1


def test_fused_survives_preemption_mid_stream(params):
    """Preempt -> free -> realloc -> recompute resume under the fused
    kernel: the truncate/restore cycle mid-stream stays token-exact."""
    prompts = _prompts()[:2]
    pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=10,
                       max_context=32)
    ecfg = EngineConfig(max_len=32, kv_bits=4, kv_group=16,
                        fused_attention=True)
    base = dataclasses.replace(ecfg, fused_attention=False)
    ref, rsrv = _serve(params, base, pcfg, prompts, [16, 16],
                       stagger=False)
    out, srv = _serve(params, ecfg, pcfg, prompts, [16, 16],
                      stagger=False)
    pre = sum(srv.scheduler.request(r).n_preemptions
              for r in srv.scheduler._requests)
    assert pre >= 1                            # pool pressure really hit
    assert out == ref
    assert srv.engine.decode_compilations == 1


def test_fused_hetero_kv_plan_matches_baseline(params):
    """Per-layer kv bits (super_segments layout): each stack run launches
    the fused kernel on its own wire format; tokens still exact."""
    plan = QuantPlan.uniform("fp32").with_kv(
        {"layer.0": 8, "layer.2": 2}, default=None, kv_group=16)
    pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=40,
                       max_context=32)
    prompts, max_new = _prompts(), [10, 6, 8]
    base = EngineConfig(max_len=32, plan=plan, backend="ref")
    ref, rsrv = _serve(params, base, pcfg, prompts, max_new)
    assert "super_segments" in rsrv.pool.pages     # genuinely mixed
    out, srv = _serve(params,
                      dataclasses.replace(base, fused_attention=True),
                      pcfg, prompts, max_new)
    assert out == ref
    assert srv.engine.decode_compilations == 1


def test_fused_speculative_verify_multi_query(params):
    """The spec verify step sends Lq = k+1 query rows through the same
    kernel; acceptance and tokens must match the unfused engine."""
    cands = candidates_for(TINY, ["lq8w"])
    ecfg = EngineConfig(max_len=32, kv_bits=8, kv_group=16, backend="ref")
    pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=40,
                       max_context=32)

    def run(fused):
        eng = SpeculativeEngine(
            TINY, params, dataclasses.replace(ecfg, fused_attention=fused),
            pcfg, draft_plan=QuantPlan(default=cands["lq8w"]), spec_k=2)
        srv = Server(TINY, params, ecfg, pcfg, engine=eng)
        rids = [srv.submit(p, RequestParams(max_new_tokens=n))
                for p, n in zip(_prompts(), [8, 6, 7])]
        outs = srv.drain(max_steps=500)
        return [outs[r] for r in rids], eng

    ref, reng = run(False)
    out, eng = run(True)
    assert eng.verifier.fused_mode is not None
    assert out == ref
    assert eng.decode_compilations == 1


def test_fused_fleet_routing_matches_baseline(params):
    """fused_attention is host-level: the registry applies it to every
    tenant engine, and routed streams match the unfused fleet."""
    from repro.fleet import FleetManifest, TenantSpec, build_fleet

    manifest = FleetManifest(arch="tiny", tenants=(
        TenantSpec("gold", scheme="lq8w", kv_bits=8, kv_group=16,
                   max_slots=2, page_size=4, n_pages=24, max_context=32),
        TenantSpec("bronze", scheme="lq4w", kv_bits=4, kv_group=16,
                   max_slots=2, page_size=4, n_pages=24, max_context=32),
    ))

    def run(fused):
        router = build_fleet(manifest, TINY, params, backend="ref",
                             fused_attention=fused)
        for tid in ("gold", "bronze"):
            for p in _prompts()[:2]:
                router.submit(tid, p, max_new_tokens=6)
        return router.drain(max_steps=500), router

    ref, _ = run(False)
    out, router = run(True)
    assert out == ref
    for tenant in router.registry:
        assert tenant.engine.fused_mode is not None
        assert tenant.engine.decode_compilations == 1


# ---------------------------------------------------------------------------
# XLA fallback: decode_attention keeps the cache storage dtype
# ---------------------------------------------------------------------------

def test_decode_attention_accumulates_f32_without_upcast_copy():
    """Regression: the fallback used to ``.astype(f32)`` both caches,
    materializing full upcast copies.  ``preferred_element_type`` gives
    the same f32 accumulation with the caches staying in storage dtype —
    same outputs, and compiled temp memory well under one upcast copy."""
    b, s, kvh, g, d = 2, 2048, 2, 2, 64
    q = jax.random.normal(KEY, (b, 1, kvh, g, d), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kvh, d),
                           jnp.bfloat16)
    vc = jax.random.normal(jax.random.fold_in(KEY, 2), kc.shape,
                           jnp.bfloat16)
    pos = jnp.asarray([s - 1, s // 2], jnp.int32)
    got = attention.decode_attention(q, kc, vc, pos)
    assert got.dtype == q.dtype
    want = attention.decode_attention(q, kc.astype(jnp.float32),
                                      vc.astype(jnp.float32), pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    compiled = jax.jit(attention.decode_attention).lower(
        q, kc, vc, pos).compile()
    try:
        temp = compiled.memory_analysis().temp_size_in_bytes
    except (AttributeError, NotImplementedError):
        pytest.skip("backend exposes no compiled memory analysis")
    one_upcast_copy = b * s * kvh * d * 4
    # the old explicit .astype floor is BOTH caches resident as f32 temps
    # (2 copies); CPU XLA may still stage ~one operand internally for the
    # bf16 dot, so the bound sits strictly between the two behaviors
    assert temp < 1.5 * one_upcast_copy, \
        f"temps {temp}B ~ both caches upcast ({2 * one_upcast_copy}B floor)"


def test_fused_solo_engine_unaffected(params):
    """The solo (non-paged) engine has no page table; the flag must not
    perturb plain generate."""
    prompt = _prompts()[0]
    outs = []
    for fused in (False, True):
        eng = Engine(TINY, params,
                     EngineConfig(max_len=32, kv_bits=8, kv_group=16,
                                  fused_attention=fused))
        out, _ = eng.generate({"tokens": jnp.asarray([prompt], jnp.int32)},
                              steps=7)
        outs.append(np.asarray(out)[0].tolist())
    assert outs[0] == outs[1]
