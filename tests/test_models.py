"""Per-arch smoke tests: reduced config, one forward + one train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import shapes as shp
from repro.models import transformer
from repro.train import TrainHParams, make_train_step


@pytest.mark.parametrize("arch", configs.names())
def test_smoke_forward_and_train_step(arch):
    cfg = configs.smoke(arch)
    batch = shp.demo_batch(cfg, batch=2, seq_len=16)

    params = transformer.init_params(cfg, jax.random.key(0))
    logits, aux = transformer.forward(params, cfg, batch)
    lt = batch["tokens"].shape[1] + \
        (cfg.n_patches if cfg.frontend == "patch_stub" else 0)
    assert logits.shape == (2, lt, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), "NaN in logits"
    assert not bool(jnp.isnan(aux).any())

    init_state, train_step = make_train_step(cfg, TrainHParams(lr=1e-3))
    state = init_state(jax.random.key(1))
    state, metrics = jax.jit(train_step)(state, batch)
    assert np.isfinite(float(metrics["loss"])), "non-finite loss"
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", configs.names())
def test_smoke_decode_matches_prefill_continuation(arch):
    """prefill(t[:n]) + decode(t[n]) logits == forward(t[:n+1]) tail."""
    cfg = configs.smoke(arch)
    batch = shp.demo_batch(cfg, batch=2, seq_len=12)
    params = transformer.init_params(cfg, jax.random.key(0))

    full_logits, _ = transformer.forward(params, cfg, batch, training=False)

    pre = dict(batch)
    toks = batch["tokens"]
    pre["tokens"] = toks[:, :-1]
    pre.pop("labels", None)
    cache = transformer.init_cache(cfg, 2, 24)
    logits_pre, cache = transformer.prefill(params, cfg, pre, cache)
    logits_dec, cache = transformer.decode_step(
        params, cfg, toks[:, -1:], cache)

    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0]), np.asarray(full_logits[:, -2]),
        rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(full_logits[:, -1]),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", configs.names())
def test_full_config_exact_spec(arch):
    """The full configs carry the exact published hyperparameters."""
    cfg = configs.get(arch)
    spec = {
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }[arch]
    n_layers, d_model, n_heads, n_kv, d_ff, vocab = spec
    assert cfg.n_layers == n_layers
    assert cfg.d_model == d_model
    assert cfg.n_heads == n_heads
    assert cfg.n_kv_heads == n_kv
    assert cfg.vocab_size == vocab
    if cfg.family == "moe":
        assert cfg.moe_d_ff == d_ff
    elif arch != "mamba2-130m":
        assert cfg.d_ff == d_ff


def test_param_counts_match_published():
    assert abs(configs.get("qwen3-moe-235b-a22b").param_count()
               - 235e9) / 235e9 < 0.02
    assert abs(configs.get("qwen3-moe-235b-a22b").active_param_count()
               - 22e9) / 22e9 < 0.02
    assert abs(configs.get("llama3.2-1b").param_count()
               - 1.24e9) / 1.24e9 < 0.02
    assert abs(configs.get("qwen3-8b").param_count() - 8.2e9) / 8.2e9 < 0.02
    assert abs(configs.get("mamba2-130m").param_count()
               - 0.13e9) / 0.13e9 < 0.05
    scout = configs.get("llama4-scout-17b-a16e")
    assert abs(scout.active_param_count() - 17e9) / 17e9 < 0.05


def test_moe_aux_loss_balanced_router():
    """A uniform router gives aux ~= 1 (Switch normalization)."""
    cfg = configs.smoke("qwen3-moe-235b-a22b")
    params = transformer.init_params(cfg, jax.random.key(0))
    batch = shp.demo_batch(cfg, batch=2, seq_len=32)
    _, aux = transformer.forward(params, cfg, batch)
    assert 0.5 < float(aux) < 3.0


def test_scan_tail_layers():
    """recurrentgemma smoke (5 layers, pattern 3) exercises the tail."""
    cfg = configs.smoke("recurrentgemma-2b")
    assert cfg.n_super == 1 and cfg.n_tail == 2
    params = transformer.init_params(cfg, jax.random.key(0))
    assert len(params["decoder"]["tail"]) == 2
