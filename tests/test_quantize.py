"""core/quantize.py: LQ/DQ invariants (paper section IV) via hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:        # property tests are extra coverage; the container may lack it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.quantize import (quantize as quantize_fn, dequantize,
                                 fake_quant, quant_error)


def _rand(shape, seed=0, scale=3.0):
    return scale * jax.random.normal(jax.random.key(seed), shape)


@pytest.mark.parametrize("bits", [8, 6, 4, 2, 1])
@pytest.mark.parametrize("granularity", ["per_tensor", "per_group"])
def test_error_bounded_by_step(bits, granularity):
    """|x - Q^-1(Q(x))| <= s/2 per region (paper eq. 4/5)."""
    x = _rand((4, 256), seed=bits)
    qt = quantize_fn(x, bits, group_size=64, granularity=granularity)
    err = np.abs(np.asarray(x - dequantize(qt)))
    scale = np.asarray(qt.scale)
    if granularity == "per_tensor":
        assert err.max() <= scale * 0.5 + 1e-6
    else:
        err_g = err.reshape(4, 4, 64)
        assert (err_g.max(-1) <= scale * 0.5 + 1e-6).all()


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_lq_error_never_worse_than_dq(bits):
    """Smaller regions => smaller steps => lower error (paper section IV.C).

    Guaranteed per-region: the local step s_lk <= global step s, so the
    max error within every region can only shrink.
    """
    x = _rand((8, 512), seed=bits + 10)
    e_dq = np.abs(np.asarray(quant_error(x, bits, granularity="per_tensor")))
    e_lq = np.abs(np.asarray(quant_error(x, bits, group_size=64,
                                         granularity="per_group")))
    assert e_lq.mean() <= e_dq.mean() + 1e-7
    assert e_lq.max() <= e_dq.max() + 1e-7


def test_region_monotonicity():
    """Paper Fig. 10: accuracy improves as regions shrink -> here, MSE
    decreases monotonically with group size at 2-bit."""
    x = _rand((16, 1024), seed=3)
    mses = []
    for gs in (1024, 256, 64, 16):
        e = quant_error(x, 2, group_size=gs, granularity="per_group")
        mses.append(float(jnp.mean(e * e)))
    assert mses == sorted(mses, reverse=True)


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_idempotent(bits):
    """Q(dequant(Q(x))) == Q(x): quantization is a projection."""
    x = _rand((4, 128), seed=bits)
    qt = quantize_fn(x, bits, group_size=32)
    x1 = dequantize(qt)
    qt2 = quantize_fn(x1, bits, group_size=32)
    np.testing.assert_allclose(np.asarray(dequantize(qt2)), np.asarray(x1),
                               rtol=1e-5, atol=1e-6)


def test_constant_region_exact():
    """A constant region has rng=0 -> scale=1, codes=0, exact rebuild."""
    x = jnp.full((2, 64), 3.25)
    qt = quantize_fn(x, 2, group_size=32)
    np.testing.assert_allclose(np.asarray(dequantize(qt)), 3.25, rtol=1e-6)


def test_8bit_high_fidelity():
    """Paper Table 1: 8-bit keeps accuracy — relative error ~ 1/255."""
    x = _rand((32, 256), seed=7)
    e = quant_error(x, 8, group_size=64)
    rel = float(jnp.abs(e).max()) / float(jnp.abs(x).max())
    assert rel < 1.0 / 255


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(bits=st.sampled_from([1, 2, 4, 8]),
           gs=st.sampled_from([16, 32, 64]),
           seed=st.integers(0, 2 ** 16))
    def test_fake_quant_matches_roundtrip(bits, gs, seed):
        x = _rand((2, 128), seed=seed)
        qt = quantize_fn(x, bits, group_size=gs)
        fq = fake_quant(x, bits, group_size=gs)
        np.testing.assert_allclose(np.asarray(dequantize(qt)),
                                   np.asarray(fq), rtol=1e-5, atol=1e-5)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fake_quant_matches_roundtrip():
        pass


def test_axis_handling():
    x = _rand((6, 4, 64), seed=9)
    qt = quantize_fn(x, 4, group_size=2, axis=1)
    assert dequantize(qt).shape == x.shape
    e = np.abs(np.asarray(x - dequantize(qt)))
    assert e.max() < np.abs(np.asarray(x)).max() / 4
