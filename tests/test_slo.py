"""Tests for the SLO plane (repro.obs.slo / repro.obs.health): spec
round-trip, burn-rate/error-budget math under an injected clock, the
breach state machine + flight-recorder integration, the CLI gates, the
health/degradation layer, the fused-fallback satellite, and fleet-serve
bit-identity with the whole judgment plane armed."""
import json

import jax
import numpy as np
import pytest

from repro.kernels import paged_attention as paged_attn
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.obs import FlightRecorder, Observability
from repro.obs.health import HealthMonitor
from repro.obs.slo import (SLOSpec, SLOTracker, TenantSLO, good_count,
                           good_fraction, validate_report)
from repro.obs.slo import main as slo_main
from repro.serve import EngineConfig, PagedConfig, RequestParams, Server

TINY = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")


class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.key(0))


def _spec(**kw):
    base = dict(tenants=(("bronze", TenantSLO(itl_p95_ms=50.0)),
                         ("gold", TenantSLO(itl_p95_ms=50.0))),
                fast_steps=4, slow_steps=8, budget_steps=8,
                warn_burn=2.0, breach_burn=4.0, cooldown_s=0.0)
    base.update(kw)
    return SLOSpec(**base)


def _obs_tracker(spec=None, telemetry=None):
    clk = FakeClock()
    obs = Observability(clock=clk)
    tracker = SLOTracker(spec or _spec(), obs, telemetry=telemetry)
    return clk, obs, tracker


# ---------------------------------------------------------------------------
# good-fraction histogram bridge
# ---------------------------------------------------------------------------

class TestGoodFraction:
    def test_empty_histogram_is_compliant(self):
        h = Observability().metrics.histogram("serve_itl_ms")
        assert good_fraction(h, 50.0) == 1.0

    def test_counts_at_or_under_target(self):
        h = Observability().metrics.histogram("serve_itl_ms")
        for v in (5.0, 5.0, 5.0, 500.0):
            h.record(v)
        # 50.0 is a default bucket bound: the three 5 ms samples sit at
        # or under it, the 500 ms one lands past it
        assert good_count(h, 50.0) == 3
        assert good_fraction(h, 50.0) == pytest.approx(0.75)

    def test_partial_bucket_counts_as_bad(self):
        h = Observability().metrics.histogram("serve_itl_ms")
        h.record(5.0)
        # target inside the (5, 10] bucket: its samples can't be split,
        # so the convention is conservative — the bucket counts as bad
        assert good_count(h, 7.0) == 1       # 5.0 is under the 5.0 bound
        h.record(9.0)
        assert good_count(h, 9.5) == 1       # the (5, 10] bucket is bad


# ---------------------------------------------------------------------------
# spec round-trip + validation
# ---------------------------------------------------------------------------

class TestSpec:
    def test_json_round_trip(self):
        spec = _spec(target=0.9, cooldown_s=2.5,
                     default=TenantSLO(ttft_p95_ms=100.0, tok_per_s=5.0))
        assert SLOSpec.from_json(spec.to_json()) == spec

    def test_save_load(self, tmp_path):
        path = str(tmp_path / "slo.json")
        spec = _spec()
        spec.save(path)
        assert SLOSpec.load(path) == spec

    def test_unknown_objective_rejected(self):
        with pytest.raises(ValueError, match="unknown SLO objectives"):
            TenantSLO.from_obj({"p99_ms": 1.0})

    def test_unknown_spec_key_rejected(self):
        with pytest.raises(ValueError, match="unknown SLO spec keys"):
            SLOSpec.from_obj({"tenants": {}, "burn": 2.0})

    def test_unknown_window_key_rejected(self):
        with pytest.raises(ValueError, match="unknown SLO spec keys"):
            SLOSpec.from_obj({"windows": {"fast": 5}})

    @pytest.mark.parametrize("kw", [
        dict(fast_steps=10, slow_steps=5),
        dict(warn_burn=7.0, breach_burn=4.0),
        dict(target=1.5),
        dict(cooldown_s=-1.0),
        dict(fast_steps=0),
    ])
    def test_bad_config_rejected(self, kw):
        with pytest.raises(ValueError):
            _spec(**kw)

    def test_duplicate_tenants_rejected(self):
        with pytest.raises(ValueError, match="duplicate tenant"):
            SLOSpec(tenants=(("a", TenantSLO(tok_per_s=1.0)),
                             ("a", TenantSLO(tok_per_s=2.0))))

    @pytest.mark.parametrize("kw", [
        dict(itl_p95_ms=-1.0), dict(ttft_p95_ms=float("inf")),
        dict(availability=1.5), dict(acceptance_rate=0.0),
    ])
    def test_bad_targets_rejected(self, kw):
        with pytest.raises(ValueError):
            TenantSLO(**kw)

    def test_extra_tenants_merge_and_override(self):
        inline = TenantSLO(itl_p95_ms=10.0)
        spec = SLOSpec.from_obj(
            {"tenants": {"a": {"itl_p95_ms": 99.0}}},
            extra_tenants=[("a", inline), ("b", TenantSLO(tok_per_s=1.0))])
        assert spec.tenant_slo("a") == inline          # inline wins
        assert spec.tenant_slo("b").tok_per_s == 1.0


# ---------------------------------------------------------------------------
# tracker math + breach state machine (fake clock throughout)
# ---------------------------------------------------------------------------

def _drive(clk, obs, tracker, *, bad_after=8, steps=24, bad_ms=500.0):
    """gold stays healthy; bronze regresses after ``bad_after`` steps."""
    gold = obs.metrics.histogram("serve_itl_ms", tenant="gold")
    bronze = obs.metrics.histogram("serve_itl_ms", tenant="bronze")
    budgets = []
    for step in range(steps):
        clk.advance(1.0)
        gold.record(5.0)
        bronze.record(5.0 if step < bad_after else bad_ms)
        tracker.on_step()
        budgets.append(tracker._series[("bronze", "itl_p95_ms")]
                       .budget_remaining())
    return budgets


class TestTracker:
    def test_healthy_run_stays_ok(self):
        clk, obs, tracker = _obs_tracker()
        _drive(clk, obs, tracker, bad_after=99)
        for tid in ("gold", "bronze"):
            assert tracker.worst_state(tid) == "ok"
            s = tracker._series[(tid, "itl_p95_ms")]
            assert s.burn(s.fast) == 0.0 and s.budget_remaining() == 1.0
        assert not any(e["name"] == "slo_breach"
                       for e in obs.tracer.events)

    def test_breach_fires_once_and_budget_burns_monotonically(self):
        clk, obs, tracker = _obs_tracker()
        budgets = _drive(clk, obs, tracker)
        s = tracker._series[("bronze", "itl_p95_ms")]
        assert s.state == "breach"
        assert len(s.episodes) == 1            # exactly one episode
        assert tracker.worst_state("gold") == "ok"   # healthy tenant ok
        fires = [e for e in obs.tracer.events if e["name"] == "slo_breach"]
        assert len(fires) == 1
        assert fires[0]["args"]["tenant"] == "bronze"
        assert obs.metrics.find("slo_breach_total", tenant="bronze",
                                objective="itl_p95_ms").value == 1
        # budget only ever decreases once the regression starts
        after = budgets[8:]
        assert all(b1 <= b0 + 1e-12 for b0, b1 in zip(after, after[1:]))
        assert after[-1] < 1.0

    def test_warning_precedes_breach(self):
        clk, obs, tracker = _obs_tracker()
        states = []
        gold = obs.metrics.histogram("serve_itl_ms", tenant="gold")
        bronze = obs.metrics.histogram("serve_itl_ms", tenant="bronze")
        for step in range(16):
            clk.advance(1.0)
            gold.record(5.0)
            bronze.record(5.0 if step < 8 else 500.0)
            tracker.on_step()
            states.append(tracker._series[("bronze", "itl_p95_ms")].state)
        assert "warning" in states
        assert states.index("warning") < states.index("breach")

    def test_recovery_returns_to_ok_and_closes_episode(self):
        clk, obs, tracker = _obs_tracker()
        gold = obs.metrics.histogram("serve_itl_ms", tenant="gold")
        bronze = obs.metrics.histogram("serve_itl_ms", tenant="bronze")
        for step in range(40):
            clk.advance(1.0)
            gold.record(5.0)
            # regress for 8 steps, then recover
            bronze.record(500.0 if 8 <= step < 16 else 5.0)
            tracker.on_step()
        s = tracker._series[("bronze", "itl_p95_ms")]
        assert s.state == "ok"
        (ep,) = s.episodes
        assert ep["end_step"] >= ep["start_step"]
        assert "end_clock" in ep

    def test_cooldown_suppresses_repeat_events(self):
        clk, obs, tracker = _obs_tracker(_spec(cooldown_s=1000.0))
        gold = obs.metrics.histogram("serve_itl_ms", tenant="gold")
        bronze = obs.metrics.histogram("serve_itl_ms", tenant="bronze")
        for step in range(48):
            clk.advance(1.0)
            gold.record(5.0)
            # two distinct breach episodes inside one cooldown window
            bad = 8 <= step < 16 or 32 <= step < 40
            bronze.record(500.0 if bad else 5.0)
            tracker.on_step()
        s = tracker._series[("bronze", "itl_p95_ms")]
        assert len(s.episodes) == 2
        fires = [e for e in obs.tracer.events if e["name"] == "slo_breach"]
        assert len(fires) == 1                 # second one suppressed
        assert tracker.suppressed_events == 1
        assert s.episodes[1].get("event_suppressed") is True

    def test_gauges_exported(self):
        clk, obs, tracker = _obs_tracker()
        _drive(clk, obs, tracker)
        m = obs.metrics
        for tid in ("gold", "bronze"):
            assert m.find("slo_budget_remaining", tenant=tid,
                          objective="itl_p95_ms") is not None
            for window in ("fast", "slow"):
                assert m.find("slo_burn_rate", tenant=tid,
                              objective="itl_p95_ms",
                              window=window) is not None
        assert m.find("slo_state", tenant="bronze",
                      objective="itl_p95_ms").value == 2
        assert m.find("slo_state", tenant="gold",
                      objective="itl_p95_ms").value == 0

    def test_noop_obs_is_a_noop(self):
        from repro.obs import NOOP
        tracker = SLOTracker(_spec(), NOOP, clock=FakeClock())
        tracker.on_step()
        assert tracker.steps == 0 and not tracker._series

    def test_availability_from_fleet_telemetry(self):
        from repro.fleet import FleetTelemetry
        clk = FakeClock()
        tel = FleetTelemetry(clk)
        spec = SLOSpec(tenants=(("a", TenantSLO(availability=0.9)),),
                       fast_steps=4, slow_steps=8, budget_steps=8,
                       warn_burn=1.0, breach_burn=2.0)
        obs = Observability(clock=clk)
        tracker = SLOTracker(spec, obs, telemetry=tel)
        for _ in range(10):
            clk.advance(1.0)
            tel.note_submit("a")
            tel.note_reject("a")               # 100% rejected
            tracker.on_step()
        s = tracker._series[("a", "availability")]
        assert s.state == "breach"
        assert s.budget_remaining() == 0.0

    def test_tok_per_s_floor(self):
        spec = SLOSpec(tenants=(("a", TenantSLO(tok_per_s=10.0)),),
                       fast_steps=4, slow_steps=8, budget_steps=8)
        clk, obs, tracker = _obs_tracker(spec)
        c = obs.metrics.counter("serve_tokens_total", tenant="a")
        for _ in range(8):
            clk.advance(1.0)
            c.inc(5)                           # 5 tok/s < the 10 floor
            tracker.on_step()
        s = tracker._series[("a", "tok_per_s")]
        assert s.total == 7                    # first poll only sets cursor
        assert s.good_total == 0
        assert s.state != "ok"

    def test_acceptance_floor(self):
        spec = SLOSpec(tenants=(("a", TenantSLO(acceptance_rate=0.9)),),
                       fast_steps=4, slow_steps=8, budget_steps=8)
        clk, obs, tracker = _obs_tracker(spec)
        obs.metrics.gauge("spec_acceptance_rate").set(0.95)
        for _ in range(4):
            clk.advance(1.0)
            tracker.on_step()
        s = tracker._series[("a", "acceptance_rate")]
        assert s.good_total == 4 and s.state == "ok"
        obs.metrics.gauge("spec_acceptance_rate").set(0.5)
        for _ in range(8):
            clk.advance(1.0)
            tracker.on_step()
        assert s.state == "breach"

    def test_default_applies_to_telemetry_tenants(self):
        from repro.fleet import FleetTelemetry
        clk = FakeClock()
        tel = FleetTelemetry(clk)
        tel.register("x")
        tel.register("y")
        spec = SLOSpec(default=TenantSLO(itl_p95_ms=50.0))
        obs = Observability(clock=clk)
        tracker = SLOTracker(spec, obs, telemetry=tel)
        clk.advance(1.0)
        tracker.on_step()
        assert set(tracker._series) == {("x", "itl_p95_ms"),
                                        ("y", "itl_p95_ms")}

    def test_report_validates_and_summarizes(self):
        clk, obs, tracker = _obs_tracker()
        _drive(clk, obs, tracker)
        rep = tracker.report()
        found = validate_report(rep)
        assert sorted(found) == ["bronze/itl_p95_ms", "gold/itl_p95_ms"]
        assert rep["worst_state"] == "breach" and rep["breached"]
        summary = tracker.tenant_summary("bronze")
        assert summary["itl_p95_ms"]["state"] == "breach"


# ---------------------------------------------------------------------------
# flight-recorder integration
# ---------------------------------------------------------------------------

class TestFlightIntegration:
    def test_breach_dumps_ring_and_metrics_once(self):
        clk = FakeClock()
        obs = Observability(clock=clk)
        flight = obs.attach_flight(FlightRecorder(cooldown_s=5.0))
        tracker = SLOTracker(_spec(), obs)
        _drive(clk, obs, tracker)
        (dump,) = flight.dumps                 # exactly one dump
        assert dump["reason"] == "slo_breach"
        assert dump["info"]["tenant"] == "bronze"
        assert dump["info"]["objective"] == "itl_p95_ms"
        assert dump["events"]                  # ring captured
        assert "gauges" in dump["metrics"]     # metrics captured

    def test_per_reason_cooldown_suppresses_burst(self):
        clk = FakeClock()
        obs = Observability(clock=clk)
        flight = obs.attach_flight(FlightRecorder(cooldown_s=1000.0))
        # tracker cooldown 0: every episode emits an event; the flight
        # recorder's own per-reason cooldown must absorb the burst
        tracker = SLOTracker(_spec(cooldown_s=0.0), obs)
        gold = obs.metrics.histogram("serve_itl_ms", tenant="gold")
        bronze = obs.metrics.histogram("serve_itl_ms", tenant="bronze")
        for step in range(48):
            clk.advance(1.0)
            gold.record(5.0)
            bad = 8 <= step < 16 or 32 <= step < 40
            bronze.record(500.0 if bad else 5.0)
            tracker.on_step()
        s = tracker._series[("bronze", "itl_p95_ms")]
        assert len(s.episodes) == 2            # two events fired...
        assert len(flight.dumps) == 1          # ...one dump taken
        assert flight.dropped_dumps >= 1


# ---------------------------------------------------------------------------
# CLI gates
# ---------------------------------------------------------------------------

class TestCLI:
    def test_usage_error(self, capsys):
        assert slo_main([]) == 2
        assert slo_main(["--bogus"]) == 2

    def test_healthy_report_passes(self, tmp_path):
        clk, obs, tracker = _obs_tracker()
        _drive(clk, obs, tracker, bad_after=99)
        path = str(tmp_path / "ok.json")
        tracker.save(path)
        assert slo_main([path]) == 0

    def test_breached_report_fails(self, tmp_path):
        path = str(tmp_path / "breach.json")
        assert slo_main(["--demo-breach", path]) == 0
        assert slo_main([path]) == 1

    def test_malformed_report_fails(self, tmp_path):
        path = str(tmp_path / "bad.json")
        path2 = str(tmp_path / "bad2.json")
        with open(path, "w") as f:
            json.dump({"version": 2}, f)
        assert slo_main([path]) == 1
        clk, obs, tracker = _obs_tracker()
        _drive(clk, obs, tracker, bad_after=99)
        rep = tracker.report()
        rep["tenants"]["gold"]["itl_p95_ms"]["budget_remaining"] = 1.7
        with open(path2, "w") as f:
            json.dump(rep, f)
        assert slo_main([path2]) == 1

    def test_check_slo_flag(self, tmp_path):
        from repro.obs.check import main as check_main
        # minimal-but-valid trace/metrics artifacts for the base checks
        spans = ("prefill", "decode", "queued", "request")
        trace = {"traceEvents": [
            {"name": n, "ph": "X", "ts": 0, "dur": 1, "pid": 0, "tid": i}
            for i, n in enumerate(spans)]}
        hists = {f'{n}{{tenant="default"}}': {"count": 1, "p50": 1.0,
                                              "p95": 2.0}
                 for n in ("serve_ttft_ms", "serve_itl_ms",
                           "serve_queue_wait_ms", "serve_prefill_ms",
                           "serve_decode_step_ms")}
        tpath, mpath = str(tmp_path / "t.json"), str(tmp_path / "m.json")
        with open(tpath, "w") as f:
            json.dump(trace, f)
        with open(mpath, "w") as f:
            json.dump({"histograms": hists}, f)
        clk, obs, tracker = _obs_tracker()
        _drive(clk, obs, tracker)              # breached — but check only
        rpath = str(tmp_path / "r.json")       # gates on STRUCTURE
        tracker.save(rpath)
        assert check_main([tpath, mpath]) == 0
        assert check_main([tpath, mpath, "--slo", rpath]) == 0
        with open(rpath, "w") as f:
            json.dump({"version": 1, "worst_state": "ok"}, f)
        assert check_main([tpath, mpath, "--slo", rpath]) == 1
        assert check_main([tpath, mpath, "--slo"]) == 2


# ---------------------------------------------------------------------------
# health / silent-degradation layer
# ---------------------------------------------------------------------------

class _FakePcfg:
    pages_per_slot = 4


class _FakeEngine:
    fused_fallback = False
    attention_mode = "xla"
    pcfg = _FakePcfg()


class _FakePool:
    def __init__(self, occ=0.0, n_free=10):
        self.occ, self.n_free = occ, n_free

    def occupancy(self):
        return self.occ


class TestHealth:
    def test_all_healthy(self):
        obs = Observability()
        mon = HealthMonitor(obs)
        mon.register("a", engine=_FakeEngine(), pool=_FakePool())
        mon.on_step()
        assert obs.metrics.find("health", tenant="a").value == 1.0
        snap = mon.snapshot()["tenants"]["a"]
        assert snap["health"] == 1.0
        assert set(snap["components"]) == {"fused", "quality", "pool",
                                           "slo"}

    def test_fused_fallback_degrades(self):
        obs = Observability()
        eng = _FakeEngine()
        eng.fused_fallback = True
        eng.attention_mode = "xla-fallback"
        mon = HealthMonitor(obs)
        mon.register("a", engine=eng, pool=_FakePool())
        mon.on_step()
        assert obs.metrics.find("health", tenant="a").value == 0.5
        assert obs.metrics.find("health_component", tenant="a",
                                component="fused").value == 0.5
        assert mon.snapshot()["tenants"]["a"]["attention_mode"] == \
            "xla-fallback"

    def test_shadow_kl_blowup_degrades(self):
        obs = Observability()
        for _ in range(8):
            obs.metrics.histogram("quality_shadow_kl").record(5.0)
        mon = HealthMonitor(obs, kl_max=1.0)
        mon.register("a", engine=_FakeEngine(), pool=_FakePool())
        mon.on_step()
        assert obs.metrics.find("health_component", tenant="a",
                                component="quality").value == 0.5

    def test_pool_pressure_fires_once_per_episode(self):
        obs = Observability()
        pool = _FakePool(occ=0.95, n_free=2)   # headroom 2/4 < 1 request
        mon = HealthMonitor(obs)
        mon.register("a", engine=_FakeEngine(), pool=pool)
        mon.on_step()
        mon.on_step()                          # still pressured: latched
        events = [e for e in obs.tracer.events
                  if e["name"] == "pool_pressure"]
        assert len(events) == 1
        assert obs.metrics.find("pool_pressure_total",
                                tenant="a").value == 1
        assert obs.metrics.find("pool_alloc_headroom",
                                tenant="a").value == pytest.approx(0.5)
        assert obs.metrics.find("health", tenant="a").value == 0.5
        # recover, then pressure again: a second episode fires
        pool.occ, pool.n_free = 0.1, 10
        for _ in range(8):
            mon.on_step()
        assert obs.metrics.find("health", tenant="a").value == 1.0
        pool.occ, pool.n_free = 0.95, 2
        for _ in range(8):
            mon.on_step()
        assert obs.metrics.find("pool_pressure_total",
                                tenant="a").value == 2

    def test_headroom_without_pressure_is_healthy(self):
        obs = Observability()
        mon = HealthMonitor(obs)
        # free pages low but occupancy low too (small pool): no pressure
        mon.register("a", engine=_FakeEngine(),
                     pool=_FakePool(occ=0.2, n_free=2))
        mon.on_step()
        assert obs.metrics.find("health", tenant="a").value == 1.0

    def test_slo_state_caps_health(self):
        clk, obs, tracker = _obs_tracker()
        _drive(clk, obs, tracker)              # bronze breaches
        mon = HealthMonitor(obs, slo=tracker)
        mon.register("bronze", engine=_FakeEngine(), pool=_FakePool())
        mon.register("gold", engine=_FakeEngine(), pool=_FakePool())
        mon.on_step()
        assert obs.metrics.find("health", tenant="bronze").value == 0.25
        assert obs.metrics.find("health", tenant="gold").value == 1.0


# ---------------------------------------------------------------------------
# fused-fallback satellite
# ---------------------------------------------------------------------------

def _serve_once(params, ecfg, obs=None):
    pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=24,
                       max_context=32)
    server = Server(TINY, params, ecfg, pcfg, obs=obs)
    rng = np.random.default_rng(3)
    rid = server.submit(list(map(int, rng.integers(0, 256, size=5))),
                        RequestParams(max_new_tokens=4))
    server.drain(max_steps=200)
    return server, server.output(rid)


class TestFusedFallback:
    def test_genuinely_fused_run_reports_zero_fallbacks(self, params):
        obs = Observability()
        ecfg = EngineConfig(max_len=32, kv_bits=8, kv_group=16,
                            fused_attention=True)
        server, out = _serve_once(params, ecfg, obs=obs)
        assert server.engine.fused_mode is not None
        assert server.engine.fused_fallback is False
        assert server.engine.attention_mode.startswith("fused-")
        assert server.stats()["attention_mode"].startswith("fused-")
        # the counter was never created, let alone incremented
        assert obs.metrics.find("fused_fallback_total") is None
        assert not any(e["name"] == "fused_fallback"
                       for e in obs.tracer.events)
        assert len(out) == 4

    def test_pallas_unavailable_downgrades_loudly(self, params,
                                                  monkeypatch):
        monkeypatch.setattr(paged_attn, "available", lambda: False)
        obs = Observability()
        ecfg = EngineConfig(max_len=32, kv_bits=8, kv_group=16,
                            backend="ref", fused_attention=True)
        server, out = _serve_once(params, ecfg, obs=obs)
        assert server.engine.fused_mode is None
        assert server.engine.fused_fallback is True
        assert server.engine.attention_mode == "xla-fallback"
        assert server.stats()["attention_mode"] == "xla-fallback"
        assert obs.metrics.find("fused_fallback_total").value == 1
        evs = [e for e in obs.tracer.events if e["name"] == "fused_fallback"]
        assert len(evs) == 1                   # one-shot, not per step
        assert len(out) == 4

    def test_one_shot_survives_late_obs_attach(self, params, monkeypatch):
        monkeypatch.setattr(paged_attn, "available", lambda: False)
        ecfg = EngineConfig(max_len=32, kv_bits=8, kv_group=16,
                            backend="ref", fused_attention=True)
        server, _ = _serve_once(params, ecfg, obs=None)  # NOOP at build
        obs = Observability()
        server.set_obs(obs)                    # late attach must report
        assert obs.metrics.find("fused_fallback_total").value == 1
        server.set_obs(obs)                    # ...exactly once
        assert obs.metrics.find("fused_fallback_total").value == 1

    def test_unfused_engine_reports_plain_xla(self, params):
        ecfg = EngineConfig(max_len=32, kv_bits=8, kv_group=16,
                            backend="ref")
        server, _ = _serve_once(params, ecfg)
        assert server.engine.attention_mode == "xla"
        assert server.engine.fused_fallback is False

    def test_resolve_mode_reports_through_obs(self, monkeypatch):
        monkeypatch.setattr(paged_attn, "available", lambda: False)
        obs = Observability()
        assert paged_attn.resolve_mode(True, obs=obs) is None
        assert obs.metrics.find("fused_fallback_total").value == 1
        # an un-requested fused path is NOT a fallback
        assert paged_attn.resolve_mode(False, obs=obs) is None
        assert obs.metrics.find("fused_fallback_total").value == 1


# ---------------------------------------------------------------------------
# manifest + fleet integration
# ---------------------------------------------------------------------------

def _manifest(tmp_path, slo=True):
    obj = {"arch": "tiny", "tenants": [
        {"id": "gold", "scheme": "lq8w", "kv_bits": 8, "kv_group": 16,
         "max_slots": 2, "page_size": 4, "n_pages": 24, "max_context": 32,
         "weight": 3},
        {"id": "bronze", "scheme": "lq2w", "kv_bits": 2, "kv_group": 16,
         "max_slots": 2, "page_size": 4, "n_pages": 24, "max_context": 32,
         "slo": {"itl_p95_ms": 40.0}},
    ]}
    if slo:
        obj["slo"] = {"tenants": {"gold": {"ttft_p95_ms": 2000.0,
                                           "itl_p95_ms": 500.0}},
                      "windows": {"fast_steps": 4, "slow_steps": 8,
                                  "budget_steps": 8}}
    path = tmp_path / "fleet.json"
    path.write_text(json.dumps(obj))
    return str(path)


class TestManifest:
    def test_manifest_slo_sections_merge(self, tmp_path):
        from repro.fleet import load_manifest
        m = load_manifest(_manifest(tmp_path))
        assert isinstance(m.slo, SLOSpec)
        assert m.slo.fast_steps == 4
        assert m.slo.tenant_slo("gold").ttft_p95_ms == 2000.0
        assert m.slo.tenant_slo("bronze").itl_p95_ms == 40.0  # inline row
        specs = {t.tenant_id: t for t in m.tenants}
        assert specs["bronze"].slo == TenantSLO(itl_p95_ms=40.0)
        assert specs["gold"].slo is None

    def test_manifest_without_slo(self, tmp_path):
        from repro.fleet import load_manifest
        obj = {"arch": "tiny", "tenants": [
            {"id": "solo", "kv_group": 16, "max_slots": 2, "page_size": 4,
             "n_pages": 24, "max_context": 32}]}
        path = tmp_path / "f.json"
        path.write_text(json.dumps(obj))
        assert load_manifest(str(path)).slo is None

    def test_inline_only_builds_a_spec(self, tmp_path):
        from repro.fleet import load_manifest
        m = load_manifest(_manifest(tmp_path, slo=False))
        assert isinstance(m.slo, SLOSpec)
        assert m.slo.tenant_slo("bronze").itl_p95_ms == 40.0
        assert m.slo.tenant_slo("gold") is None

    def test_bad_inline_slo_rejected(self, tmp_path):
        from repro.fleet import load_manifest
        obj = {"arch": "tiny", "tenants": [
            {"id": "a", "kv_group": 16, "max_slots": 2, "page_size": 4,
             "n_pages": 24, "max_context": 32,
             "slo": {"p99_ms": 1.0}}]}
        path = tmp_path / "f.json"
        path.write_text(json.dumps(obj))
        with pytest.raises(ValueError, match="unknown SLO objectives"):
            load_manifest(str(path))


def _fleet_run(params, *, judge=False):
    from repro.fleet import FleetRegistry, FleetRouter, TenantSpec
    from repro.obs.health import attach_fleet_health
    reg = FleetRegistry(TINY, params, backend="ref")
    for tid, scheme, bits in (("gold", "lq8w", 8), ("bronze", "lq2w", 2)):
        reg.register(TenantSpec(tid, scheme=scheme, kv_bits=bits,
                                kv_group=16, max_slots=2, page_size=4,
                                n_pages=24, max_context=32))
    obs = Observability() if judge else None
    router = FleetRouter(reg, obs=obs)
    tracker = health = None
    if judge:
        # the obs (and thus the ITL histograms the tracker consumes) is
        # armed at engine build, so jit compile time lands in the first
        # steps — the latency target must dwarf it to stay deterministic
        spec = SLOSpec(default=TenantSLO(itl_p95_ms=120_000.0,
                                         availability=0.9),
                       fast_steps=4, slow_steps=8, budget_steps=8)
        tracker = SLOTracker(spec, obs, telemetry=router.telemetry)
        router.telemetry.slo = tracker
        health = attach_fleet_health(router, slo=tracker)
    rng = np.random.default_rng(7)
    for tid in ("gold", "bronze"):
        router.submit(tid, list(map(int, rng.integers(0, 256, size=6))),
                      max_new_tokens=5)
    steps = 0
    while router.has_work:
        router.step()
        if tracker is not None:
            tracker.on_step()
            health.on_step()
        steps += 1
        assert steps < 1000
    outs = {tid: router.registry[tid].scheduler.outputs()
            for tid in ("gold", "bronze")}
    return router, tracker, health, outs


class TestFleetIntegration:
    def test_bit_identical_with_judgment_plane_armed(self, params):
        _, _, _, plain = _fleet_run(params, judge=False)
        router, tracker, health, judged = _fleet_run(params, judge=True)
        assert judged == plain                 # tokens untouched
        for t in router.registry:
            assert t.engine.decode_compilations == 1
        assert tracker.worst_state("gold") == "ok"
        assert tracker.worst_state("bronze") == "ok"
        snap = router.telemetry.snapshot()
        for tid in ("gold", "bronze"):
            assert snap["tenants"][tid]["slo"]["itl_p95_ms"]["state"] == \
                "ok"
            assert snap["tenants"][tid]["health"] == 1.0
        stats = router.stats()
        assert stats["tenants"]["gold"]["attention_mode"] == "xla"
        rep = tracker.report()
        validate_report(rep)
        assert rep["worst_state"] == "ok" and not rep["breached"]

    def test_metrics_server_serves_slo_json(self, params):
        import urllib.request
        from repro.obs import MetricsServer
        _, tracker, _, _ = _fleet_run(params, judge=True)
        with MetricsServer(tracker.obs, port=0) as msrv:
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(f"{msrv.url}/slo.json")
            msrv.attach_slo(tracker)
            with urllib.request.urlopen(f"{msrv.url}/slo.json") as r:
                rep = json.loads(r.read().decode())
        validate_report(rep)
        assert rep["worst_state"] == "ok"
