"""Tests for the online quality plane (obs/numerics.py, obs/residuals.py,
obs/flight.py, obs/export.py): shadow-divergence and KV dequant probes
are host-side-only (bit-identical tokens, one compiled decode step),
error gauges move with bitwidth, cost-model residual ratios self-check at
1.0 and the calibration loop round-trips, the flight recorder dumps on
anomalies under its rate limits, and the live /metrics endpoint serves
Prometheus text."""
import json
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.core import schemes
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.obs import (FlightRecorder, MetricsServer, Observability,
                       calibrated_hw, fit_calibration, load_calibration,
                       record_residuals, record_weight_wire_error,
                       save_calibration)
from repro.obs.check import check_numerics
from repro.obs.numerics import (AcceptanceDrift, NumericsConfig,
                                QualityMonitor, layer_blocks)
from repro.plan.costmodel import plan_cost
from repro.plan.plan import candidates_for
from repro.serve import EngineConfig, PagedConfig, RequestParams, Server

TINY = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")


class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.key(0))


def _server(params, obs=None, kv_bits=8, seed=0):
    ecfg = EngineConfig(max_len=32, kv_bits=kv_bits, kv_group=16,
                        backend="ref")
    pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=24, max_context=32)
    return Server(TINY, params, ecfg, pcfg, seed=seed, obs=obs)


def _drive(server, n_req=3, max_new=6):
    rng = np.random.default_rng(3)
    rids = [server.submit(list(map(int, rng.integers(0, 256, size=5))),
                          RequestParams(max_new_tokens=max_new))
            for _ in range(n_req)]
    server.drain()
    return [server.output(r) for r in rids]


@pytest.fixture(scope="module")
def quality_run(params):
    """One instrumented serve run with probes on + its plain reference."""
    ref = _drive(_server(params))
    obs = Observability()
    server = _server(params, obs=obs)
    monitor = server.attach_quality(QualityMonitor(
        obs, TINY, params, server.engine,
        ncfg=NumericsConfig(every_n_steps=2)))
    out = _drive(server)
    residuals = record_residuals(obs, TINY, server.engine, server.pool)
    return {"ref": ref, "out": out, "obs": obs, "server": server,
            "monitor": monitor, "residuals": residuals}


# ---------------------------------------------------------------------------
# shadow divergence + KV dequant probes
# ---------------------------------------------------------------------------

class TestQualityMonitor:
    def test_probes_are_invisible(self, quality_run):
        # bit-identical tokens, ONE compiled decode step: the replay jits
        # never touch the engine's functions
        assert quality_run["out"] == quality_run["ref"]
        assert quality_run["server"].engine.decode_compilations == 1

    def test_shadow_metrics_recorded(self, quality_run):
        m = quality_run["obs"].metrics
        kl = m.find("quality_shadow_kl")
        probes = m.find("quality_shadow_probes_total")
        assert kl is not None and kl.count == probes.value > 0
        assert kl.max < 1.0          # fp weights + 8-bit KV: tiny divergence
        agree = m.find("quality_shadow_top1_agree")
        assert agree is not None and 0.0 <= agree.value <= 1.0

    def test_kv_gauges_cover_every_layer(self, quality_run):
        m = quality_run["obs"].metrics
        for i in range(TINY.n_layers):
            g = m.find("kv_dequant_mse", layer=f"layer{i}")
            assert g is not None and 0.0 <= g.value < 1e-2   # 8-bit: small
            assert m.find("kv_dequant_maxabs", layer=f"layer{i}") is not None
            bits = m.find("kv_dequant_bits", layer=f"layer{i}")
            assert bits is not None and bits.value == 8.0    # deployed wire

    def test_snapshot_passes_check_numerics(self, quality_run):
        found = check_numerics(quality_run["obs"].metrics.snapshot())
        assert "quality_shadow_kl" in found

    def test_lower_kv_bits_larger_dequant_error(self, params):
        def mean_mse(kv_bits):
            obs = Observability()
            server = _server(params, obs=obs, kv_bits=kv_bits)
            server.attach_quality(QualityMonitor(
                obs, TINY, params, server.engine,
                ncfg=NumericsConfig(every_n_steps=2)))
            _drive(server, n_req=2)
            vals = [obs.metrics.find("kv_dequant_mse",
                                     layer=f"layer{i}").value
                    for i in range(TINY.n_layers)]
            return float(np.mean(vals))
        assert mean_mse(2) > mean_mse(8) > 0.0

    def test_probe_sampling_knob(self, params):
        obs = Observability()
        server = _server(params, obs=obs)
        server.attach_quality(QualityMonitor(
            obs, TINY, params, server.engine,
            ncfg=NumericsConfig(every_n_steps=0)))    # probes disabled
        _drive(server, n_req=1)
        assert obs.metrics.find("quality_shadow_kl") is None


def test_layer_blocks_enumerates_params_in_order(params):
    idx = [i for i, _ in layer_blocks(params["decoder"], TINY)]
    assert idx == list(range(TINY.n_layers))
    blocks = dict(layer_blocks(params["decoder"], TINY))
    leaves = jax.tree.leaves(blocks[0])
    assert all(leaf.ndim >= 1 for leaf in leaves)    # stack dim sliced away


# ---------------------------------------------------------------------------
# weight wire-error
# ---------------------------------------------------------------------------

class TestWeightWireError:
    def test_lower_bits_larger_error(self, params):
        cands = candidates_for(TINY, ["lq8w", "lq2w"])
        e8 = record_weight_wire_error(None, TINY, params, cands["lq8w"])
        e2 = record_weight_wire_error(None, TINY, params, cands["lq2w"])
        assert set(e8) == {f"layer{i}" for i in range(TINY.n_layers)}
        for label in e8:
            assert e8[label]["n_weights"] == e2[label]["n_weights"] > 0
            assert 0.0 < e8[label]["mse"] < e2[label]["mse"]

    def test_fp_scheme_zero_error(self, params):
        out = record_weight_wire_error(None, TINY, params, schemes.FP32)
        assert all(s["mse"] == 0.0 and s["n_weights"] == 0
                   for s in out.values())

    def test_gauges_recorded(self, params):
        cands = candidates_for(TINY, ["lq8w"])
        obs = Observability()
        record_weight_wire_error(obs, TINY, params, cands["lq8w"])
        g = obs.metrics.find("quant_weight_mse", layer="layer0")
        assert g is not None and g.value > 0.0


# ---------------------------------------------------------------------------
# spec-acceptance drift
# ---------------------------------------------------------------------------

class TestAcceptanceDrift:
    def test_fires_once_per_breach_episode(self):
        d = AcceptanceDrift(alpha=1.0, threshold=0.1, min_cycles=2,
                            baseline=0.9)
        assert d.update(0.9) is False      # warmup cycle
        assert d.update(0.9) is False      # settled, no breach
        assert d.update(0.5) is True       # breach edge fires
        assert d.update(0.5) is False      # latched: no re-fire
        assert d.update(0.9) is False      # recovery clears the latch
        assert d.update(0.5) is True       # next episode fires again

    def test_baseline_auto_calibrates(self):
        d = AcceptanceDrift(alpha=1.0, threshold=0.1, min_cycles=3)
        for _ in range(3):
            assert d.update(0.8) is False
        assert d.baseline == pytest.approx(0.8)
        assert d.update(0.4) is True

    def test_alpha_validated(self):
        with pytest.raises(ValueError, match="alpha"):
            AcceptanceDrift(alpha=0.0)

    def test_spec_engine_feeds_drift(self, params):
        from repro.plan import QuantPlan
        from repro.spec import SpeculativeEngine
        cands = candidates_for(TINY, ["lq2w"])
        ecfg = EngineConfig(max_len=32, kv_bits=8, kv_group=16,
                            backend="ref")
        pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=24,
                           max_context=32)
        obs = Observability()
        eng = SpeculativeEngine(TINY, params, ecfg, pcfg,
                                draft_plan=QuantPlan(default=cands["lq2w"]),
                                spec_k=3, obs=obs)
        server = Server(TINY, params, ecfg, pcfg, engine=eng, obs=obs)
        server.attach_quality(QualityMonitor(
            obs, TINY, params, eng,
            ncfg=NumericsConfig(every_n_steps=0)))   # drift only
        _drive(server, n_req=2)
        ewma = obs.metrics.find("spec_acceptance_ewma")
        assert ewma is not None and 0.0 <= ewma.value <= 1.0


# ---------------------------------------------------------------------------
# cost-model residuals + calibration loop
# ---------------------------------------------------------------------------

class TestResiduals:
    def test_byte_ratios_are_exact(self, quality_run):
        res = quality_run["residuals"]
        assert res["weight_bytes"]["ratio"] == pytest.approx(1.0)
        assert res["kv_bytes"]["ratio"] == pytest.approx(1.0)
        assert res["decode_ms"]["measured"] > 0.0

    def test_residual_gauges(self, quality_run):
        g = quality_run["obs"].metrics.find(
            "costmodel_residual", quantity="kv_bytes", stat="ratio")
        assert g is not None and g.value == pytest.approx(1.0)

    def test_calibration_roundtrip(self, quality_run, tmp_path):
        calib = fit_calibration(quality_run["residuals"], model=TINY.name)
        assert calib["ms_factor"] > 0 and calib["model"] == "tiny"
        path = tmp_path / "calib.json"
        save_calibration(path, calib)
        assert load_calibration(path)["ms_factor"] == calib["ms_factor"]

    def test_load_rejects_non_calibration(self, tmp_path):
        p = tmp_path / "other.json"
        p.write_text(json.dumps({"foo": 1}))
        with pytest.raises(ValueError, match="ms_factor"):
            load_calibration(p)

    def test_calibrated_hw_scales_predicted_ms(self):
        configs = (schemes.get("lq8w"),) * TINY.n_layers
        base = plan_cost(TINY, configs)
        slow = plan_cost(TINY, configs, calibrated_hw(2.5))
        assert slow["ms"] == pytest.approx(2.5 * base["ms"])
        assert slow["bytes"] == base["bytes"]      # bytes are hw-free

    def test_calibrated_hw_rejects_nonpositive(self):
        with pytest.raises(ValueError, match="positive"):
            calibrated_hw(0.0)


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def _event(name, **args):
    return {"name": name, "ph": "i", "ts": 0.0, "pid": 0, "tid": 0,
            "args": args}


class TestFlightRecorder:
    def test_ring_is_bounded(self):
        fr = FlightRecorder(capacity=4, clock=FakeClock())
        for i in range(10):
            fr.on_record(_event("decode", step=i))
        assert len(fr.ring) == 4
        assert fr.ring[0]["args"]["step"] == 6

    def test_alloc_fail_triggers_dump(self):
        fr = FlightRecorder(clock=FakeClock())
        fr.on_record(_event("decode"))
        fr.on_record(_event("alloc_fail", rid=7, n_pages=3, free=1))
        assert len(fr.dumps) == 1
        d = fr.dumps[0]
        assert d["reason"] == "alloc_fail" and d["info"]["rid"] == 7
        assert [e["name"] for e in d["events"]] == ["decode", "alloc_fail"]

    def test_cooldown_suppresses_then_recovers(self):
        clk = FakeClock()
        fr = FlightRecorder(cooldown_s=5.0, clock=clk)
        fr.on_record(_event("alloc_fail"))
        fr.on_record(_event("alloc_fail"))      # inside cooldown
        assert len(fr.dumps) == 1 and fr.dropped_dumps == 1
        clk.advance(6.0)
        fr.on_record(_event("alloc_fail"))
        assert len(fr.dumps) == 2

    def test_preempt_storm_window(self):
        clk = FakeClock()
        fr = FlightRecorder(storm_n=3, storm_window_s=1.0, clock=clk)
        for _ in range(2):
            fr.on_record(_event("preempt"))
        clk.advance(2.0)                        # the window slides past
        fr.on_record(_event("preempt"))
        assert not fr.dumps
        fr.on_record(_event("preempt"))
        fr.on_record(_event("preempt"))
        assert len(fr.dumps) == 1
        assert fr.dumps[0]["reason"] == "preempt_storm"
        assert fr.dumps[0]["info"]["preempts"] == 3

    def test_max_dumps_cap(self):
        clk = FakeClock()
        fr = FlightRecorder(max_dumps=2, cooldown_s=0.0, clock=clk)
        for _ in range(4):
            fr.on_record(_event("alloc_fail"))
            clk.advance(1.0)
        assert len(fr.dumps) == 2 and fr.dropped_dumps == 2

    def test_dump_files_and_save(self, tmp_path):
        out = tmp_path / "flight.json"
        fr = FlightRecorder(out=str(out), clock=FakeClock())
        fr.on_record(_event("drift_alarm", ewma=0.3))
        dump_path = tmp_path / "flight.json.1.drift_alarm.json"
        assert json.loads(dump_path.read_text())["reason"] == "drift_alarm"
        fr.save(out)
        snap = json.loads(out.read_text())
        assert len(snap["dumps"]) == 1 and snap["dropped_dumps"] == 0

    def test_pool_exhaustion_reaches_recorder(self, params):
        obs = Observability()
        fr = obs.attach_flight(FlightRecorder())
        server = _server(params, obs=obs)
        ok = server.pool.alloc(99, server.pool.n_allocatable + 1)
        assert ok is False
        assert fr.dumps and fr.dumps[0]["reason"] == "alloc_fail"
        assert obs.metrics.find("pool_alloc_fail_total").value == 1


# ---------------------------------------------------------------------------
# live /metrics endpoint
# ---------------------------------------------------------------------------

class TestMetricsServer:
    def test_routes(self):
        obs = Observability()
        obs.metrics.counter("serve_tokens_total", tenant="t").inc(5)
        obs.metrics.histogram("serve_itl_ms").record(1.5)
        with MetricsServer(obs, port=0) as srv:
            text = urllib.request.urlopen(f"{srv.url}/metrics").read()
            body = text.decode()
            assert 'serve_tokens_total{tenant="t"} 5' in body
            assert "# TYPE serve_itl_ms histogram" in body
            assert urllib.request.urlopen(
                f"{srv.url}/healthz").read() == b"ok\n"
            snap = json.loads(urllib.request.urlopen(
                f"{srv.url}/snapshot.json").read())
            assert snap["counters"]['serve_tokens_total{tenant="t"}'] == 5
            with pytest.raises(urllib.error.HTTPError) as exc:
                urllib.request.urlopen(f"{srv.url}/nope")
            assert exc.value.code == 404

    def test_close_releases_port(self):
        obs = Observability()
        srv = MetricsServer(obs, port=0)
        url = srv.url
        srv.close()
        with pytest.raises(urllib.error.URLError):
            urllib.request.urlopen(f"{url}/healthz", timeout=0.5)

    def test_concurrent_scrapes_while_recording(self):
        # a GET storm against /metrics + /snapshot.json while the
        # registry is being written: every response parses, none hangs
        # (scrapers race the serving threads in production)
        import threading

        obs = Observability()
        for i in range(8):
            obs.metrics.histogram("serve_itl_ms",
                                  tenant=f"t{i}").record(1.0)
        stop = threading.Event()

        def writer():
            i = 0
            while not stop.is_set():
                obs.metrics.counter("serve_tokens_total",
                                    tenant=f"t{i % 8}").inc()
                obs.metrics.histogram(
                    "serve_itl_ms", tenant=f"t{i % 8}").record(i % 7 + 0.5)
                i += 1

        errors: list = []

        def scraper(url, n=20):
            try:
                for _ in range(n):
                    body = urllib.request.urlopen(
                        f"{url}/metrics", timeout=5).read().decode()
                    assert "# TYPE serve_itl_ms histogram" in body
                    snap = json.loads(urllib.request.urlopen(
                        f"{url}/snapshot.json", timeout=5).read())
                    assert "histograms" in snap
            except Exception as e:                 # pragma: no cover
                errors.append(e)

        with MetricsServer(obs, port=0) as srv:
            wt = threading.Thread(target=writer, daemon=True)
            wt.start()
            threads = [threading.Thread(target=scraper, args=(srv.url,))
                       for _ in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=60)
            stop.set()
            wt.join(timeout=5)
        assert not errors, errors
        assert not any(t.is_alive() for t in threads), "scrape hung"


# ---------------------------------------------------------------------------
# straggler monitor on the shared Stopwatch
# ---------------------------------------------------------------------------

def test_straggler_uses_injectable_clock():
    from repro.distributed.straggler import StragglerMonitor
    clk = FakeClock()
    mon = StragglerMonitor(clock=clk)
    mon.start()
    clk.advance(0.25)
    assert mon.stop() == pytest.approx(0.25)
    assert mon.stats()["count"] == 1
