"""Tests for the ``python -m repro.obs.check`` artifact gate: exit codes
(0 valid, 1 malformed/invalid, 2 usage) and the ``--spec`` /
``--numerics`` extensions, driven through ``main(argv)`` directly."""
import json

import pytest

from repro.obs.check import check_numerics, main


def _trace(extra_spans=()):
    """A minimal Chrome-trace dict carrying the required serving spans."""
    names = ["prefill", "decode", "queued", "request", *extra_spans]
    return {"traceEvents": [
        {"name": n, "ph": "X", "ts": i * 10.0, "dur": 5.0,
         "pid": 0, "tid": i}
        for i, n in enumerate(names)]}


def _hist(count=3):
    return {"count": count, "p50": 1.0, "p95": 2.0}


def _metrics(extra_hists=(), quality=False):
    names = ["serve_ttft_ms", "serve_itl_ms", "serve_queue_wait_ms",
             "serve_prefill_ms", "serve_decode_step_ms", *extra_hists]
    snap = {"counters": {}, "gauges": {},
            "histograms": {n: _hist() for n in names}}
    if quality:
        snap["histograms"]["quality_shadow_kl"] = _hist()
        snap["gauges"] = {
            "quality_shadow_top1_agree": 1.0,
            'kv_dequant_mse{layer="layer0"}': 1e-6,
            'kv_dequant_maxabs{layer="layer0"}': 1e-3,
            'costmodel_residual{quantity="weight_bytes",stat="ratio"}': 1.0}
    return snap


@pytest.fixture
def artifacts(tmp_path):
    def write(trace, metrics):
        tp, mp = tmp_path / "trace.json", tmp_path / "metrics.json"
        tp.write_text(trace if isinstance(trace, str) else json.dumps(trace))
        mp.write_text(metrics if isinstance(metrics, str)
                      else json.dumps(metrics))
        return str(tp), str(mp)
    return write


class TestExitCodes:
    def test_valid_returns_0(self, artifacts, capsys):
        tp, mp = artifacts(_trace(), _metrics())
        assert main([tp, mp]) == 0
        out = capsys.readouterr().out
        assert "serving histograms ok" in out

    def test_malformed_json_returns_1(self, artifacts, capsys):
        tp, mp = artifacts("{not json", _metrics())
        assert main([tp, mp]) == 1
        assert "check failed" in capsys.readouterr().err

    def test_missing_span_returns_1(self, artifacts, capsys):
        trace = _trace()
        trace["traceEvents"] = [e for e in trace["traceEvents"]
                                if e["name"] != "decode"]
        tp, mp = artifacts(trace, _metrics())
        assert main([tp, mp]) == 1
        assert "decode" in capsys.readouterr().err

    def test_empty_histogram_returns_1(self, artifacts, capsys):
        metrics = _metrics()
        metrics["histograms"]["serve_ttft_ms"] = _hist(count=0)
        tp, mp = artifacts(_trace(), metrics)
        assert main([tp, mp]) == 1
        assert "recorded nothing" in capsys.readouterr().err

    def test_missing_file_returns_1(self, tmp_path, capsys):
        assert main([str(tmp_path / "no.json"),
                     str(tmp_path / "nope.json")]) == 1
        assert "check failed" in capsys.readouterr().err

    def test_usage_error_returns_2(self, capsys):
        assert main([]) == 2
        assert main(["only_one.json"]) == 2
        assert main(["a.json", "b.json", "c.json"]) == 2
        assert "usage:" in capsys.readouterr().err


class TestSpecFlag:
    def test_spec_requires_draft_verify(self, artifacts, capsys):
        tp, mp = artifacts(_trace(), _metrics())
        assert main([tp, mp, "--spec"]) == 1
        err = capsys.readouterr().err
        assert "draft" in err or "verify" in err

    def test_spec_valid(self, artifacts):
        tp, mp = artifacts(
            _trace(extra_spans=("draft", "verify")),
            _metrics(extra_hists=("serve_draft_ms", "serve_verify_ms")))
        assert main([tp, mp, "--spec"]) == 0


class TestNumericsFlag:
    def test_numerics_requires_quality_metrics(self, artifacts, capsys):
        tp, mp = artifacts(_trace(), _metrics())
        assert main([tp, mp, "--numerics"]) == 1
        assert "quality_shadow_kl" in capsys.readouterr().err

    def test_numerics_valid(self, artifacts, capsys):
        tp, mp = artifacts(_trace(), _metrics(quality=True))
        assert main([tp, mp, "--numerics"]) == 0
        assert "quality-plane metrics ok" in capsys.readouterr().out

    def test_check_numerics_returns_found_keys(self):
        found = check_numerics(_metrics(quality=True))
        assert "quality_shadow_kl" in found
        assert any(k.startswith("costmodel_residual") for k in found)

    def test_check_numerics_rejects_empty_kl(self):
        snap = _metrics(quality=True)
        snap["histograms"]["quality_shadow_kl"] = _hist(count=0)
        with pytest.raises(AssertionError, match="recorded nothing"):
            check_numerics(snap)
