"""Tests for the ``python -m repro.obs.check`` artifact gate: exit codes
(0 valid, 1 malformed/invalid, 2 usage) and the ``--spec`` /
``--numerics`` / ``--profile`` extensions, driven through ``main(argv)``
directly."""
import json

import pytest

from repro.obs.check import check_numerics, check_profile, main


def _trace(extra_spans=()):
    """A minimal Chrome-trace dict carrying the required serving spans."""
    names = ["prefill", "decode", "queued", "request", *extra_spans]
    return {"traceEvents": [
        {"name": n, "ph": "X", "ts": i * 10.0, "dur": 5.0,
         "pid": 0, "tid": i}
        for i, n in enumerate(names)]}


def _hist(count=3):
    return {"count": count, "p50": 1.0, "p95": 2.0}


def _metrics(extra_hists=(), quality=False, profile=False):
    names = ["serve_ttft_ms", "serve_itl_ms", "serve_queue_wait_ms",
             "serve_prefill_ms", "serve_decode_step_ms", *extra_hists]
    snap = {"counters": {}, "gauges": {},
            "histograms": {n: _hist() for n in names}}
    if profile:
        for phase in ("gather", "dequant", "attention", "lm_head",
                      "other"):
            run = "all" if phase in ("lm_head", "other") else "run0"
            key = (f'serve_phase_ms{{layer_run="{run}",phase="{phase}"}}')
            snap["histograms"][key] = {"count": 4, "p50": 0.2, "p95": 0.4}
        snap["gauges"].update({"serve_mfu": 0.03,
                               "serve_hbm_util": 0.4})
    if quality:
        snap["histograms"]["quality_shadow_kl"] = _hist()
        snap["gauges"] = {
            "quality_shadow_top1_agree": 1.0,
            'kv_dequant_mse{layer="layer0"}': 1e-6,
            'kv_dequant_maxabs{layer="layer0"}': 1e-3,
            'costmodel_residual{quantity="weight_bytes",stat="ratio"}': 1.0}
    return snap


@pytest.fixture
def artifacts(tmp_path):
    def write(trace, metrics):
        tp, mp = tmp_path / "trace.json", tmp_path / "metrics.json"
        tp.write_text(trace if isinstance(trace, str) else json.dumps(trace))
        mp.write_text(metrics if isinstance(metrics, str)
                      else json.dumps(metrics))
        return str(tp), str(mp)
    return write


class TestExitCodes:
    def test_valid_returns_0(self, artifacts, capsys):
        tp, mp = artifacts(_trace(), _metrics())
        assert main([tp, mp]) == 0
        out = capsys.readouterr().out
        assert "serving histograms ok" in out

    def test_malformed_json_returns_1(self, artifacts, capsys):
        tp, mp = artifacts("{not json", _metrics())
        assert main([tp, mp]) == 1
        assert "check failed" in capsys.readouterr().err

    def test_missing_span_returns_1(self, artifacts, capsys):
        trace = _trace()
        trace["traceEvents"] = [e for e in trace["traceEvents"]
                                if e["name"] != "decode"]
        tp, mp = artifacts(trace, _metrics())
        assert main([tp, mp]) == 1
        assert "decode" in capsys.readouterr().err

    def test_empty_histogram_returns_1(self, artifacts, capsys):
        metrics = _metrics()
        metrics["histograms"]["serve_ttft_ms"] = _hist(count=0)
        tp, mp = artifacts(_trace(), metrics)
        assert main([tp, mp]) == 1
        assert "recorded nothing" in capsys.readouterr().err

    def test_missing_file_returns_1(self, tmp_path, capsys):
        assert main([str(tmp_path / "no.json"),
                     str(tmp_path / "nope.json")]) == 1
        assert "check failed" in capsys.readouterr().err

    def test_usage_error_returns_2(self, capsys):
        assert main([]) == 2
        assert main(["only_one.json"]) == 2
        assert main(["a.json", "b.json", "c.json"]) == 2
        assert "usage:" in capsys.readouterr().err


class TestSpecFlag:
    def test_spec_requires_draft_verify(self, artifacts, capsys):
        tp, mp = artifacts(_trace(), _metrics())
        assert main([tp, mp, "--spec"]) == 1
        err = capsys.readouterr().err
        assert "draft" in err or "verify" in err

    def test_spec_valid(self, artifacts):
        tp, mp = artifacts(
            _trace(extra_spans=("draft", "verify")),
            _metrics(extra_hists=("serve_draft_ms", "serve_verify_ms")))
        assert main([tp, mp, "--spec"]) == 0


class TestNumericsFlag:
    def test_numerics_requires_quality_metrics(self, artifacts, capsys):
        tp, mp = artifacts(_trace(), _metrics())
        assert main([tp, mp, "--numerics"]) == 1
        assert "quality_shadow_kl" in capsys.readouterr().err

    def test_numerics_valid(self, artifacts, capsys):
        tp, mp = artifacts(_trace(), _metrics(quality=True))
        assert main([tp, mp, "--numerics"]) == 0
        assert "quality-plane metrics ok" in capsys.readouterr().out

    def test_check_numerics_returns_found_keys(self):
        found = check_numerics(_metrics(quality=True))
        assert "quality_shadow_kl" in found
        assert any(k.startswith("costmodel_residual") for k in found)

    def test_check_numerics_rejects_empty_kl(self):
        snap = _metrics(quality=True)
        snap["histograms"]["quality_shadow_kl"] = _hist(count=0)
        with pytest.raises(AssertionError, match="recorded nothing"):
            check_numerics(snap)


class TestProfileFlag:
    def test_profile_requires_perf_metrics(self, artifacts, capsys):
        tp, mp = artifacts(_trace(), _metrics())
        assert main([tp, mp, "--profile"]) == 1
        assert "serve_phase_ms" in capsys.readouterr().err

    def test_profile_valid(self, artifacts, capsys):
        tp, mp = artifacts(
            _trace(extra_spans=("profile", "phase:gather")),
            _metrics(profile=True))
        assert main([tp, mp, "--profile"]) == 0
        assert "perf-plane metrics ok" in capsys.readouterr().out

    def test_profile_requires_trace_spans(self, artifacts, capsys):
        tp, mp = artifacts(_trace(), _metrics(profile=True))
        assert main([tp, mp, "--profile"]) == 1
        assert "profile" in capsys.readouterr().err

    def test_gauge_out_of_unit_interval_fails(self):
        snap = _metrics(profile=True)
        snap["gauges"]["serve_mfu"] = 0.0       # never recorded a step
        with pytest.raises(AssertionError, match="outside"):
            check_profile(_trace(("profile", "phase:gather")), snap)

    def test_phase_sum_band(self):
        snap = _metrics(profile=True)
        for k in snap["histograms"]:
            if k.startswith("serve_phase_ms"):
                snap["histograms"][k]["p50"] = 1e6  # vs step p50 of 1 ms
        with pytest.raises(AssertionError, match="implausible"):
            check_profile(_trace(("profile", "phase:gather")), snap)

    def test_spec_uses_verify_step(self, artifacts):
        # spec runs carry no plain decode-step histogram with counts;
        # the phase sum compares against serve_verify_ms instead
        tp, mp = artifacts(
            _trace(extra_spans=("draft", "verify", "profile",
                                "phase:gather")),
            _metrics(extra_hists=("serve_draft_ms", "serve_verify_ms"),
                     profile=True))
        assert main([tp, mp, "--spec", "--profile"]) == 0
