"""Tests for the perf-attribution plane (obs/profile.py, obs/check.py
--profile): the sampled phase profiler is host-side-only (bit-identical
tokens, one compiled decode step), every phase lands in the
``serve_phase_ms`` histograms, the utilization gauges stay in (0, 1],
the artifacts pass ``check_profile``, named scopes reach the lowered
HLO, and the spec engine profiles through its verifier."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.obs import Observability
from repro.obs.check import check_profile
from repro.obs.profile import (PHASES, PhaseProfiler, annotate,
                               record_utilization, xprof_capture)
from repro.plan import QuantPlan
from repro.plan.plan import candidates_for
from repro.serve import EngineConfig, PagedConfig, RequestParams, Server
from repro.spec import SpeculativeEngine

TINY = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.key(0))


def _server(params, obs=None, kv_bits=8, engine=None):
    ecfg = EngineConfig(max_len=32, kv_bits=kv_bits, kv_group=16,
                        backend="ref")
    pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=24, max_context=32)
    return Server(TINY, params, ecfg, pcfg, seed=0, obs=obs, engine=engine)


def _drive(server, n_req=3, max_new=6):
    rng = np.random.default_rng(3)
    rids = [server.submit(list(map(int, rng.integers(0, 256, size=5))),
                          RequestParams(max_new_tokens=max_new))
            for _ in range(n_req)]
    server.drain()
    return [server.output(r) for r in rids]


@pytest.fixture(scope="module")
def profiled_run(params):
    """One profiled serve run (quant KV, probes every 2 steps) + its
    uninstrumented reference."""
    ref = _drive(_server(params))
    obs = Observability()
    server = _server(params, obs=obs)
    profiler = server.attach_profiler(PhaseProfiler(
        obs, TINY, server.engine, every_n_steps=2))
    out = _drive(server)
    util = record_utilization(obs, TINY, server.engine, server.pool)
    return {"ref": ref, "out": out, "obs": obs, "server": server,
            "profiler": profiler, "util": util}


# ---------------------------------------------------------------------------
# invisibility: the hard contract
# ---------------------------------------------------------------------------

class TestInvisibility:
    def test_tokens_bit_identical(self, profiled_run):
        assert profiled_run["out"] == profiled_run["ref"]

    def test_one_compiled_decode_step(self, profiled_run):
        # the probe's standalone jits and the step replay reuse or avoid
        # the engine's traces; a second compile would mean the profiler
        # perturbed the serving path
        assert profiled_run["server"].engine.decode_compilations == 1

    def test_scheduler_key_stream_untouched(self, profiled_run):
        # the step replay folds its own key; the scheduler's fold counter
        # advanced only once per real decode step
        sched = profiled_run["server"].scheduler
        assert sched._decode_steps == profiled_run["profiler"].steps


# ---------------------------------------------------------------------------
# phase histograms
# ---------------------------------------------------------------------------

class TestPhaseHistograms:
    def test_every_phase_recorded(self, profiled_run):
        m = profiled_run["obs"].metrics
        probes = m.find("profile_probes_total")
        assert probes is not None and probes.value > 0
        snap = m.snapshot()["histograms"]
        for phase in PHASES:
            keys = [k for k in snap if k.startswith("serve_phase_ms{")
                    and f'phase="{phase}"' in k]
            assert keys, f"phase {phase!r} never recorded"
            assert all(snap[k]["count"] == probes.value for k in keys)

    def test_step_replay_recorded(self, profiled_run):
        h = profiled_run["obs"].metrics.find("serve_step_replay_ms")
        assert h is not None and h.count > 0

    def test_fp_wire_records_zero_dequant(self, params):
        obs = Observability()
        server = _server(params, obs=obs, kv_bits=None)
        server.attach_profiler(PhaseProfiler(obs, TINY, server.engine,
                                             every_n_steps=2))
        _drive(server, n_req=2)
        snap = obs.metrics.snapshot()["histograms"]
        dq = [snap[k] for k in snap if 'phase="dequant"' in k]
        assert dq and all(h["sum"] == 0.0 for h in dq)
        ga = [snap[k] for k in snap if 'phase="gather"' in k]
        assert ga and all(h["sum"] > 0.0 for h in ga)

    def test_probe_returns_breakdown(self, profiled_run):
        out = profiled_run["profiler"].probe(
            profiled_run["server"].scheduler)
        assert "gather/run0" in out and "lm_head/all" in out
        assert out["step_replay/all"] > 0.0


# ---------------------------------------------------------------------------
# utilization gauges
# ---------------------------------------------------------------------------

class TestUtilization:
    def test_gauges_in_unit_interval(self, profiled_run):
        u = profiled_run["util"]
        assert u is not None
        assert 0.0 < u["mfu"] <= 1.0
        assert 0.0 < u["hbm_util"] <= 1.0
        m = profiled_run["obs"].metrics
        assert m.find("serve_mfu").value == u["mfu"]
        assert m.find("serve_hbm_util").value == u["hbm_util"]

    def test_calibrated_hw_clamps_to_one(self, profiled_run):
        # a roof calibrated onto this very run can imply >100% on the
        # tiny model; the gauge contract clamps at 1.0
        from repro.obs import calibrated_hw
        srv = profiled_run["server"]
        hw = calibrated_hw({"ms_factor": 1e9, "model": "tiny"})
        u = record_utilization(profiled_run["obs"], TINY, srv.engine,
                               srv.pool, hw=hw,
                               labels={"tenant": "clamped"})
        assert u["mfu"] == 1.0 and u["hbm_util"] == 1.0

    def test_none_before_any_step(self, params):
        obs = Observability()
        server = _server(params, obs=obs)
        assert record_utilization(obs, TINY, server.engine,
                                  server.pool) is None


# ---------------------------------------------------------------------------
# artifact gate (check --profile)
# ---------------------------------------------------------------------------

class TestCheckProfile:
    def test_artifacts_pass(self, profiled_run, tmp_path):
        obs = profiled_run["obs"]
        tp, mp = tmp_path / "trace.json", tmp_path / "metrics.json"
        obs.save_trace(str(tp))
        obs.save_metrics(str(mp))
        trace = json.loads(tp.read_text())
        snap = json.loads(mp.read_text())
        found = check_profile(trace, snap)
        assert any("serve_mfu" in k for k in found)

    def test_missing_phase_fails(self, profiled_run):
        snap = profiled_run["obs"].metrics.snapshot()
        snap["histograms"] = {
            k: v for k, v in snap["histograms"].items()
            if 'phase="attention"' not in k}
        with pytest.raises(AssertionError, match="attention"):
            check_profile({"traceEvents": []}, snap)

    def test_out_of_range_gauge_fails(self, profiled_run, tmp_path):
        obs = profiled_run["obs"]
        tp = tmp_path / "trace.json"
        obs.save_trace(str(tp))
        snap = obs.metrics.snapshot()
        snap["gauges"]["serve_mfu"] = 1.7
        with pytest.raises(AssertionError, match="outside"):
            check_profile(json.loads(tp.read_text()), snap)


# ---------------------------------------------------------------------------
# fused engine: honest single-phase attribution
# ---------------------------------------------------------------------------

def test_fused_engine_profiles_single_fused_phase(params, tmp_path):
    """A fused engine runs gather+dequant+attention as one kernel, so the
    probe must record ONE ``fused_attention`` phase per stack run — never
    the XLA triplet — and the artifacts must pass ``check --profile``
    under that decomposition, tokens and compile count untouched."""
    from repro.kernels import paged_attention as paged_attn
    if not paged_attn.available():
        pytest.skip("Pallas unavailable: no fused mode on this host")
    ref = _drive(_server(params))
    obs = Observability()
    ecfg = EngineConfig(max_len=32, kv_bits=8, kv_group=16, backend="ref",
                        fused_attention=True)
    pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=24,
                       max_context=32)
    server = Server(TINY, params, ecfg, pcfg, seed=0, obs=obs)
    server.attach_profiler(PhaseProfiler(obs, TINY, server.engine,
                                         every_n_steps=2))
    out = _drive(server)
    assert out == ref                          # profiling + fusion: no drift
    assert server.engine.decode_compilations == 1
    record_utilization(obs, TINY, server.engine, server.pool)
    snap = obs.metrics.snapshot()
    hists = snap["histograms"]
    assert any('phase="fused_attention"' in k for k in hists)
    for phase in ("gather", "dequant", "attention"):
        assert not any(f'phase="{phase}"' in k for k in hists), \
            f"fused probe still records the XLA phase {phase!r}"
    tp = tmp_path / "trace.json"
    obs.save_trace(str(tp))
    check_profile(json.loads(tp.read_text()), snap)


# ---------------------------------------------------------------------------
# speculative engine: profile through the verifier
# ---------------------------------------------------------------------------

def test_spec_engine_profiles_via_verifier(params):
    cands = candidates_for(TINY, ["lq8w"])
    ecfg = EngineConfig(max_len=32, kv_bits=8, kv_group=16, backend="ref")
    pcfg = PagedConfig(max_slots=2, page_size=4, n_pages=40, max_context=32)
    eng = SpeculativeEngine(TINY, params, ecfg, pcfg,
                            draft_plan=QuantPlan(default=cands["lq8w"]),
                            spec_k=2)
    ref_eng = SpeculativeEngine(TINY, params, ecfg, pcfg,
                                draft_plan=QuantPlan(
                                    default=cands["lq8w"]), spec_k=2)
    ref = _drive(Server(TINY, params, ecfg, pcfg, engine=ref_eng))
    obs = Observability()
    server = Server(TINY, params, ecfg, pcfg, engine=eng, obs=obs)
    server.attach_profiler(PhaseProfiler(obs, TINY, eng, every_n_steps=2))
    out = _drive(server)
    assert out == ref                       # replay through _multi_paged
    assert eng.decode_compilations == 1     # reused the verify trace
    snap = obs.metrics.snapshot()["histograms"]
    assert any('phase="attention"' in k for k in snap)
    u = record_utilization(obs, TINY, eng, server.pool)
    assert u is not None and 0.0 < u["mfu"] <= 1.0


# ---------------------------------------------------------------------------
# annotations + capture
# ---------------------------------------------------------------------------

def test_annotate_is_a_context_manager():
    with annotate("unit-test-span"):
        x = jnp.ones((2, 2)) + 1
    assert float(x.sum()) == 8.0


def test_named_scopes_reach_lowered_hlo(params):
    pages = _server(params).pool.pages
    table = jnp.zeros((2, 8), jnp.int32)
    lowered = jax.jit(
        lambda p, t, pg, tb, pos: transformer.paged_decode_step(
            p, TINY, t, pg, tb, pos)
    ).lower(params, jnp.zeros((2, 1), jnp.int32), pages, table,
            jnp.zeros((2,), jnp.int32))
    # named scopes land in the HLO location metadata, not the op text
    text = lowered.compiler_ir().operation.get_asm(enable_debug_info=True)
    assert "lm_head" in text and "paged_decode_step" in text


def test_xprof_capture_writes_or_degrades(tmp_path):
    # on backends without profiler support this must degrade to a no-op,
    # never raise
    with xprof_capture(str(tmp_path / "xprof")):
        jax.block_until_ready(jnp.ones((4, 4)) @ jnp.ones((4, 4)))
