"""packing.py: bit-pack/unpack roundtrip across widths and shapes."""
import jax.numpy as jnp
import numpy as np
import pytest

try:        # property tests are extra coverage; the container may lack it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import packing


@pytest.mark.parametrize("bits", packing.SUPPORTED_BITS)
@pytest.mark.parametrize("shape", [(8,), (4, 16), (2, 3, 24)])
def test_roundtrip(bits, shape):
    rng = np.random.default_rng(bits)
    per = packing.codes_per_byte(bits)
    if shape[-1] % per:
        pytest.skip("unaligned")
    codes = rng.integers(0, 1 << bits, size=shape).astype(np.uint8)
    packed = packing.pack(jnp.asarray(codes), bits)
    out = packing.unpack(packed, bits, shape[-1])
    np.testing.assert_array_equal(np.asarray(out), codes)


@pytest.mark.parametrize("bits,expected", [(1, 8), (2, 4), (4, 2), (8, 1),
                                           (6, 1), (3, 1)])
def test_codes_per_byte(bits, expected):
    assert packing.codes_per_byte(bits) == expected


def test_packed_size():
    codes = jnp.zeros((4, 32), jnp.uint8)
    assert packing.pack(codes, 2).shape == (4, 8)
    assert packing.pack(codes, 4).shape == (4, 16)
    assert packing.pack(codes, 1).shape == (4, 4)
    assert packing.pack(codes, 8).shape == (4, 32)


def test_misaligned_raises():
    with pytest.raises(ValueError):
        packing.pack(jnp.zeros((4, 13), jnp.uint8), 2)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(bits=st.sampled_from([1, 2, 4, 8]),
           n_groups=st.integers(1, 5),
           data=st.data())
    def test_roundtrip_property(bits, n_groups, data):
        per = packing.codes_per_byte(bits)
        n = n_groups * per
        codes = data.draw(st.lists(st.integers(0, (1 << bits) - 1),
                                   min_size=n, max_size=n))
        arr = jnp.asarray(codes, jnp.uint8)
        out = packing.unpack(packing.pack(arr, bits), bits, n)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(arr))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_roundtrip_property():
        pass
