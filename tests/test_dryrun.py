"""Dry-run integration: one cell per kind compiles in a subprocess.

The full 40-cell x 2-mesh sweep runs via ``python -m repro.launch.dryrun
--all`` (artifacts in experiments/dryrun/); here CI compiles one train,
one prefill and one decode cell on the single-pod mesh to catch
sharding-rule regressions.  A subprocess is required because the 512
placeholder devices must be configured before jax initializes.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cell(arch, shape, mesh="single"):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh],
        capture_output=True, text=True, timeout=560, env=env, cwd=ROOT)
    blob = proc.stdout + proc.stderr
    if proc.returncode != 0 and (
            "AttributeError: module 'jax" in blob
            or "No module named 'jax" in blob
            or "Unable to initialize backend" in blob):
        # jax build / placeholder-device backend can't run the dry run here
        # (match is anchored on jax itself so real regressions still fail)
        pytest.skip("dry-run backend unavailable: " + blob.strip()[-200:])
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    return proc.stdout


@pytest.mark.slow
def test_dryrun_train_cell():
    out = _run_cell("llama3.2-1b", "train_4k")
    assert "OK" in out and "all cells passed" in out


@pytest.mark.slow
def test_dryrun_decode_cell():
    out = _run_cell("granite-3-2b", "decode_32k")
    assert "all cells passed" in out


@pytest.mark.slow
def test_dryrun_ssm_long_context():
    out = _run_cell("mamba2-130m", "long_500k")
    assert "all cells passed" in out


def test_sweep_artifacts_complete():
    """The committed sweep covers all 40 cells x 2 meshes."""
    d = os.path.join(ROOT, "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("sweep not yet run")
    files = [f for f in os.listdir(d) if f.endswith(".json")
             and "lq" not in f]
    if len(files) < 80:
        pytest.skip(f"full sweep not committed here ({len(files)}/80 cells)")
    bad = []
    for f in files:
        rec = json.load(open(os.path.join(d, f)))
        if rec.get("status") not in ("ok", "skipped"):
            bad.append(f)
    assert not bad, bad
